"""Pure-jnp oracle for filco_mm."""
from __future__ import annotations

import jax.numpy as jnp


def flex_mm_ref(a_buf, b_buf, dims):
    """out[:m,:n] = a[:m,:k] @ b[:k,:n]; zeros elsewhere.

    Implemented with masks (not slicing) so it jits with traced dims.
    """
    Mx, Kx = a_buf.shape
    _, Nx = b_buf.shape
    m, k, n = dims[0], dims[1], dims[2]
    am = (jnp.arange(Mx)[:, None] < m) & (jnp.arange(Kx)[None, :] < k)
    bm_ = (jnp.arange(Kx)[:, None] < k) & (jnp.arange(Nx)[None, :] < n)
    a = jnp.where(am, a_buf, 0)
    b = jnp.where(bm_, b_buf, 0)
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    om = (jnp.arange(Mx)[:, None] < m) & (jnp.arange(Nx)[None, :] < n)
    return jnp.where(om, out, 0).astype(a_buf.dtype)


def static_mm_ref(a_buf, b_buf):
    return jnp.dot(a_buf.astype(jnp.float32),
                   b_buf.astype(jnp.float32)).astype(a_buf.dtype)
