"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only the
dry-run is allowed to fake 512 host devices).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

# spec fitting lives with the sharding rules now (the serving engine fits
# specs per composed sub-mesh at runtime); re-exported here for launch code.
from repro.distribution.partitioning import fit_spec, sanitize_spec  # noqa: F401


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 dual-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh(shape, axes)
