"""Serving launcher.

Single-tenant continuous batching:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
      --requests 8

Multi-tenant fabric with real-time recomposition (traffic-driven: bursty
tenants steal CUs from idle ones; a lone busy tenant unifies the fabric).
Tenant engines run tensor-parallel on their sub-meshes and recompositions
pre-compile the target composition (--no-tp / --no-warm to disable).  Needs
one CU (model-axis column) per tenant — on a CPU host fake enough devices
first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --fabric \
      --arch minitron-4b --arch qwen2.5-32b --reduced --requests 12

Heterogeneous fleet (one tenant per workload class — transformer decode +
mamba SSM + encoder embedding + seamless enc-dec — with class-aware CU
costing):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --fabric --scenario mixed \
      --reduced --requests 6

Tokens/s-vs-CU-count scaling curve (the measured counterpart of the
policy's analytical speedup — run under fake devices as above):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --scaling-curve

TP-decode smoke (2-way TP streams must equal replicated 1-way; CI guard):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --tp-smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import sys
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.base import ModelConfig
from repro.core.composer import MeshComposer
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.serve import (AnalyticalPolicy, ComposedServer, ReplicaGroup,
                         SLOTarget, ServeConfig, ServeEngine,
                         TenantDesignSpace, TenantSpec, arrival_schedule,
                         serve_engine_rules)
from repro.workloads import DECODE

# --scenario profiles served by the open-loop traffic generator
# (repro.serve.traffic) on the mixed four-class fleet, with SLO targets
# attached so the fabric's SLO-aware scheduler is live
TRAFFIC_SCENARIOS = ("diurnal", "flash-crowd", "heavy-tail")


# the heterogeneous fleet --scenario mixed serves: one tenant per workload
# class, so the class-aware policy splits the fabric across all four bound
# resources (decode bandwidth / SSM state bandwidth / encoder compute /
# enc-dec decode + cross-attention source reads)
MIXED_FLEET = (("decode", "minitron-4b"),
               ("ssm", "falcon-mamba-7b"),
               ("encoder", "qwen2.5-32b"),
               ("encdec", "seamless-m4t-medium"))


def _telemetry_line(server, steps: int, toks: int, dt: float) -> str:
    """The per-interval serving summary (one line, stderr): throughput,
    decode-step percentiles, fleet queue depth, last recompose reason."""
    h = server.obs.registry.merged_histogram("decode_step_s")
    p50 = h.quantile(0.5) * 1e3 if h.count else 0.0
    p99 = h.quantile(0.99) * 1e3 if h.count else 0.0
    qd = sum(eng.queue_depth for eng in server.engines.values())
    reason = server.events[-1].reason if server.events else "-"
    return (f"[serve {dt:7.1f}s step {steps:5d}] "
            f"tok/s={toks / max(dt, 1e-9):7.1f} "
            f"step_ms p50={p50:.2f} p99={p99:.2f} "
            f"queue={qd} last_recompose={reason}")


def _streams_digest(results) -> str:
    """Order-independent sha256 over every tenant's (rid -> token stream)
    map.  Equal digests mean bit-identical serving output — the acceptance
    check that paging / preemption / SLO scheduling never change a single
    emitted token (greedy decode rows are batch-independent).  Float
    outputs (encoder embeddings) are excluded: their bits legitimately
    track the applied TP degree — reduction order — and are pinned
    close-not-equal across degrees in tests/test_workloads.py, so two runs
    whose policies diverge may differ there without any scheduling bug."""
    h = hashlib.sha256()
    for t in sorted(results):
        for rid in sorted(results[t]):
            arr = np.asarray(results[t][rid])
            if not np.issubdtype(arr.dtype, np.integer):
                continue
            h.update(f"{t}/{rid}:".encode())
            h.update(arr.tobytes())
            h.update(b";")
    return h.hexdigest()


def run_fabric(args) -> int:
    """Traffic-driven multi-tenant serving on one recomposable fabric."""
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else
            jax.make_mesh((1, jax.device_count()), ("data", "model")))
    serve = ServeConfig(max_slots=args.max_slots, max_len=args.max_len,
                        eos_id=-1, kv_arena_frac=args.kv_frac,
                        kv_page_rows=args.kv_page_rows)
    use_traffic = args.scenario in TRAFFIC_SCENARIOS
    if args.scenario == "mixed" or use_traffic:
        # traffic scenarios carry SLO targets so the SLO-aware scheduler
        # (and the attainment report) are live; plain "mixed" stays
        # best-effort — its benchmark baselines predate SLO scheduling
        slo = (SLOTarget(ttft_p50_ms=args.slo_ttft_p50_ms,
                         ttft_p99_ms=args.slo_ttft_p99_ms,
                         per_token_p99_ms=args.slo_per_token_p99_ms)
               if use_traffic else None)
        # --slo-tenant scopes the targets (and therefore the scheduler's
        # preemption lever and the attainment report) to one tenant; the
        # rest of the fleet serves best-effort
        tenants = [TenantSpec(f"{w}-{arch}", arch, reduced=args.reduced,
                              serve=serve, seed=i, workload=w,
                              slo=(slo if args.slo_tenant in f"{w}-{arch}"
                                   else None))
                   for i, (w, arch) in enumerate(MIXED_FLEET)]
    else:
        tenants = [TenantSpec(f"tenant{i}-{arch}", arch, reduced=args.reduced,
                              serve=serve, seed=i)
                   for i, arch in enumerate(args.arch)]
    policy = AnalyticalPolicy(two_stage=not args.split_only)
    server = ComposedServer(mesh, tenants, policy=policy,
                            decide_every=args.decide_every,
                            tp=not args.no_tp, warm=not args.no_warm,
                            prewarm_async=args.prewarm_async,
                            telemetry=not args.no_telemetry,
                            slo_preempt=not args.no_preempt)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    if use_traffic:
        # seeded open-loop arrival process (repro.serve.traffic): the same
        # seed replays the identical schedule, so paired benchmark arms
        # (paged vs slot-granular) see the same offered load
        queue = [(a.step, a.tenant, a.prompt_len, a.max_new)
                 for a in arrival_schedule(
                     args.scenario, [t.name for t in tenants],
                     args.requests, args.seed,
                     max_new=args.max_new_tokens)]
    else:
        # bursty open-loop traffic: each tenant gets its requests in one
        # burst at a random step, so load keeps shifting under the
        # policy's feet (prompt lengths draw at submit time — the rng
        # stream here is unchanged from the pre-traffic-module launcher)
        queue = [(s, n, None, args.max_new_tokens)
                 for s, n in sorted((int(rng.integers(0, 4 * args.requests)),
                                     t.name)
                                    for t in tenants
                                    for _ in range(args.requests))]
    steps = 0
    predicted = None
    toks = 0
    # harness-level step timing: host perf_counter around server.step(),
    # measured identically with telemetry on or off — the benchmark's
    # overhead comparison reads this, not the registry's own histograms
    harness_step_ms = []
    while queue or server.pending():
        while queue and queue[0][0] <= steps:
            _, name, plen, mnew = queue.pop(0)
            vocab = server.cfgs[name].vocab_size
            if plen is None:
                plen = int(rng.integers(4, 24))
            server.submit(name, rng.integers(1, vocab, size=plen),
                          max_new_tokens=mnew)
        s0 = time.perf_counter()
        out = server.step()
        harness_step_ms.append((time.perf_counter() - s0) * 1e3)
        toks += sum(len(v) for v in out.values())
        if policy.predicted is not None:
            predicted = dict(policy.predicted)   # last busy decide's view
        steps += 1
        if args.log_every and steps % args.log_every == 0:
            # stderr: stdout carries exactly one JSON document (the
            # benchmark harness parses it from the first brace)
            print(_telemetry_line(server, steps, toks,
                                  time.monotonic() - t0), file=sys.stderr)
        if steps > 10_000:
            break
    if use_traffic:
        # the open-loop while above exits when no tokens are *owed*; drain
        # the in-flight pipelined dispatches too so completion checks and
        # the streams digest see every request's full output
        server.drain(max_steps=2000)
    dt = time.monotonic() - t0
    stats = server.stats()
    arr = np.asarray(harness_step_ms if harness_step_ms else [0.0])
    # per-class throughput: decode/ssm/encdec tenants emit tokens, encoder
    # tenants emit completed sequences (embeddings)
    throughput = {
        t: {"class": server.classes[t],
            "unit": ("seqs_per_s" if server.classes[t] == "encoder"
                     else "tokens_per_s"),
            "value": round(stats["tokens_emitted"][t] / dt, 2)}
        for t in server.engines}
    print(json.dumps({
        "tenants": [t.name for t in tenants], "scenario": args.scenario,
        "two_stage": not args.split_only,
        "decode_steps": steps,
        "wall_s": round(dt, 2), **stats,
        "telemetry": not args.no_telemetry,
        "harness_step_ms": {
            "p50": round(float(np.percentile(arr, 50)), 3),
            "p99": round(float(np.percentile(arr, 99)), 3),
            "n": len(harness_step_ms)},
        "slo": server.slo_summary(),
        "slo_attainment": server.slo_attainment(),
        "streams_digest": _streams_digest(server.results()),
        "per_class_throughput": throughput,
        # the last busy decide's predicted makespans (analytical, seconds):
        # what Stage 2 thought the best and the applied design cost
        "predicted_makespan_s": predicted,
        "events": [{"step": e.step, "reason": e.reason,
                    "sizes": e.sizes_after,
                    "retuned": list(e.retuned),
                    "design": e.design,
                    "seconds": round(e.seconds, 4),
                    "warm_compile_seconds": round(e.warm_compile_seconds, 4),
                    "warm_builds": e.warm_builds,
                    "overlapped": e.overlapped,
                    "post_step_seconds": {
                        t: round(s, 4)
                        for t, s in e.post_step_seconds.items()}}
                   for e in server.events],
    }, indent=1))
    if args.trace_out:
        server.dump_trace(args.trace_out)
        print(f"trace written: {args.trace_out}", file=sys.stderr)
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(server.metrics_snapshot(), f, indent=1)
        print(f"metrics written: {args.metrics_json}", file=sys.stderr)
    return 0


# ---------------------------------------------------------------------------
# tokens/s vs CU count: the measured scaling curve
# ---------------------------------------------------------------------------

def bench_config(d_model: int, layers: int, d_ff: int) -> ModelConfig:
    """A dense decode-bench model heavy enough that per-CU work dominates
    dispatch overhead on a CPU host (the reduced smoke configs are dominated
    by fixed per-step cost, which no amount of TP can shrink)."""
    heads = max(d_model // 128, 1)
    return ModelConfig(
        name=f"serve-bench-d{d_model}-L{layers}", family="dense",
        num_layers=layers, d_model=d_model, num_heads=heads,
        num_kv_heads=max(heads // 2, 1), d_ff=d_ff, vocab_size=2048,
        head_dim=128, attn_type="full", dtype="float32", remat=False)


def run_scaling(args) -> int:
    """Measure steady-state decode tokens/s at each sub-mesh size: the
    direct validation that CUs granted by the policy buy throughput.

    CUs buy *capacity*: the tenant's pooled KV cache shards over its
    sub-mesh, so a composition of k CUs holds k times the decode slots at
    the same per-device memory (``--scale-slots-per-cu``).  Decode at small
    batch is weights-bound, so the extra slots ride the same weight streams
    and per-step latency stays ~flat while tokens/s scales with the grant —
    the measured counterpart of the policy's analytical speedup.  The
    flatness of ``step_ms_by_cus`` is itself part of the evidence."""
    cfg = bench_config(args.scale_dmodel, args.scale_layers, args.scale_dff)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    comp = MeshComposer(mesh)
    rules = None if args.no_tp else serve_engine_rules()
    sizes = [s for s in args.scale_sizes if s <= comp.num_cus]
    M = args.scale_steps
    curve, lat, slots = {}, {}, {}
    for size in sizes:
        B = args.scale_slots_per_cu * size
        eng = ServeEngine(model, params,
                          ServeConfig(max_slots=B, max_len=args.max_len,
                                      eos_id=-1),
                          mesh=comp.submesh(range(size), f"cus{size}"),
                          rules=rules)
        rng = np.random.default_rng(args.seed)
        for _ in range(B):
            eng.submit(rng.integers(1, cfg.vocab_size, size=16),
                       max_new_tokens=3 * M + 8)
        for _ in range(3):                    # prefill + warm the executable
            eng.step()
        jax.block_until_ready(eng.cache)
        best, steps_ms = 0.0, []
        for _ in range(2):                    # best-of-2 absorbs host jitter
            t0 = time.perf_counter()
            for _ in range(M):
                s0 = time.perf_counter()
                eng.step()
                steps_ms.append((time.perf_counter() - s0) * 1e3)
            jax.block_until_ready(eng.cache)
            best = max(best, B * M / (time.perf_counter() - t0))
        curve[size], slots[size] = round(best, 2), B
        arr = np.asarray(steps_ms)
        lat[size] = {"p50": round(float(np.percentile(arr, 50)), 2),
                     "p95": round(float(np.percentile(arr, 95)), 2)}
    monotone = all(curve[a] < curve[b]
                   for a, b in zip(sizes, sizes[1:]))
    print(json.dumps({
        "bench_model": cfg.name, "measured_steps": M,
        "tp": not args.no_tp,
        "slots_by_cus": {str(s): slots[s] for s in sizes},
        "tokens_per_s_by_cus": {str(s): curve[s] for s in sizes},
        "step_ms_by_cus": {str(s): lat[s] for s in sizes},
        "monotone": monotone,
    }, indent=1))
    return 0


# ---------------------------------------------------------------------------
# DSE smoke: Stage 1 must pick a non-default design point, applied live
# ---------------------------------------------------------------------------

def run_dse_smoke(args) -> int:
    """Two-tenant fleet under the two-stage policy: the serving DSE's
    Stage 1 must pick at least one non-default design point (slot count
    above the provisioned default, or a TP degree below the grant) and the
    fabric must apply it live (a recomposition event carrying design
    deltas) while every stream completes.  Tenant "a" is a small model
    whose engine batch is structurally capped (``slot_cap``), so on a
    multi-CU grant Stage 1 must also pick ``dp > 1`` — data-parallel
    replica tiling, applied live through the ReplicaGroup's
    drain-and-rebalance.  Fast CI guard that the two-stage path actually
    optimizes rather than echoing the engine defaults."""
    if jax.device_count() < 4:
        print("dse-smoke needs >= 4 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return 2
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=48, eos_id=-1)
    # a: small model, batch capped at 4 slots/engine -> a deep queue on a
    # wide grant is only servable by replica tiling (the dp axis)
    sc_a = dataclasses.replace(sc, slot_cap=4)
    tenants = [TenantSpec("a", "minitron-4b", serve=sc_a),
               TenantSpec("b", "qwen2.5-32b", seed=1, serve=sc)]
    server = ComposedServer(mesh, tenants, policy=AnalyticalPolicy(),
                            decide_every=3)
    rng = np.random.default_rng(args.seed)
    for t, n in (("a", 16), ("b", 6)):     # queue depth >> default slots
        vocab = server.cfgs[t].vocab_size
        for _ in range(n):
            server.submit(t, rng.integers(1, vocab, size=8),
                          max_new_tokens=10)
    out = server.drain(max_steps=500)
    stats = server.stats()
    applied = {t: d for e in server.events for t, d in e.design.items()}
    nondefault = {
        t: d for t, d in stats["design_points"].items()
        if d["slots"] != sc.max_slots
        or (d["tp"] is not None and 0 < d["tp"] < d["cus"])}
    # dp > 1 is a steady-load design: once the fleet drains, the policy
    # folds "a" back to one engine — so assert over the event history
    dp_picked = any(e.design.get("a", {}).get("dp", 1) > 1
                    and e.sizes_after.get("a", 0) >= 4
                    for e in server.events)
    complete = all(len(toks) == 10
                   for streams in out.values() for toks in streams.values())
    ok = bool(nondefault) and bool(applied) and dp_picked and complete
    print(json.dumps({"design_points": stats["design_points"],
                      "applied_deltas": applied,
                      "nondefault": sorted(nondefault),
                      "dp_picked": dp_picked,
                      "complete": complete, "ok": ok}))
    if not ok:
        print("DSE smoke FAILED: Stage 1 never picked (or the fabric never "
              "applied) a non-default design point with dp > 1")
        return 1
    print("DSE smoke OK: non-default design point (dp > 1) chosen and "
          "applied live")
    return 0


# ---------------------------------------------------------------------------
# obs smoke: the telemetry pipeline must observe a real mixed-fleet run
# ---------------------------------------------------------------------------

def run_obs_smoke(args) -> int:
    """Telemetry smoke on the heterogeneous fleet: serve a short
    ``--scenario mixed`` run with tracing on, export the Perfetto trace,
    and assert that

    * the trace-event JSON is valid and carries at least one ``recompose``
      span plus decode-step and warm-compile spans, and
    * every tenant class accumulated a non-empty decode-step histogram
      (the encoder class records its batched encode iteration under the
      same ``decode_step_s`` name — one CI predicate covers all four).

    Fast CI guard that instrumentation stays wired through the whole
    stack: engines, replica groups, the fabric and the exporters."""
    if jax.device_count() < 4:
        print("obs-smoke needs >= 4 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return 2
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    serve = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    tenants = [TenantSpec(f"{w}-{arch}", arch, reduced=True, serve=serve,
                          seed=i, workload=w)
               for i, (w, arch) in enumerate(MIXED_FLEET)]
    server = ComposedServer(mesh, tenants, policy=AnalyticalPolicy(),
                            decide_every=3)
    rng = np.random.default_rng(args.seed)
    for t in server.engines:
        vocab = server.cfgs[t].vocab_size
        for _ in range(3):
            server.submit(t, rng.integers(1, vocab, size=8),
                          max_new_tokens=6)
    server.drain(max_steps=600)
    if server.stats()["recompositions"] == 0:
        # quiet run: force one live recomposition so the trace predicate
        # exercises the recompose span path deterministically
        sizes = server.sizes()
        lo = min(sizes, key=sizes.get)
        hi = max(sizes, key=sizes.get)
        sizes[lo], sizes[hi] = sizes[lo] + 1, sizes[hi] - 1
        server.recompose(sizes, reason="obs-smoke")
        server.drain(max_steps=200)
    trace_path = args.trace_out or "/tmp/obs_smoke_trace.json"
    server.dump_trace(trace_path)
    with open(trace_path) as f:
        trace = json.load(f)
    events = trace.get("traceEvents", [])
    names = [e.get("name") for e in events]
    schema_ok = all(
        isinstance(e.get("ts"), (int, float))
        and isinstance(e.get("dur"), (int, float))
        and e.get("ph") == "X" and e.get("name")
        for e in events)
    merged = server.metrics()
    hist_by_class = {
        server.classes[t]:
            merged.merged_histogram("decode_step_s", tenant=t).count
        for t in server.engines}
    checks = {
        "trace_events": len(events),
        "trace_schema_ok": bool(events) and schema_ok,
        "recompose_spans": names.count("recompose"),
        "decode_step_spans": sum(n in ("decode_step", "encode_step")
                                 for n in names),
        "warm_compile_spans": names.count("warm_compile"),
        "decode_step_hist_by_class": hist_by_class,
    }
    ok = (checks["trace_schema_ok"]
          and checks["recompose_spans"] >= 1
          and checks["decode_step_spans"] >= 1
          and checks["warm_compile_spans"] >= 1
          and all(n > 0 for n in hist_by_class.values()))
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(server.metrics_snapshot(), f, indent=1)
    print(json.dumps({**checks, "trace_path": trace_path, "ok": ok}))
    if not ok:
        print("obs smoke FAILED: telemetry pipeline lost spans or "
              "histograms (see checks above)")
        return 1
    print("obs smoke OK: recompose/decode-step/warm-compile spans traced "
          "and every tenant class has decode-step latency histograms")
    return 0


# ---------------------------------------------------------------------------
# SLO smoke: flash-crowd must preempt, preempted streams must stay bit-exact
# ---------------------------------------------------------------------------

def run_slo_smoke(args) -> int:
    """Paged-KV + SLO-preemption smoke on the mixed fleet.

    A flash-crowd schedule lands on an *oversubscribed* paged arena
    (``kv_arena_frac`` well under 1), so page exhaustion during decode
    growth — plus the SLO scheduler's TTFT protection — must preempt at
    least one live stream.  The same schedule then replays on slot-granular
    (non-paged, non-preempting) engines, and every emitted unit must match
    bit-for-bit: preemption saves exact device state and greedy decode rows
    are batch-independent, so scheduling may never change output.  Asserts

    * at least one preemption fired on the paged run,
    * every request (preempted ones included) completed its full budget,
    * paged and slot-granular runs produce identical stream digests, and
    * the SLO attainment block is non-empty.
    """
    if jax.device_count() < 4:
        print("slo-smoke needs >= 4 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return 2
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    requests, mnew = max(args.requests, 6), 24

    def build(paged: bool) -> ComposedServer:
        serve = ServeConfig(max_slots=3, max_len=64, eos_id=-1,
                            paged_kv=paged, kv_page_rows=8,
                            kv_arena_frac=0.4 if paged else 1.0)
        slo = (SLOTarget(ttft_p50_ms=100.0, ttft_p99_ms=400.0)
               if paged else None)
        tenants = [TenantSpec(f"{w}-{arch}", arch, reduced=True, serve=serve,
                              seed=i, workload=w, slo=slo)
                   for i, (w, arch) in enumerate(MIXED_FLEET)]
        # no policy: the smoke pins scheduling behavior, not the DSE
        return ComposedServer(mesh, tenants, policy=None,
                              slo_preempt=paged)

    sched = arrival_schedule(
        "flash-crowd", [f"{w}-{arch}" for w, arch in MIXED_FLEET],
        requests, args.seed, max_new=mnew)

    def run(server: ComposedServer):
        rng = np.random.default_rng(args.seed)
        queue = [(a.step, a.tenant, a.prompt_len, a.max_new) for a in sched]
        steps = 0
        while queue or server.pending():
            while queue and queue[0][0] <= steps:
                _, name, plen, mn = queue.pop(0)
                vocab = server.cfgs[name].vocab_size
                server.submit(name, rng.integers(1, vocab, size=plen),
                              max_new_tokens=mn)
            server.step()
            steps += 1
            if steps > 4000:
                break
        server.drain(max_steps=1000)
        return server.results()

    paged_srv = build(True)
    res_paged = run(paged_srv)
    base_srv = build(False)
    res_base = run(base_srv)
    stats = paged_srv.stats()
    preemptions = sum(stats["preemptions"].values())
    att = paged_srv.slo_attainment()
    complete = all(
        len(units) == mnew
        for t, streams in res_paged.items()
        if paged_srv.classes[t] != "encoder"
        for units in streams.values())
    digest_paged = _streams_digest(res_paged)
    digest_base = _streams_digest(res_base)
    checks = {
        "preemptions": preemptions,
        "slo_preemptions": stats["slo_preemptions"],
        "complete": complete,
        "digest_match": digest_paged == digest_base,
        "attainment_tenants": sorted(att["tenants"]),
        "streams_digest": digest_paged,
    }
    ok = (preemptions >= 1 and complete and checks["digest_match"]
          and bool(att["tenants"]))
    print(json.dumps({**checks, "ok": ok}))
    if not ok:
        print("SLO smoke FAILED: flash-crowd did not preempt, or a "
              "preempted stream diverged / never completed (see checks)")
        return 1
    print("SLO smoke OK: flash-crowd preempted live streams and every "
          "request completed bit-identically to the unpreempted run")
    return 0


# ---------------------------------------------------------------------------
# dp bench: Stage-1-chosen replica tiling vs the same grant forced to dp=1
# ---------------------------------------------------------------------------

def run_dp_bench(args) -> int:
    """Steady-state decode tokens/s on one fixed grant, Stage-1-chosen
    design (which must pick ``dp > 1``) vs the same search with the tenant
    pinned to a single engine (``dp_cap=1``).

    The engine's step program is batch-capped (``slot_cap``), so the
    single-engine arm can shard its (small, weights-bound) batch over the
    whole grant but never widen it — while the replica-tiled arm decodes
    ``dp`` independent capped batches concurrently.  The measured gap is
    the serving counterpart of the paper's reconfigurable-tiling win.

    Both arms are built up front and their timed loops interleave
    (A,B,A,B,...) with best-of per arm, so slow drift in host load hits
    both the same way instead of whichever arm happens to run last."""
    if jax.device_count() < 4:
        print("dp-bench needs >= 4 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return 2
    # deep-narrow at a long context: per-sublayer compute is tiny next to
    # the 2(p-1) collective phases a tp=4 step pays, while the long padded
    # KV read keeps tp=4 the best *single-engine* design — exactly the
    # regime where the grant only buys throughput as replicas.  Fixed
    # max_len (not --max-len): the regime is the benchmark.
    cfg = bench_config(512, 6, 4096)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    comp = MeshComposer(mesh)
    grant, queue, M, reps = 4, 16, args.scale_steps, 3
    sc = ServeConfig(max_slots=4, max_len=4096, eos_id=-1, slot_cap=4)
    pol = AnalyticalPolicy()

    def arm(dp_cap):
        space = TenantDesignSpace(wclass=DECODE, max_len=sc.max_len,
                                  base_slots=sc.max_slots,
                                  slot_cap=sc.slot_cap, dp_cap=dp_cap)
        best = pol.stage1.best(cfg, space, queue, grant)
        grp = ReplicaGroup(DECODE, model, params, sc,
                           sub=comp.submesh(range(grant), f"dpb{dp_cap}"),
                           rules=serve_engine_rules())
        grp.apply(None, best)
        rng = np.random.default_rng(args.seed)
        for _ in range(queue):
            grp.submit(rng.integers(1, cfg.vocab_size, size=16),
                       max_new_tokens=reps * M + 8)
        for _ in range(3):                  # prefill + warm the executables
            grp.step()
        grp.sync()
        return best, grp

    chosen, grp_dp = arm(dp_cap=64)
    forced, grp_one = arm(dp_cap=1)
    toks_dp = toks_one = 0.0
    for _ in range(reps):
        for grp, which in ((grp_dp, "dp"), (grp_one, "one")):
            n, t0 = 0, time.perf_counter()
            for _ in range(M):
                n += len(grp.step())
            grp.sync()
            tput = round(n / (time.perf_counter() - t0), 2)
            if which == "dp":
                toks_dp = max(toks_dp, tput)
            else:
                toks_one = max(toks_one, tput)
    ok = (chosen.dp or 1) > 1 and (forced.dp or 1) == 1 \
        and toks_dp > toks_one
    print(json.dumps({
        "bench_model": cfg.name, "grant_cus": grant, "queue": queue,
        "measured_steps": M, "timed_reps": reps, "slot_cap": sc.slot_cap,
        "chosen": {"dp": chosen.dp, "tp": chosen.tp, "slots": chosen.slots},
        "forced": {"dp": forced.dp, "tp": forced.tp, "slots": forced.slots},
        "tokens_per_s_dp": toks_dp, "tokens_per_s_dp1": toks_one,
        "speedup": round(toks_dp / max(toks_one, 1e-9), 3), "ok": ok,
    }))
    if not ok:
        print("dp bench FAILED: Stage 1 did not pick dp > 1, or replica "
              "tiling did not beat the single-engine arm")
        return 1
    print("dp bench OK: Stage-1-chosen replica tiling beats dp=1")
    return 0


# ---------------------------------------------------------------------------
# TP smoke: sharded decode must emit the replicated stream
# ---------------------------------------------------------------------------

def run_tp_smoke(args) -> int:
    """2-way TP vs replicated 1-way: same prompts, identical token streams,
    including across a mid-stream reshard that changes the TP degree.  Fast
    CI guard against sharded decode silently regressing to replication or
    diverging from it."""
    if jax.device_count() < 2:
        print("tp-smoke needs >= 2 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return 2
    cfg = dataclasses.replace(get_reduced("minitron-4b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    comp = MeshComposer(mesh)
    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 12)))
               for _ in range(3)]

    def run(tp, rules, reshard_at=None):
        eng = ServeEngine(model, params, sc,
                          mesh=comp.submesh(range(tp), f"tp{tp}"),
                          rules=rules)
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        step = 0
        while eng.has_work:
            if reshard_at and step in reshard_at:
                eng.reshard_to(comp.submesh(range(reshard_at[step]), "re"))
            eng.step()
            step += 1
            assert step < 200
        return eng.results()

    ref = run(1, None)                                 # replicated baseline
    tp2 = run(2, serve_engine_rules())
    dyn = run(2, serve_engine_rules(), reshard_at={4: 1, 8: 2})
    ok = ref == tp2 == dyn
    print(json.dumps({"match_tp2": tp2 == ref, "match_dyn": dyn == ref,
                      "requests": len(ref), "ok": ok}))
    if not ok:
        print("TP smoke FAILED: sharded decode diverged from replicated")
        return 1
    print("TP smoke OK: 2-way TP and mid-stream reshard match replicated")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, action="append",
                    help="repeat for multiple tenants with --fabric")
    ap.add_argument("--fabric", action="store_true",
                    help="multi-tenant ComposedServer with live recomposition")
    ap.add_argument("--scenario",
                    choices=["bursty", "mixed", "diurnal", "flash-crowd",
                             "heavy-tail"],
                    default="bursty",
                    help="fabric traffic: 'bursty' serves the --arch tenants; "
                         "'mixed' serves one tenant per workload class "
                         "(transformer decode + mamba SSM + encoder + "
                         "seamless enc-dec); 'diurnal'/'flash-crowd'/"
                         "'heavy-tail' serve the mixed fleet under the "
                         "seeded open-loop generator (repro.serve.traffic) "
                         "with SLO targets attached")
    ap.add_argument("--decide-every", type=int, default=4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-tp", action="store_true",
                    help="replicated engines (no tensor parallelism)")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip pre-compiling recomposition targets")
    ap.add_argument("--prewarm-async", action="store_true",
                    help="compile recomposition targets in a background "
                         "thread while serving continues")
    ap.add_argument("--scaling-curve", action="store_true",
                    help="measure decode tokens/s at each --scale-sizes "
                         "sub-mesh size")
    ap.add_argument("--scale-sizes", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--scale-steps", type=int, default=10)
    ap.add_argument("--scale-slots-per-cu", type=int, default=4,
                    help="decode slots per granted CU (capacity scales "
                         "with the composition)")
    ap.add_argument("--scale-dmodel", type=int, default=2048)
    ap.add_argument("--scale-layers", type=int, default=4)
    ap.add_argument("--scale-dff", type=int, default=8192)
    ap.add_argument("--tp-smoke", action="store_true",
                    help="assert 2-way TP decode matches replicated decode")
    ap.add_argument("--split-only", action="store_true",
                    help="disable the serving DSE's Stage 1: the policy "
                         "searches raw CU splits (the pre-two-stage "
                         "behavior; the two_stage_dse benchmark ablation)")
    ap.add_argument("--dse-smoke", action="store_true",
                    help="assert the two-stage policy picks and applies a "
                         "non-default per-tenant design point (dp > 1 for "
                         "the batch-capped small-model tenant)")
    ap.add_argument("--dp-bench", action="store_true",
                    help="measure Stage-1-chosen replica tiling (dp > 1) vs "
                         "the same grant forced to one engine (dp_cap=1)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the fabric's metrics registry and span "
                         "tracer (token streams are identical either way)")
    ap.add_argument("--metrics-json", metavar="PATH",
                    help="write the merged metrics-registry snapshot as "
                         "JSON after the run")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="write the span ring buffer as Chrome/Perfetto "
                         "trace-event JSON after the run")
    ap.add_argument("--log-every", type=int, default=200, metavar="N",
                    help="print a one-line telemetry summary to stderr "
                         "every N fabric steps (0 disables)")
    ap.add_argument("--obs-smoke", action="store_true",
                    help="assert the telemetry pipeline traces a mixed-"
                         "fleet run end to end (spans + per-class "
                         "decode-step histograms)")
    ap.add_argument("--kv-frac", type=float, default=1.0,
                    help="paged-KV arena size as a fraction of the worst-"
                         "case slot reservation (< 1 oversubscribes: page "
                         "exhaustion during growth triggers preemption)")
    ap.add_argument("--kv-page-rows", type=int, default=16,
                    help="token rows per KV page (ServeConfig.kv_page_rows)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable the fabric's SLO-preemption lever while "
                         "keeping attainment reporting (the slot-granular "
                         "benchmark baseline arm)")
    ap.add_argument("--slo-ttft-p50-ms", type=float, default=150.0,
                    help="TTFT p50 target for traffic-scenario tenants")
    ap.add_argument("--slo-ttft-p99-ms", type=float, default=400.0,
                    help="TTFT p99 target for traffic-scenario tenants")
    ap.add_argument("--slo-per-token-p99-ms", type=float, default=0.0,
                    help="per-token p99 target for traffic-scenario "
                         "tenants (0 = untracked)")
    ap.add_argument("--slo-tenant", default="", metavar="SUBSTR",
                    help="apply SLO targets only to tenants whose name "
                         "contains SUBSTR (empty = every tenant); scopes "
                         "both the scheduler and the attainment report to "
                         "the tenant under test")
    ap.add_argument("--slo-smoke", action="store_true",
                    help="assert a flash-crowd on an oversubscribed paged "
                         "arena preempts at least one stream and every "
                         "request completes bit-identically to the "
                         "slot-granular run")
    args = ap.parse_args(argv)

    if args.tp_smoke:
        return run_tp_smoke(args)
    if args.obs_smoke:
        return run_obs_smoke(args)
    if args.slo_smoke:
        return run_slo_smoke(args)
    if args.dse_smoke:
        return run_dse_smoke(args)
    if args.dp_bench:
        return run_dp_bench(args)
    if args.scaling_curve:
        return run_scaling(args)
    if args.scenario == "mixed" or args.scenario in TRAFFIC_SCENARIOS:
        if not args.fabric:
            ap.error(f"--scenario {args.scenario} requires --fabric")
        if args.arch:
            ap.error(f"--scenario {args.scenario} picks its own per-class "
                     "fleet; drop --arch")
        return run_fabric(args)
    if not args.arch:
        ap.error("--arch is required (except with "
                 "--tp-smoke/--scaling-curve/--fabric --scenario mixed)")
    if args.fabric:
        return run_fabric(args)
    if len(args.arch) != 1:
        ap.error("multiple --arch requires --fabric")
    args.arch = args.arch[0]

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    mesh = rules = None
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = None if args.no_tp else serve_engine_rules()

    engine = ServeEngine(model, params,
                         ServeConfig(max_slots=args.max_slots,
                                     max_len=args.max_len, eos_id=-1),
                         mesh=mesh, rules=rules)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    rids = []
    for _ in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, size=plen)
        rids.append(engine.submit(prompt, max_new_tokens=args.max_new_tokens))
    steps = 0
    emitted = 0
    step_ms = []
    while engine.has_work:
        s0 = time.perf_counter()
        emitted += len(engine.step())
        step_ms.append((time.perf_counter() - s0) * 1e3)
        steps += 1
        if steps > 10_000:
            break
    dt = time.monotonic() - t0
    arr = np.asarray(step_ms)
    print(json.dumps({
        "requests": args.requests, "decode_steps": steps,
        "tokens_emitted": emitted, "wall_s": round(dt, 2),
        "tokens_per_s": round(emitted / dt, 1),
        "step_ms": {"p50": round(float(np.percentile(arr, 50)), 2),
                    "p95": round(float(np.percentile(arr, 95)), 2)},
        "arena_utilization": engine.arena.utilization(),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
