"""Two-stage DSE driver (paper §3.1, Fig. 6).

Stage 1 (Runtime Parameter Optimizer): brute-force per-layer runtime
parameters under FMU/CU constraints -> mode tables (repro.core.modes).
Stage 2 (Schedule Optimizer): resource-constrained DAG scheduling over the
mode tables — exact MILP-equivalent branch-and-bound for small task sets,
the GA heuristic for large ones (``solver='auto'`` switches on problem
size, reproducing the paper's guidance in §4.4).

The result carries the ExecutionPlan consumed by the code generator
(instruction streams) and, on the TPU side, by the mesh composer.

The *serving-side* incarnation of the same two-stage split lives in
``repro.serve.dse``: there Stage 1 optimizes one tenant engine's runtime
parameters (TP degree, slot count, bucket ladder) per candidate CU grant
with the analytical model, and Stage 2 is the recomposition policy's split
search over those Stage-1-optimal :class:`DesignPoint` memos.  The
``DesignPoint`` record is defined here because it is the shared currency
between the two stages — the offline driver's mode tables play the same
role for the schedule optimizer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

from repro.common.platform import PlatformProfile, VCK190
from repro.configs.paper_workloads import MMWorkload
from repro.core import modes as modes_lib
from repro.core.analytical import AccelConfig
from repro.core.ga import GAConfig, GAResult, solve_ga
from repro.core.milp import Result as MILPResult
from repro.core.milp import solve_exact
from repro.core.schedule import Schedule, ScheduleProblem, validate

AUTO_EXACT_MAX_NODES = 12        # |layers| x |modes| budget for exact solver
AUTO_EXACT_MAX_MODES = 8


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One workload's optimized runtime configuration on a ``cus``-CU
    sub-accelerator — Stage 1's output, Stage 2's search atom.

    On the serving fabric the knobs are the tenant engine's runtime
    parameters; ``None`` means "keep the engine's current setting" (used
    by the split-only policy mode, which optimizes nothing per tenant):

    * ``tp``      — tensor-parallel degree over the sub-mesh (<= cus; the
      analytical all-reduce cost can make ``tp < cus`` optimal);
    * ``dp``      — data-parallel replica count inside the grant: the grant
      is tiled into ``dp`` disjoint ``tp``-wide slices, each running an
      independent engine replica (Herald-style configuration tiling; the
      serving fabric's ``ReplicaGroup`` owns the replicas);
    * ``slots``   — concurrent decode/SSM slots **per replica** (batch per
      step, priced via ``batch`` in the analytical step cost,
      memory-feasibility-bounded by one replica slice's HBM);
    * ``buckets`` — padded-length program ladder for encode phases
      (encoder / enc-dec tenants), chosen from observed job lengths.

    ``cost`` is the predicted seconds per unit of owed work (decode step /
    prompt token) at this design point — what Stage 2's makespan minimizes.
    """

    cus: int
    tp: Optional[int] = None
    slots: Optional[int] = None
    buckets: Optional[Tuple[int, ...]] = None
    dp: Optional[int] = None
    cost: float = 0.0

    def knobs(self) -> dict:
        """The non-default engine knobs this point pins (for telemetry)."""
        out = {}
        if self.tp is not None:
            out["tp"] = self.tp
        if self.dp is not None:
            out["dp"] = self.dp
        if self.slots is not None:
            out["slots"] = self.slots
        if self.buckets is not None:
            out["buckets"] = list(self.buckets)
        return out


def tp_candidates(cus: int) -> Tuple[int, ...]:
    """Candidate tensor-parallel degrees on a ``cus``-CU grant: powers of
    two up to the grant, plus the grant itself (the full-mesh default)."""
    if cus <= 0:
        return ()
    out = []
    p = 1
    while p < cus:
        out.append(p)
        p *= 2
    out.append(cus)
    return tuple(out)


def dp_candidates(cus: int, tp: int) -> Tuple[int, ...]:
    """Candidate data-parallel replica counts for ``tp``-wide replicas on a
    ``cus``-CU grant: powers of two plus the maximum packing, subject to
    ``tp * dp <= cus`` (replica slices are disjoint)."""
    if cus <= 0 or tp <= 0 or tp > cus:
        return ()
    cap = cus // tp
    out = []
    p = 1
    while p < cap:
        out.append(p)
        p *= 2
    out.append(cap)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class PlannedLayer:
    layer: int
    name: str
    mkn: Tuple[int, int, int]
    mode_fmus: int
    mode_cus: int
    tile: Tuple[int, int, int]
    start: float
    end: float
    fmu_ids: Tuple[int, ...]
    cu_ids: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    workload: str
    layers: Tuple[PlannedLayer, ...]
    makespan: float

    def throughput_flops(self, total_flops: float) -> float:
        return total_flops / self.makespan if self.makespan else 0.0

    def time_slots(self) -> List[Tuple[float, List[PlannedLayer]]]:
        """Group layers by start time — concurrent groups run on disjoint
        CU sets (the composed-accelerator view)."""
        slots = {}
        for pl in self.layers:
            slots.setdefault(pl.start, []).append(pl)
        return sorted(slots.items())


@dataclasses.dataclass
class DSEResult:
    plan: ExecutionPlan
    schedule: Schedule
    problem: ScheduleProblem
    solver: str
    stage1_s: float
    stage2_s: float
    makespan: float
    optimal: bool


def _plan_from_schedule(workload: MMWorkload, problem: ScheduleProblem,
                        schedule: Schedule) -> ExecutionPlan:
    planned = []
    for p in sorted(schedule.placements, key=lambda q: (q.start, q.layer)):
        layer = workload.layers[p.layer]
        mode = problem.modes[p.layer][p.mode_idx]
        tile = tuple(mode.meta) if mode.meta else (layer.m, layer.k, layer.n)
        planned.append(PlannedLayer(
            layer=p.layer, name=layer.name, mkn=(layer.m, layer.k, layer.n),
            mode_fmus=mode.fmus, mode_cus=mode.cus, tile=tile,
            start=p.start, end=p.end, fmu_ids=p.fmu_ids, cu_ids=p.cu_ids))
    return ExecutionPlan(workload.name, tuple(planned), schedule.makespan)


def run_dse(workload: MMWorkload, accel: AccelConfig,
            platform: PlatformProfile = VCK190, *,
            f_max: Optional[int] = None, c_max: Optional[int] = None,
            solver: str = "auto", max_modes: int = 16,
            exact_time_limit_s: float = 60.0,
            ga_config: Optional[GAConfig] = None) -> DSEResult:
    f_max = f_max if f_max is not None else accel.num_fmus
    c_max = c_max if c_max is not None else accel.num_cus

    t0 = time.monotonic()
    problem = modes_lib.build_problem(workload, accel, platform,
                                      f_max=f_max, c_max=c_max,
                                      max_modes=max_modes)
    stage1_s = time.monotonic() - t0

    if solver == "auto":
        big = (problem.num_layers > AUTO_EXACT_MAX_NODES or
               max(len(m) for m in problem.modes) > AUTO_EXACT_MAX_MODES)
        solver = "ga" if big else "milp"

    t1 = time.monotonic()
    if solver == "milp":
        ga_seed = solve_ga(problem, ga_config or GAConfig(generations=40))
        res: MILPResult = solve_exact(problem,
                                      time_limit_s=exact_time_limit_s,
                                      incumbent=ga_seed.schedule)
        schedule, optimal = res.schedule, res.optimal
    elif solver == "ga":
        ga = solve_ga(problem, ga_config or GAConfig())
        schedule, optimal = ga.schedule, False
    else:
        raise ValueError(solver)
    stage2_s = time.monotonic() - t1

    assert schedule is not None
    validate(problem, schedule)
    plan = _plan_from_schedule(workload, problem, schedule)
    return DSEResult(plan=plan, schedule=schedule, problem=problem,
                     solver=solver, stage1_s=stage1_s, stage2_s=stage2_s,
                     makespan=schedule.makespan, optimal=optimal)
