"""End-to-end behaviour tests for the paper's system: the complete FILCO
flow (workload -> two-stage DSE -> Table-1 instruction streams -> functional
data-plane execution) reproducing reference numerics, and the framework flow
(config -> train steps -> checkpoint -> serve) on a reduced architecture."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.platform import VCK190
from repro.configs import get_reduced
from repro.configs.paper_workloads import bert
from repro.core.analytical import (best_accel_latency, filco_vck190,
                                   rsn_overlay)
from repro.core.codegen import generate
from repro.core.dse import run_dse
from repro.core.ga import GAConfig
from repro.core.simulator import DataPlaneSim
from repro.data import make_pipeline
from repro.distribution import strip
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine
from repro.train import TrainConfig, Trainer


def test_filco_flow_end_to_end():
    """Paper Fig. 6: model -> DSE -> codegen -> executable data plane."""
    wl = bert(32, layers=1)
    accel = filco_vck190()
    res = run_dse(wl, accel, solver="ga", max_modes=4,
                  ga_config=GAConfig(population=16, generations=15, seed=0))
    # the DSE-optimized point beats naive sequential RSN routing
    seq_rsn = sum(best_accel_latency(rsn_overlay(), VCK190, l.m, l.k, l.n
                                     ).total_s for l in wl.layers)
    assert res.makespan < seq_rsn
    prog = generate(wl, res.plan)
    fmu_cap = max(max(l.m * l.k, l.k * l.n, l.m * l.n) for l in wl.layers)
    sim = DataPlaneSim(prog.layout.total_elems, accel.num_fmus, fmu_cap,
                       accel.num_cus)
    rng = np.random.default_rng(0)
    first = wl.layers[0]
    x0 = rng.normal(size=(first.m, first.k)).astype(np.float32)
    sim.ddr[prog.layout.input_addr:
            prog.layout.input_addr + x0.size] = x0.reshape(-1)
    for i, l in enumerate(wl.layers):
        w = (rng.normal(size=(l.k, l.n)) / np.sqrt(l.k)).astype(np.float32)
        sim.ddr[prog.layout.weight_addr[i]:
                prog.layout.weight_addr[i] + w.size] = w.reshape(-1)
    sim.run(prog)  # must complete without deadlock; numerics covered in
    #                tests/test_codegen_sim.py


def test_framework_flow_train_checkpoint_serve():
    """Train a reduced arch, checkpoint, restore, serve — one lifecycle."""
    cfg = get_reduced("minitron-4b")
    model = build_model(cfg)
    pipe = make_pipeline(cfg, seq_len=32, global_batch=4)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(model, TrainConfig(steps=6, lr=1e-3, warmup=2,
                                        checkpoint_every=6, ckpt_dir=d,
                                        log_every=2),
                     mesh=None, pipeline=pipe)
        out = tr.fit()
        assert out["status"] == "completed"
        losses = [m["loss"] for m in out["metrics"]]
        assert losses[-1] < losses[0]
        # restore into a fresh trainer, serve with the trained params
        tr2 = Trainer(model, TrainConfig(steps=6, ckpt_dir=d), mesh=None,
                      pipeline=pipe)
        params, _, step = tr2.restore_or_init()
        assert step == 6
    eng = ServeEngine(model, params, ServeConfig(max_slots=2, max_len=48,
                                                 eos_id=-1))
    eng.submit(np.arange(1, 9), max_new_tokens=4)
    for _ in range(10):
        if not eng._queue and not eng._active:
            break
        eng.step()
    assert not eng._active
