"""SSM (mamba) serving engine: recurrent decode from a constant-size state
slot pool.

The workload-class contrast that makes heterogeneous composition worthwhile
(FILCO §1; Herald/COAC): a transformer decode tenant's per-slot cost grows
with sequence length (KV reads) and its admission is length-budgeted, while
a mamba tenant carries **O(1) state per slot** — a conv window plus the
(d_inner, N) recurrent state per layer — so:

* admission is slot-bound, never length-bound: any prompt length and any
  generation budget occupy exactly one constant-size state slot
  (``mamba_prefill`` folds the whole prompt into the state);
* per-token decode cost is flat in sequence length and bound by *state +
  parameter bandwidth*, not by a growing KV stream — which is why the
  class-aware policy prices SSM steps with a state-bandwidth model instead
  of the decode-GEMV model;
* the whole device state (params + pooled conv/h states) reshards in one
  ``device_put``, exactly like the transformer engine, with TP over the
  sub-mesh's model axis via the same ``ShardingPlan`` machinery
  (``ssm_inner`` shards; token streams are invariant across TP degree and
  live recomposition — pinned in tests/test_workloads.py).

Implementation: the continuous-batching machinery (slots, pipelined decode
dispatch, AOT executables, resharding) is the shared engine substrate from
:mod:`repro.workloads.decode`; this class swaps the admission accounting for
the constant-size state pool.  ``Model.prefill``/``Model.decode_step`` on an
attention-free config bottom out in ``mamba_prefill``/``mamba_step`` per
layer, and the engine prefills at the exact prompt length (padding would
corrupt recurrent state).

With ``ServeConfig.use_kernels`` on (the default), each decode step runs the
fused single-step scan (``repro.kernels.mamba_scan.mamba_step_fused``) —
the whole in_proj→conv→SSM→out_proj chain in one kernel per slot row
instead of a dozen XLA dispatches.  There is no KV bound to specialize on
(state is O(1)), so the base engine's ``_decode_bounds()`` is () and the
``use_kernels`` flag alone distinguishes the compiled decode program.
"""
from __future__ import annotations

from typing import Optional

from repro.distribution import partitioning as part
from repro.models import ssm as S
from repro.models.model import Model
from repro.obs import Telemetry
from repro.workloads.compile_cache import ExecutableCache
from repro.workloads.decode import DecodeEngine, Request, ServeConfig


class SSMEngine(DecodeEngine):
    workload_class = "ssm"

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 mesh=None, rules: Optional[part.ShardingRules] = None,
                 exec_cache: Optional[ExecutableCache] = None,
                 obs: Optional[Telemetry] = None):
        mc = model.cfg
        if mc.ssm is None or not mc.attention_free:
            raise ValueError(
                f"SSMEngine serves attention-free SSM archs; {mc.name!r} is "
                f"family={mc.family!r} (use DecodeEngine for archs with a "
                "KV cache, including hybrids)")
        super().__init__(model, params, cfg, mesh=mesh, rules=rules,
                         exec_cache=exec_cache, obs=obs)

    # ------------------------------------------------------------------
    # constant-size state pool: admission accounting hooks
    # ------------------------------------------------------------------
    def _per_token_cache_elems(self) -> int:
        """Per-SLOT (not per-token) recurrent-state elements: conv window +
        (d_inner, N) hidden state, per layer.  Named for the hook it fills;
        ``_slot_rows`` is 1, so arena views are (1, state_elems)."""
        return S.state_elems(self.model.cfg) * self.model.cfg.num_layers

    def _arena_capacity(self) -> int:
        # one constant-size state slot per decode slot — max_len plays no
        # part: SSM state does not grow with the sequence
        return self.cfg.max_slots * self._per_token_elems

    def _slot_rows(self, req: Request) -> int:
        return 1

    def _row_cap(self) -> int:
        # O(1) state: one arena row per slot, so under paging every page is
        # a single constant-size state unit and _live_rows never grows —
        # SSM tenants get preemption (the state block exports like any
        # slot) but no page-growth pressure
        return 1

    def _oversized(self, req: Request) -> bool:
        # O(1) state: no prompt length or generation budget can overflow a
        # slot.  Backpressure is purely slot availability.
        return False
