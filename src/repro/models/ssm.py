"""Mamba-1 selective SSM block (arXiv:2312.00752), JAX-native.

The selective scan is computed *chunked*: a lax.scan over sequence chunks
carrying the (B, d_inner, N) state, with an associative scan inside each
chunk.  This never materializes the (B, S, d_inner, N) state expansion over
the full sequence — the TPU analogue of Mamba's "hardware-aware" kernel
(DESIGN.md §6) — and is exactly the algorithm the Pallas kernel in
``repro.kernels.mamba_scan`` implements in VMEM.

Decode is O(1): one conv-window shift + one state update per token.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.partitioning import Annotated
from repro.kernels.mamba_scan import mamba_step_fused
from repro.models import layers as L


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.d_inner or s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, s.state_dim, s.conv_width


def state_elems(cfg: ModelConfig) -> int:
    """Per-slot recurrent-state elements of ONE mamba block: the conv window
    plus the (d_inner, N) hidden state.  Constant in sequence length — the
    reason SSM serving admits by slot count, not by prompt length."""
    d_in, _, n, w = dims(cfg)
    return (w - 1) * d_in + d_in * n


def mamba_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    d_in, dt_rank, n, w = dims(cfg)
    ks = jax.random.split(rng, 8)
    # dt_proj init per Mamba reference: bias s.t. softplus(bias) in [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[0], (d_in,)) *
                 (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))       # inverse softplus
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))
    return {
        "in_proj": L.dense_init(ks[1], d, 2 * d_in, ("embed", "ssm_inner")),
        "conv_w": Annotated(
            jax.random.normal(ks[2], (w, d_in)) / math.sqrt(w),
            ("conv_w", "ssm_inner")),
        "conv_b": L.bias_init(d_in, ("ssm_inner",)),
        "x_proj": L.dense_init(ks[3], d_in, dt_rank + 2 * n, ("ssm_inner", None)),
        "dt_proj": L.dense_init(ks[4], dt_rank, d_in, (None, "ssm_inner"),
                                std=dt_rank ** -0.5),
        "dt_bias": Annotated(dt_bias, ("ssm_inner",)),
        "A_log": Annotated(jnp.log(a), ("ssm_inner", "state")),
        "D": Annotated(jnp.ones((d_in,)), ("ssm_inner",)),
        "out_proj": L.dense_init(ks[5], d_in, d, ("ssm_inner", "embed")),
    }


# ---------------------------------------------------------------------------
# chunked selective scan
# ---------------------------------------------------------------------------

def selective_scan(deltaA, deltaBx, h0, chunk: int = 128):
    """h_t = deltaA_t * h_{t-1} + deltaBx_t, returns (h_all, h_last).

    deltaA, deltaBx: (B, S, D, N); h0: (B, D, N).
    lax.scan over ceil(S/chunk) chunks; associative scan within a chunk.
    """
    B, S, D, N = deltaA.shape
    nchunk = -(-S // chunk)
    pad = nchunk * chunk - S
    if pad:
        deltaA = jnp.pad(deltaA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                         constant_values=1.0)
        deltaBx = jnp.pad(deltaBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dA = deltaA.reshape(B, nchunk, chunk, D, N).transpose(1, 0, 2, 3, 4)
    dBx = deltaBx.reshape(B, nchunk, chunk, D, N).transpose(1, 0, 2, 3, 4)

    def combine(a, b):
        # composition of affine maps h -> a1*h + b1 then h -> a2*h + b2
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    def body(h, xs):
        da, dbx = xs                                   # (B, chunk, D, N)
        pa, ph = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = ph + pa * h[:, None]                   # inject carry
        return h_all[:, -1], h_all

    # checkpoint the chunk step: the backward recomputes the in-chunk
    # associative scan instead of saving every scan level — only chunk-
    # boundary states persist (the Mamba hardware-aware-scan property).
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h_last, h_chunks = jax.lax.scan(body, h0, (dA, dBx))
    h_all = h_chunks.transpose(1, 0, 2, 3, 4).reshape(B, nchunk * chunk, D, N)
    return h_all[:, :S], h_last


# ---------------------------------------------------------------------------
# fused selective scan with recompute backward (hillclimb variant;
# EXPERIMENTS.md §Perf).  Boundary: (x, dt, b, c) -> y.  The forward computes
# per-chunk discretization + scan + C-projection without ever writing the
# (B, S, D, N) state expansion to HBM; the backward saves only chunk-boundary
# states and recomputes within-chunk states — the Mamba hardware-aware-kernel
# contract, here in jnp so the dry-run prices it.
# ---------------------------------------------------------------------------

def _affine_combine(a, b):
    a1, b1 = a
    a2, b2 = b
    return a1 * a2, b1 * a2 + b2


def _chunk_states(da, dbx, h0):
    """Within-chunk states via associative scan. Returns (h_all, h_last)."""
    pa, ph = jax.lax.associative_scan(_affine_combine, (da, dbx), axis=1)
    h_all = ph + pa * h0[:, None]
    return h_all, h_all[:, -1]


def _fused_fwd_pass(x, dt, b, c, A, d_vec, chunk):
    """Returns (y, boundary states (nchunk, B, D, N)).  Scan math runs in
    fp32 (the kernel's VMEM accumulator dtype); I/O stays in x.dtype."""
    B, S, D = x.shape
    N = b.shape[-1]
    nchunk = S // chunk
    f32 = jnp.float32
    xc = x.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3)
    bc = b.reshape(B, nchunk, chunk, N).transpose(1, 0, 2, 3)
    cc = c.reshape(B, nchunk, chunk, N).transpose(1, 0, 2, 3)
    A32 = A.astype(f32)

    def body(h, xs):
        xk, dtk, bk, ck = xs
        xk32, dtk32 = xk.astype(f32), dtk.astype(f32)
        da = jnp.exp(dtk32[..., None] * A32)
        dbx = (dtk32 * xk32)[..., None] * bk.astype(f32)[:, :, None, :]
        h_all, h_last = _chunk_states(da, dbx, h)
        yk = jnp.einsum("bsdn,bsn->bsd", h_all, ck.astype(f32)) \
            + d_vec.astype(f32) * xk32
        return h_last, (yk.astype(x.dtype), h)

    h0 = (dt[:, 0].astype(f32)[:, :, None] * A32) * 0.0   # sharded zeros
    _, (yc, bounds) = jax.lax.scan(body, h0, (xc, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3).reshape(B, S, D)
    return y, bounds                                 # (nchunk, B, D, N)


def _serial_fwd_pass(x, dt, b, c, A, d_vec, chunk):
    """Fully serial scan with the C-projection folded into each step: the
    only HBM traffic is streaming (x, dt, b, c) once and writing y — the
    Pallas ``mamba_scan`` kernel's traffic contract, expressed in jnp so the
    dry-run prices the kernel-equivalent implementation.  Chunk boundaries
    are still saved for the recompute backward."""
    B, S, D = x.shape
    N = b.shape[-1]
    nchunk = S // chunk
    f32 = jnp.float32
    A32 = A.astype(f32)
    # time-leading layouts for the inner scans
    xc = x.reshape(B, nchunk, chunk, D).transpose(1, 2, 0, 3)
    dtc = dt.reshape(B, nchunk, chunk, D).transpose(1, 2, 0, 3)
    bc = b.reshape(B, nchunk, chunk, N).transpose(1, 2, 0, 3)
    cc = c.reshape(B, nchunk, chunk, N).transpose(1, 2, 0, 3)

    def step(h, xs):
        x_t, dt_t, b_t, c_t = xs                       # (B,D),(B,D),(B,N)x2
        x32, dt32 = x_t.astype(f32), dt_t.astype(f32)
        da = jnp.exp(dt32[:, :, None] * A32)           # (B,D,N)
        h = da * h + (dt32 * x32)[:, :, None] * b_t.astype(f32)[:, None, :]
        y_t = jnp.sum(h * c_t.astype(f32)[:, None, :], axis=-1) \
            + d_vec.astype(f32) * x32
        return h, y_t.astype(x.dtype)

    def chunk_body(h, xs):
        xk, dtk, bk, ck = xs                           # (chunk,B,·)
        h_last, yk = jax.lax.scan(step, h, (xk, dtk, bk, ck))
        return h_last, (yk, h)

    # derive the zero carry from sharded operands: a plain jnp.zeros carry is
    # replicated and drags the whole while-loop body to unsharded d_inner
    # (16x redundant state math) — EXPERIMENTS.md §Perf falcon iter 3.
    h0 = (dt[:, 0].astype(f32)[:, :, None] * A32) * 0.0
    _, (yc, bounds) = jax.lax.scan(chunk_body, h0, (xc, dtc, bc, cc))
    y = yc.transpose(2, 0, 1, 3).reshape(B, S, D)
    return y, bounds                                   # (nchunk, B, D, N)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def fused_selective_scan(x, dt, b, c, A, d_vec, chunk=128, serial=False):
    fwd = _serial_fwd_pass if serial else _fused_fwd_pass
    y, _ = fwd(x, dt, b, c, A, d_vec, chunk)
    return y


def _fss_fwd(x, dt, b, c, A, d_vec, chunk, serial):
    fwd = _serial_fwd_pass if serial else _fused_fwd_pass
    y, bounds = fwd(x, dt, b, c, A, d_vec, chunk)
    return y, (x, dt, b, c, A, d_vec, bounds)


def _fss_bwd_serial(chunk, res, gy):
    """Serial recompute backward: per chunk, re-run the forward serially
    (storing one chunk of states transiently), then a serial reverse sweep
    for the gradients — kernel-equivalent HBM traffic."""
    x, dt, b, c, A, d_vec, bounds = res
    B, S, D = x.shape
    N = b.shape[-1]
    nchunk = S // chunk
    f32 = jnp.float32
    A32 = A.astype(f32)
    d32 = d_vec.astype(f32)
    xc = x.reshape(B, nchunk, chunk, D).transpose(1, 2, 0, 3).astype(f32)
    dtc = dt.reshape(B, nchunk, chunk, D).transpose(1, 2, 0, 3).astype(f32)
    bc = b.reshape(B, nchunk, chunk, N).transpose(1, 2, 0, 3).astype(f32)
    cc = c.reshape(B, nchunk, chunk, N).transpose(1, 2, 0, 3).astype(f32)
    gyc = gy.reshape(B, nchunk, chunk, D).transpose(1, 2, 0, 3).astype(f32)
    bnd = bounds.astype(f32)                          # (nchunk, B, D, N)

    def fstep(h, xs):
        x_t, dt_t, b_t = xs
        da = jnp.exp(dt_t[:, :, None] * A32)
        h_new = da * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        return h_new, h                                # ys = h_{t-1}

    def bstep(carry, xs):
        g_in, dA_acc, dd_acc = carry
        x_t, dt_t, b_t, c_t, gy_t, h_prev = xs
        da = jnp.exp(dt_t[:, :, None] * A32)
        h_t = da * h_prev + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        g_t = gy_t[:, :, None] * c_t[:, None, :] + g_in
        dda = g_t * h_prev
        ddt = jnp.sum(dda * (A32 * da), axis=-1) \
            + jnp.sum(g_t * b_t[:, None, :], axis=-1) * x_t
        dx = jnp.sum(g_t * b_t[:, None, :], axis=-1) * dt_t + d32 * gy_t
        db = jnp.sum(g_t * (dt_t * x_t)[:, :, None], axis=1)
        dc = jnp.sum(gy_t[:, :, None] * h_t, axis=1)
        dA_acc = dA_acc + jnp.sum(dda * dt_t[:, :, None] * da, axis=0)
        dd_acc = dd_acc + jnp.sum(gy_t * x_t, axis=0)
        return (da * g_t, dA_acc, dd_acc), (dx, ddt, db, dc)

    def chunk_body(carry, xs):
        g_in, dA_acc, dd_acc = carry
        xk, dtk, bk, ck, gk, h0 = xs
        _, h_prevs = jax.lax.scan(fstep, h0, (xk, dtk, bk))  # (chunk,B,D,N)
        (g_out, dA_acc, dd_acc), grads = jax.lax.scan(
            bstep, (g_in, dA_acc, dd_acc),
            (xk, dtk, bk, ck, gk, h_prevs), reverse=True)
        return (g_out, dA_acc, dd_acc), grads

    g0 = (dtc[0, 0][:, :, None] * A32) * 0.0              # sharded zeros
    carry0 = (g0, A32 * 0.0, d32 * 0.0)
    (_, dA_acc, dd_acc), grads = jax.lax.scan(
        chunk_body, carry0, (xc, dtc, bc, cc, gyc, bnd), reverse=True)
    dx_c, ddt_c, db_c, dc_c = grads                   # (nchunk, chunk, B, ·)

    def unchunk(t, width):
        return t.transpose(2, 0, 1, 3).reshape(B, S, width)

    return (unchunk(dx_c, D).astype(x.dtype),
            unchunk(ddt_c, D).astype(dt.dtype),
            unchunk(db_c, N).astype(b.dtype),
            unchunk(dc_c, N).astype(c.dtype),
            dA_acc.astype(A.dtype), dd_acc.astype(d_vec.dtype))


def _fss_bwd(chunk, serial, res, gy):
    if serial:
        return _fss_bwd_serial(chunk, res, gy)
    x, dt, b, c, A, d_vec, bounds = res
    B, S, D = x.shape
    N = b.shape[-1]
    nchunk = S // chunk
    f32 = jnp.float32
    xc = x.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3).astype(f32)
    dtc = dt.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3).astype(f32)
    bcm = b.reshape(B, nchunk, chunk, N).transpose(1, 0, 2, 3).astype(f32)
    ccm = c.reshape(B, nchunk, chunk, N).transpose(1, 0, 2, 3).astype(f32)
    gyc = gy.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3).astype(f32)
    bnd = bounds.astype(f32)                         # (nchunk, B, D, N)
    A32 = A.astype(f32)

    def body(carry, xs):
        gh_carry = carry                      # dL/dh at the chunk's end+1
        xk, dtk, bk, ck, gk, h0 = xs
        da = jnp.exp(dtk[..., None] * A32)
        dbx = (dtk * xk)[..., None] * bk[:, :, None, :]
        h_all, _ = _chunk_states(da, dbx, h0)            # recompute states
        h_prev = jnp.concatenate([h0[:, None], h_all[:, :-1]], axis=1)
        ghat = gk[..., None] * ck[:, :, None, :]          # dy/dh direct term
        # reverse affine scan: g_t = ghat_t + a_{t+1} * g_{t+1}.  The carry
        # from the next chunk arrives pre-multiplied (g_h0 below), so the
        # reversed sequence's first coefficient is identity, NOT zero.
        a_next = jnp.concatenate(
            [da[:, 1:], jnp.ones_like(da[:, :1])], axis=1)
        a_rev = a_next[:, ::-1]
        g_rev = ghat[:, ::-1]
        pa, pg = jax.lax.associative_scan(_affine_combine, (a_rev, g_rev),
                                          axis=1)
        g = (pg + pa * gh_carry[:, None])[:, ::-1]        # (B,chunk,D,N)
        g_h0 = da[:, 0] * g[:, 0]                         # into previous chunk
        dda = g * h_prev
        ddbx = g
        ddt = jnp.sum(dda * (A32 * da), axis=-1) \
            + jnp.sum(ddbx * bk[:, :, None, :], axis=-1) * xk
        dA_k = jnp.sum(dda * dtk[..., None] * da, axis=(0, 1))
        dx_k = jnp.sum(ddbx * bk[:, :, None, :], axis=-1) * dtk \
            + d_vec.astype(f32) * gk
        db_k = jnp.sum(ddbx * (dtk * xk)[..., None], axis=2)
        dc_k = jnp.einsum("bsd,bsdn->bsn", gk, h_all)
        dd_k = jnp.sum(gk * xk, axis=(0, 1))
        return g_h0, (dx_k, ddt, db_k, dc_k, dA_k, dd_k)

    g_end = (dtc[0, :, 0][:, :, None] * A32) * 0.0        # sharded zeros
    # process chunks in reverse
    xs = (xc[::-1], dtc[::-1], bcm[::-1], ccm[::-1], gyc[::-1], bnd[::-1])
    _, outs = jax.lax.scan(body, g_end, xs)
    dx_c, ddt_c, db_c, dc_c, dA_c, dd_c = outs

    def unchunk(t, width):
        return t[::-1].transpose(1, 0, 2, 3).reshape(B, S, width)

    dx = unchunk(dx_c, D).astype(x.dtype)
    ddt = unchunk(ddt_c, D).astype(dt.dtype)
    db = unchunk(db_c, N).astype(b.dtype)
    dc = unchunk(dc_c, N).astype(c.dtype)
    dA = jnp.sum(dA_c, axis=0).astype(A.dtype)
    dd = jnp.sum(dd_c, axis=0).astype(d_vec.dtype)
    return dx, ddt, db, dc, dA, dd


fused_selective_scan.defvjp(_fss_fwd, _fss_bwd)


def _conv_causal(x, w, b):
    """Depthwise causal conv along S. x: (B,S,D); w: (W,D)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):  # W is 4 — unrolled taps, no conv primitive needed
        out = out + xp[:, i: i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inner(p, cfg, x_conv, h0, chunk, *, impl: str = "chunked"):
    """Shared SSM math given conv output. Returns (y, h_last).

    impl='chunked'  — baseline: materialize deltaA/deltaBx and the state
                      expansion per chunk (checkpointed associative scan).
    impl='fused'    — custom_vjp fused scan: per-chunk discretize + scan +
                      C-project with recompute backward (no h_last; training
                      forward only).  EXPERIMENTS.md §Perf.
    """
    d_in, dt_rank, n, _ = dims(cfg)
    dbc = jnp.einsum("bsd,dk->bsk", x_conv, p["x_proj"].astype(x_conv.dtype))
    dt_raw, b_ssm, c_ssm = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_raw, p["dt_proj"].astype(x_conv.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))   # (B,S,Din)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (Din,N)
    S = x_conv.shape[1]
    if impl in ("fused", "fused_serial") and S % min(chunk, S) == 0:
        y = fused_selective_scan(x_conv, dt, b_ssm, c_ssm, A,
                                 p["D"].astype(jnp.float32),
                                 min(chunk, S), impl == "fused_serial")
        return y.astype(jnp.float32), None
    deltaA = jnp.exp(dt[..., None] * A)                             # (B,S,Din,N)
    deltaBx = (dt * x_conv.astype(jnp.float32))[..., None] * \
        b_ssm.astype(jnp.float32)[:, :, None, :]
    h_all, h_last = selective_scan(deltaA, deltaBx, h0, chunk=chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all,
                   c_ssm.astype(jnp.float32))                       # (B,S,Din)
    y = y + p["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    return y, h_last


def mamba_fwd(p, cfg: ModelConfig, x, *, chunk: int = 128,
              impl: str = "chunked"):
    """Full-sequence Mamba block. x: (B,S,d) -> (B,S,d)."""
    d_in, _, n, _ = dims(cfg)
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    x_part, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_conv_causal(x_part, p["conv_w"], p["conv_b"]))
    h0 = jnp.zeros((B, d_in, n), jnp.float32)
    y, _ = _ssm_inner(p, cfg, x_conv, h0, chunk, impl=impl)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))


# ---------------------------------------------------------------------------
# stateful (serving) paths
# ---------------------------------------------------------------------------

def mamba_cache_init(cfg: ModelConfig, batch: int, dtype):
    d_in, _, n, w = dims(cfg)
    return {
        "conv": Annotated(jnp.zeros((batch, w - 1, d_in), dtype),
                          ("batch", None, "ssm_inner")),
        "h": Annotated(jnp.zeros((batch, d_in, n), jnp.float32),
                       ("batch", "ssm_inner", "state")),
    }


def mamba_prefill(p, cfg: ModelConfig, x, cache, *, chunk: int = 128):
    d_in, _, n, w = dims(cfg)
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    x_part, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_conv_causal(x_part, p["conv_w"], p["conv_b"]))
    h0 = jnp.zeros((B, d_in, n), jnp.float32)
    y, h_last = _ssm_inner(p, cfg, x_conv, h0, chunk)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))
    new_cache = {
        "conv": x_part[:, S - (w - 1):, :].astype(cache["conv"].dtype),
        "h": h_last,
    }
    return out, new_cache


def mamba_step(p, cfg: ModelConfig, x1, cache, *, use_kernels=False,
               live=None):
    """One-token update. x1: (B,1,d).

    use_kernels routes through the fused single-step op in
    ``repro.kernels.mamba_scan`` (gate + scan + out in one kernel; empty
    slots skip work).  Live rows are bit-identical to the inline chain."""
    if use_kernels:
        out, new_conv, new_h = mamba_step_fused(
            x1, cache["conv"], cache["h"], p["in_proj"], p["conv_w"],
            p["conv_b"], p["x_proj"], p["dt_proj"], p["dt_bias"], p["A_log"],
            p["D"], p["out_proj"], live=live)
        return out, {"conv": new_conv, "h": new_h}
    d_in, dt_rank, n, w = dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x1, p["in_proj"].astype(x1.dtype))
    x_part, z = jnp.split(xz, 2, axis=-1)                 # (B,1,Din)
    window = jnp.concatenate([cache["conv"].astype(x1.dtype), x_part], axis=1)
    xc = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    x_conv = jax.nn.silu(xc)[:, None].astype(x1.dtype)    # (B,1,Din)
    dbc = jnp.einsum("bsd,dk->bsk", x_conv, p["x_proj"].astype(x1.dtype))
    dt_raw, b_ssm, c_ssm = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_raw, p["dt_proj"].astype(x1.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,Din)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    deltaA = jnp.exp(dt[..., None] * A)                   # (B,Din,N)
    deltaBx = (dt * x_conv[:, 0].astype(jnp.float32))[..., None] * \
        b_ssm[:, 0].astype(jnp.float32)[:, None, :]
    h = deltaA * cache["h"] + deltaBx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) * x_conv[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None].astype(x1.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x1.dtype))
    new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype), "h": h}
    return out, new_cache
