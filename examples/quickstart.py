"""Quickstart: the three faces of the framework in ~a minute on CPU.

 1. FILCO DSE: two-stage search (mode tables -> GA schedule) for a BERT
    workload on the VCK190 profile, -> instruction streams (Table 1).
 2. Training: a reduced assigned-architecture config, a few steps with the
    production trainer (checkpointing + fault machinery included).
 3. Serving: continuous-batching engine on the same model.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_reduced
from repro.configs.paper_workloads import bert
from repro.core.analytical import filco_vck190
from repro.core.codegen import generate
from repro.core.dse import run_dse
from repro.core.ga import GAConfig
from repro.data import make_pipeline
from repro.distribution import strip
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine
from repro.train import TrainConfig, Trainer


def demo_dse():
    print("=== 1. FILCO two-stage DSE (paper §3) ===")
    wl = bert(64, layers=1)
    res = run_dse(wl, filco_vck190(), solver="ga", max_modes=6,
                  ga_config=GAConfig(population=16, generations=20, seed=0))
    print(f"workload: {wl.name} ({len(wl.layers)} MM layers, "
          f"{wl.total_flops/1e9:.2f} GFLOP)")
    print(f"schedule: makespan={res.makespan*1e6:.0f}us "
          f"throughput={res.plan.throughput_flops(wl.total_flops)/1e9:.1f} GFLOP/s "
          f"(stage1={res.stage1_s:.2f}s stage2={res.stage2_s:.2f}s)")
    prog = generate(wl, res.plan)
    print(f"codegen: {len(prog.iom_load)} IOM loads, "
          f"{sum(len(s) for s in prog.fmu.values())} FMU instrs, "
          f"{sum(len(s) for s in prog.cu.values())} CU instrs, "
          f"{prog.total_bytes()} bytes total "
          f"(runtime reconfiguration = a few bytes/layer, no bitstream reload)")


def demo_train():
    print("\n=== 2. Training (reduced qwen2.5 config) ===")
    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    pipe = make_pipeline(cfg, seq_len=32, global_batch=4)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(model, TrainConfig(steps=6, lr=1e-3, warmup=2,
                                        log_every=2, checkpoint_every=6,
                                        ckpt_dir=d), mesh=None, pipeline=pipe)
        out = tr.fit()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"status={out['status']} losses={['%.3f' % l for l in losses]}")


def demo_serve():
    print("\n=== 3. Serving (continuous batching + FlexArena KV pool) ===")
    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    params = strip(model.init(jax.random.key(0)))
    eng = ServeEngine(model, params,
                      ServeConfig(max_slots=3, max_len=48, eos_id=-1,
                                  prefill_bucket=8))
    rng = np.random.default_rng(0)
    for n in (5, 11, 7):
        eng.submit(rng.integers(1, cfg.vocab_size, size=n), max_new_tokens=6)
    steps = 0
    while eng._queue or eng._active:
        eng.step()
        steps += 1
    print(f"served 3 requests in {steps} decode steps; "
          f"arena utilization now {eng.arena.utilization():.2f}")


if __name__ == "__main__":
    demo_dse()
    demo_train()
    demo_serve()
    print("\nquickstart OK")
