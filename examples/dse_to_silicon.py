"""The full FILCO pipeline on one workload: DNN model -> two-stage DSE ->
schedule -> instruction streams -> functional data-plane execution, with the
numerics checked against the reference — Fig. 6 end to end.

Run: PYTHONPATH=src python examples/dse_to_silicon.py
"""
import numpy as np

from repro.configs.paper_workloads import bert
from repro.core.analytical import filco_vck190
from repro.core.codegen import generate
from repro.core.dse import run_dse
from repro.core.ga import GAConfig
from repro.core.instructions import encode_stream
from repro.core.simulator import DataPlaneSim


def main():
    wl = bert(32, layers=1, name="BERT-32/L1")
    accel = filco_vck190()
    print(f"workload: {wl.name} — {len(wl.layers)} layers, "
          f"diversity={wl.diversity():.2f}")

    # two-stage DSE (exact for small instances, GA beyond)
    res = run_dse(wl, accel, solver="ga", max_modes=6,
                  ga_config=GAConfig(population=24, generations=30, seed=0))
    print(f"stage1 {res.stage1_s:.2f}s, stage2[{res.solver}] {res.stage2_s:.2f}s "
          f"-> makespan {res.makespan*1e6:.0f}us")
    for pl in res.plan.layers[:6]:
        print(f"  {pl.name:10s} {str(pl.mkn):>18s} tile={pl.tile} "
              f"fmus={pl.fmu_ids} cus={pl.cu_ids} "
              f"t=[{pl.start*1e6:.0f},{pl.end*1e6:.0f}]us")

    # codegen: Table-1 streams
    prog = generate(wl, res.plan)
    blob = encode_stream(prog.iom_load)
    print(f"instruction memory: {prog.total_bytes()} bytes "
          f"({len(blob)} for IOM loads)")

    # execute on the functional data plane and check numerics
    layout = prog.layout
    # the functional sim sizes each FMU to hold the largest operand (the
    # real FMU streams tiles; numerics are identical)
    fmu_cap = max(max(l.m * l.k, l.k * l.n, l.m * l.n) for l in wl.layers)
    sim = DataPlaneSim(layout.total_elems, accel.num_fmus,
                       fmu_cap, accel.num_cus)
    rng = np.random.default_rng(0)
    first = wl.layers[0]
    x0 = rng.normal(size=(first.m, first.k)).astype(np.float32)
    sim.ddr[layout.input_addr:layout.input_addr + x0.size] = x0.reshape(-1)
    weights = {}
    for i, l in enumerate(wl.layers):
        w = (rng.normal(size=(l.k, l.n)) / np.sqrt(l.k)).astype(np.float32)
        weights[i] = w
        sim.ddr[layout.weight_addr[i]:
                layout.weight_addr[i] + w.size] = w.reshape(-1)
    sim.run(prog)

    # reference walk of the DAG (same operand provenance as codegen)
    outs = {}
    for i, l in enumerate(wl.layers):
        src = None
        for d in l.deps:
            dep = wl.layers[d]
            if (dep.m, dep.n) == (l.m, l.k):
                src = outs[d]
                break
        if src is None:
            src = sim_input = x0 if (l.m, l.k) == x0.shape else \
                np.resize(x0, (l.m, l.k))
        outs[i] = src @ weights[i]
    last = max(outs)
    got = sim.ddr[layout.result_addr[last]:
                  layout.result_addr[last] + outs[last].size]
    err = np.abs(got.reshape(outs[last].shape) - outs[last]).max()
    print(f"data-plane execution matches reference: max|err| = {err:.2e}")
    assert err < 1e-3
    print("DSE -> ISA -> execution OK")


if __name__ == "__main__":
    main()
