from repro.distribution.partitioning import (
    Annotated,
    ShardingRules,
    constrain,
    logical_specs,
    physical_specs,
    serve_rules,
    shardings,
    single_device_rules,
    strip,
    train_rules,
)

__all__ = [
    "Annotated",
    "ShardingRules",
    "constrain",
    "logical_specs",
    "physical_specs",
    "serve_rules",
    "shardings",
    "single_device_rules",
    "strip",
    "train_rules",
]
