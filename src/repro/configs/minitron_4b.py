"""minitron-4b — pruned Nemotron dense model [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
Squared-ReLU MLP (Nemotron family), no GLU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    head_dim=128,
    attn_type="full",
    act="relu2",
    glu=False,
)

REDUCED = ModelConfig(
    name="minitron-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    attn_type="full",
    act="relu2",
    glu=False,
)
