"""Roofline table generator: reads the dry-run JSON grid and renders the
EXPERIMENTS.md §Roofline table (per arch x cell x mesh: three terms,
dominant bottleneck, MODEL_FLOPS/HLO ratio, roofline fraction)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

DEFAULT_DIR = "results/dryrun"


def load(results_dir: str = DEFAULT_DIR, variant: str = "baseline"
         ) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("variant") != variant:
            continue
        rows.append(r)
    return rows


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def table(rows: List[Dict], mesh: Optional[str] = "single") -> str:
    out = ["| arch | cell | chips | compute | memory | collective | "
           "dominant | useful | resident GiB | peak GiB (CPU-UB) | roofline |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        if mesh and r["mesh"] != mesh:
            continue
        ro = r["roofline"]
        args = r.get("memory_analysis", {}).get("argument_size_in_bytes", 0)
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['chips']} "
            f"| {_fmt_s(ro['compute_s'])} | {_fmt_s(ro['memory_s'])} "
            f"| {_fmt_s(ro['collective_s'])} | {ro['dominant']} "
            f"| {ro['useful_ratio']:.2f} "
            f"| {args/(1<<30):.2f} "
            f"| {r['peak_bytes_per_device']/(1<<30):.2f} "
            f"| {ro['roofline_fraction']:.3f} |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> Dict:
    singles = [r for r in rows if r["mesh"] == "single"]
    multis = [r for r in rows if r["mesh"] == "multi"]
    doms = {}
    for r in singles:
        doms[r["roofline"]["dominant"]] = \
            doms.get(r["roofline"]["dominant"], 0) + 1
    return {
        "cells_single": len(singles), "cells_multi": len(multis),
        "dominant_histogram": doms,
        "worst_roofline": min(
            (r["roofline"]["roofline_fraction"], r["arch"], r["cell"])
            for r in singles) if singles else None,
        "most_collective_bound": max(
            ((r["roofline"]["collective_s"] /
              max(r["roofline"]["compute_s"] + r["roofline"]["memory_s"],
                  1e-12)), r["arch"], r["cell"])
            for r in singles) if singles else None,
    }


def main():
    rows = load()
    if not rows:
        print("roofline,,0,no dry-run results yet (run scripts/run_dryrun_grid.sh)")
        return {}
    print(table(rows, mesh="single"))
    print()
    s = summary(rows)
    print(f"roofline_summary,cells={s['cells_single']}+{s['cells_multi']},"
          f"dominants={s['dominant_histogram']},"
          f"worst={s['worst_roofline']}")
    return {"rows": rows, "summary": s}


if __name__ == "__main__":
    main()
