"""Mixture-of-Experts FFN: top-k routing with capacity, shared experts,
dense-residual branch (Arctic), first-k-dense layers (DeepSeek).

Baseline dispatch is the GShard/T5X einsum formulation (one-hot dispatch /
combine tensors): fully SPMD-friendly — resharding the (groups, experts,
capacity, d) tensor from group-sharded to expert-sharded lowers to an
all-to-all on the expert axis.  A gather-based "sparse dispatch" variant
(``dispatch_impl='gather'``) removes the one-hot matmul FLOPs; it is the
beyond-paper optimization evaluated in EXPERIMENTS.md §Perf.

Groups: tokens are grouped per batch row (G=B), each group dispatches
independently with capacity C = ceil(S * top_k / E * capacity_factor).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# dense FFN (also the non-MoE path)
# ---------------------------------------------------------------------------

def ffn_init(rng, cfg: ModelConfig, d_ff: int, *, expert_dim: int = 0):
    """Plain (or stacked, if expert_dim>0) GLU/MLP weights.

    Expert weights use distinct logical axes ("expert", "expert_embed",
    "expert_mlp") so rules can shard experts over one mesh axis and the inner
    dim over another without colliding with the dense "embed"/"mlp" rules.
    """
    import jax.random as jr

    from repro.distribution.partitioning import Annotated

    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    lead: Tuple = (expert_dim,) if expert_dim else ()
    lg: Tuple = ("expert",) if expert_dim else ()
    ax_d = "expert_embed" if expert_dim else "embed"
    ax_f = "expert_mlp" if expert_dim else "mlp"

    def w(rng_, shape, logical):
        std = 1.0 / math.sqrt(shape[-2])
        arr = jr.normal(rng_, lead + shape) * std
        return Annotated(arr, lg + logical)

    p = {
        "w_up": w(ks[0], (d, d_ff), (ax_d, ax_f)),
        "w_down": w(ks[1], (d_ff, d), (ax_f, ax_d)),
    }
    if cfg.glu:
        p["w_gate"] = w(ks[2], (d, d_ff), (ax_d, ax_f))
    return p


def ffn_apply(p, cfg: ModelConfig, x):
    act = L.activation(cfg.act)
    up = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    if cfg.glu:
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))


def _expert_ffn(p, cfg: ModelConfig, x):
    """x: (E, C*, d) batched over the leading expert dim of stacked weights."""
    act = L.activation(cfg.act)
    up = jnp.einsum("ecd,edf->ecf", x, p["w_up"].astype(x.dtype))
    if cfg.glu:
        gate = jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------

def moe_init(rng, cfg: ModelConfig):
    mo = cfg.moe
    ks = jax.random.split(rng, 4)
    p = {
        "router": L.dense_init(ks[0], cfg.d_model, mo.num_experts,
                               ("embed", None), std=0.02),
        "experts": ffn_init(ks[1], cfg, mo.expert_d_ff,
                            expert_dim=mo.num_experts),
    }
    if mo.num_shared_experts:
        p["shared"] = ffn_init(
            ks[2], cfg, mo.num_shared_experts * (mo.shared_d_ff or mo.expert_d_ff))
    if mo.dense_residual:
        p["dense"] = ffn_init(ks[3], cfg,
                              mo.dense_residual_d_ff or cfg.d_ff)
    return p


def capacity(mo: MoEConfig, group_tokens: int) -> int:
    c = int(group_tokens * mo.top_k / mo.num_experts * mo.capacity_factor)
    return max(c, 1)


def _routing(p, mo: MoEConfig, xg):
    """xg: (G,T,d) -> gates (G,T,k), idx (G,T,k), probs (G,T,E) fp32."""
    # keep x in its wire dtype; accumulate in f32 (upcasting x first hoists
    # the convert above the SP all-gather and doubles wire bytes)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, mo.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    return gate_vals, idx, probs


def _capacity_positions(idx, gate_vals, E: int, C: int):
    """Slot-by-slot capacity assignment (GShard).  Returns
    (pos, keep): pos (G,T,k) int32 position-in-expert, keep (G,T,k) bool."""
    G, T, K = idx.shape
    counts = jnp.zeros((G, E), jnp.int32)
    poss, keeps = [], []
    for j in range(K):
        oh = jax.nn.one_hot(idx[:, :, j], E, dtype=jnp.int32)     # (G,T,E)
        pos_e = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # (G,T,E)
        pos = jnp.sum(pos_e * oh, axis=-1)                        # (G,T)
        keep = pos < C
        counts = counts + jnp.sum(oh * keep[..., None].astype(jnp.int32), axis=1)
        poss.append(pos)
        keeps.append(keep)
    return jnp.stack(poss, -1), jnp.stack(keeps, -1)


def moe_apply(p, cfg: ModelConfig, x, *, dispatch_impl: str = "einsum"):
    """x: (B,S,d) -> (y, aux_loss)."""
    mo = cfg.moe
    B0, S0, d = x.shape
    # regroup tokens: dispatch memory is (G,T,E,C) with C ∝ T, i.e. linear in
    # the group size — GShard-style groups bound it (DESIGN.md §6).
    g = mo.group_size
    if g and S0 > g and S0 % g == 0:
        x = x.reshape(B0 * (S0 // g), g, d)
    B, S, _ = x.shape
    E = mo.num_experts
    C = capacity(mo, S)
    xg = x
    gate_vals, idx, probs = _routing(p, mo, xg)
    pos, keep = _capacity_positions(idx, gate_vals, E, C)

    if dispatch_impl == "einsum":
        # combine tensor (G,T,E,C): gate weight at (expert, position) slots,
        # built in the activation dtype (fp32 here doubles peak memory).
        adt = x.dtype
        combine = jnp.zeros((B, S, E, C), adt)
        for j in range(mo.top_k):
            oh_e = jax.nn.one_hot(idx[:, :, j], E, dtype=adt)
            oh_c = jax.nn.one_hot(pos[:, :, j], C, dtype=adt)
            w = (gate_vals[:, :, j] * keep[:, :, j]).astype(adt)
            combine = combine + w[..., None, None] * \
                (oh_e[..., :, None] * oh_c[..., None, :])
        dispatch = (combine > 0).astype(x.dtype)
        expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, x)   # a2a g->e
        eo = _expert_ffn(p["experts"], cfg,
                         expert_in.transpose(1, 0, 2, 3).reshape(E, B * C, d))
        expert_out = eo.reshape(E, B, C, d).transpose(1, 0, 2, 3)  # (G,E,C,d)
        y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), expert_out)
    elif dispatch_impl == "gather":
        # Sparse dispatch: build (G,E,C) source-token index via scatter, then
        # pure gathers — no one-hot matmul FLOPs (EXPERIMENTS.md §Perf).
        src = jnp.zeros((B, E, C), jnp.int32)
        has = jnp.zeros((B, E, C), x.dtype)
        g_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
        for j in range(mo.top_k):
            e_j, p_j, k_j = idx[:, :, j], pos[:, :, j], keep[:, :, j]
            p_safe = jnp.where(k_j, p_j, C)        # dropped -> OOB (ignored)
            src = src.at[g_idx, e_j, p_safe].set(
                jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)),
                mode="drop")
            has = has.at[g_idx, e_j, p_safe].set(1.0, mode="drop")
        expert_in = jnp.take_along_axis(
            x[:, None, :, :],                       # (G,1,T,d)
            src[..., None].clip(0, S - 1), axis=2) * has[..., None]
        eo = _expert_ffn(p["experts"], cfg,
                         expert_in.transpose(1, 0, 2, 3).reshape(E, B * C, d))
        expert_out = eo.reshape(E, B, C, d).transpose(1, 0, 2, 3)  # (G,E,C,d)
        y = jnp.zeros_like(x)
        for j in range(mo.top_k):
            e_j, p_j = idx[:, :, j], pos[:, :, j]
            w = (gate_vals[:, :, j] * keep[:, :, j]).astype(x.dtype)
            t_out = jnp.take_along_axis(
                expert_out.reshape(B, E * C, d),
                (e_j * C + p_j.clip(0, C - 1))[..., None], axis=1)
            y = y + w[..., None] * t_out
    else:
        raise ValueError(dispatch_impl)

    # auxiliary load-balance loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    oh0 = jax.nn.one_hot(idx[:, :, 0], E, dtype=jnp.float32)
    fe = jnp.mean(oh0, axis=(0, 1))
    aux = E * jnp.sum(fe * me)

    if mo.num_shared_experts:
        y = y + ffn_apply(p["shared"], cfg, x)
    if mo.dense_residual:
        y = y + ffn_apply(p["dense"], cfg, x)
    return y.reshape(B0, S0, d), aux
