"""ragged_decode — single-token decode attention over ragged KV lengths.

The serving decode step is a batched GEMV against a preallocated
(B, T, Hkv, D) cache where T is the slot capacity, but each row only holds
``lengths[b]`` valid entries and some slots are empty altogether.  The padded
XLA path streams all B*T rows every step; this kernel consumes only the live
portion of the stream (the Reconfigurable-Stream-Network datapath idea
applied to the FILCO serving hot path):

* grid (slot, kv_head, kv_block) with a running flash-softmax state in VMEM
  scratch across the sequential kv_block dimension;
* per-row true lengths ride scalar prefetch, so blocks past ``lengths[b]``
  are skipped — compute via ``pl.when`` and DMA via an index map that clamps
  skipped iterations onto the previous block (same block index -> no fetch);
* an empty-slot row skip: rows with ``live[b] == 0`` do no KV work at all
  and write exact zeros.

``interpret=True`` runs the same kernel on CPU (CI's kernels-smoke job);
tests pin it bit-close against :mod:`repro.kernels.ragged_decode.ref`, whose
live rows are in turn bit-identical to the padded serving path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(lens_ref, live_ref, glob_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, bk, window, logit_cap, scale):
    b = pl.program_id(0)
    i = pl.program_id(2)
    nb = pl.num_programs(2)
    length = lens_ref[b]
    live = live_ref[b] != 0

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(live & (i * bk < length))
    def _block():
        q = q_ref[...].astype(jnp.float32)                   # (G, D)
        k = k_ref[...].astype(jnp.float32)                   # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G, bk)
        if logit_cap > 0.0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        pos = i * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = pos < length
        if window:
            w_ok = pos > (length - 1 - window)
            mask = mask & (w_ok | (glob_ref[0] != 0))
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # explicit mask (not exp underflow): a fully window-masked first
        # block would otherwise yield exp(NEG_INF - NEG_INF) = 1
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        resc = jnp.exp(m_prev - m_new)
        v = v_ref[...].astype(jnp.float32)                   # (bk, D)
        acc_ref[...] = acc_ref[...] * resc + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[...] = l_prev * resc + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _final():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0.0, l, 1.0)
        o_ref[...] = jnp.where(live, out, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "logit_cap", "bk", "interpret"))
def ragged_decode_kernel(q, k, v, lengths, live, glob, *, window: int = 0,
                         logit_cap: float = 0.0, bk: int = 128,
                         interpret: bool = False):
    """q: (B, Hq, D); k, v: (B, T, Hkv, D); lengths, live: (B,) int32;
    glob: (1,) int32 sliding-window bypass flag -> (B, Hq, D).

    ``lengths`` must be in [1, T] for live rows (callers clip); dead rows
    (``live == 0``) skip all KV traffic and return zeros.
    """
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    assert T % bk == 0, (T, bk)
    nb = T // bk
    scale = 1.0 / math.sqrt(D)

    def kv_index(b, h, i, lens, live_r, glob_r):
        # clamp skipped iterations onto the last block this row needs: the
        # pipeline sees an unchanged block index and issues no new DMA
        last = jnp.maximum(pl.cdiv(lens[b], bk), 1) - 1
        last = jnp.where(live_r[b] != 0, last, 0)
        return (b, jnp.minimum(i, last), h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((None, G, D), lambda b, h, i, *_: (b, h, 0)),
            pl.BlockSpec((None, bk, None, D), kv_index),
            pl.BlockSpec((None, bk, None, D), kv_index),
        ],
        out_specs=pl.BlockSpec((None, G, D), lambda b, h, i, *_: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, bk=bk, window=window,
                               logit_cap=logit_cap, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(lengths, live, glob, q, k, v)
