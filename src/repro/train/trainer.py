"""Trainer: jit-compiled train step with microbatch accumulation, sharded
params/optimizer state, checkpointing, preemption and straggler handling.

``make_train_step`` builds the pure step function (used directly by the
dry-run); :class:`Trainer` wraps it with the host-side production loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.distribution import partitioning as part
from repro.models.model import Model
from repro.optim import base as optim
from repro.train import checkpoint as ckpt_lib
from repro.train import fault

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 10
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation
    log_every: int = 10
    checkpoint_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    attn_impl: str = "blockwise"
    moe_dispatch: str = "einsum"
    ssm_impl: str = "chunked"
    attn_block: int = 512


def make_train_step(model: Model, opt: optim.Optimizer, cfg: TrainConfig,
                    *, residual_spec=None) -> Callable:
    """(params, opt_state, step, batch) -> (params, opt_state, metrics).

    With cfg.microbatches > 1, the batch's leading dim is split and gradients
    are accumulated in a lax.scan (constant memory in microbatch count)."""
    lr_fn = optim.cosine_schedule(cfg.lr, cfg.warmup, cfg.steps)

    def loss_fn(params, batch):
        loss, metrics = model.loss(
            params, batch, attn_impl=cfg.attn_impl,
            moe_dispatch=cfg.moe_dispatch, residual_spec=residual_spec,
            ssm_impl=cfg.ssm_impl, attn_block=cfg.attn_block)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step_fn(params, opt_state, step, batch):
        if cfg.microbatches > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((cfg.microbatches,
                                     x.shape[0] // cfg.microbatches)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / cfg.microbatches,
                    gacc, grads)
                return (gacc, lacc + loss / cfg.microbatches), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (gzero, jnp.zeros(())), micro)
            metrics = {"xent": loss, "aux": jnp.zeros(())}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        grads, gnorm = optim.clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_fn(step)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        out = dict(metrics)
        out.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return params, opt_state, out

    return step_fn


def setup_sharded_state(model: Model, opt: optim.Optimizer, mesh: Mesh,
                        rules: part.ShardingRules, rng
                        ) -> Tuple[PyTree, PyTree, PyTree, PyTree]:
    """Init params + opt state directly into their target shardings.

    Returns (params, opt_state, param_shardings, opt_shardings)."""
    annotated = jax.eval_shape(model.init, rng)
    param_sh = part.shardings(annotated, mesh, rules)

    def init_stripped(r):
        return part.strip(model.init(r))

    with mesh:
        params = jax.jit(init_stripped, out_shardings=param_sh)(rng)
        opt_shapes = jax.eval_shape(opt.init, params)
        opt_sh = _derive_opt_shardings(opt_shapes, params, param_sh, mesh)
        opt_state = jax.jit(opt.init, out_shardings=opt_sh)(params)
    return params, opt_state, param_sh, opt_sh


def _derive_opt_shardings(opt_shapes, params, param_sh, mesh):
    """Optimizer leaves mirroring a param shape inherit its sharding;
    factored/scalar leaves are replicated (tiny)."""
    shape_to_sh = {}
    for p, s in zip(jax.tree.leaves(params), jax.tree.leaves(param_sh)):
        shape_to_sh.setdefault(p.shape, s)
    rep = NamedSharding(mesh, P())

    def pick(leaf):
        return shape_to_sh.get(leaf.shape, rep)

    return jax.tree.map(pick, opt_shapes)


class Trainer:
    """Production loop: data -> jitted step -> metrics/checkpoints/fault
    handling.  CPU-runnable end-to-end with reduced configs."""

    def __init__(self, model: Model, cfg: TrainConfig, mesh: Optional[Mesh],
                 rules: Optional[part.ShardingRules] = None,
                 pipeline: Optional[SyntheticLM] = None):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules or part.single_device_rules()
        self.pipeline = pipeline
        self.opt = optim.make_optimizer(model.cfg.optimizer)
        self.guard = fault.PreemptionGuard(install_signal=False)
        self.watchdog = fault.StragglerWatchdog()
        self.metrics_log: list = []
        residual_spec = None
        if mesh is not None and self.rules.rules.get("act_seq"):
            residual_spec = self.rules.spec(("batch", "act_seq", None))
        self._step_fn = make_train_step(model, self.opt, cfg,
                                        residual_spec=residual_spec)

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        rng = jax.random.key(seed)
        if self.mesh is not None:
            params, opt_state, psh, osh = setup_sharded_state(
                self.model, self.opt, self.mesh, self.rules, rng)
            self._jit_step = jax.jit(self._step_fn, donate_argnums=(0, 1))
        else:
            params = part.strip(self.model.init(rng))
            opt_state = self.opt.init(params)
            self._jit_step = jax.jit(self._step_fn, donate_argnums=(0, 1))
        return params, opt_state

    def restore_or_init(self, seed: int = 0):
        step0 = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        params, opt_state = self.init_state(seed)
        if step0 is None:
            return params, opt_state, 0
        state, extra = ckpt_lib.restore(
            self.cfg.ckpt_dir, step0,
            {"params": params, "opt": opt_state})
        return state["params"], state["opt"], int(extra.get("next_step", step0))

    # ------------------------------------------------------------------
    def fit(self, params=None, opt_state=None, start_step: int = 0,
            steps: Optional[int] = None) -> Dict[str, Any]:
        if params is None:
            params, opt_state, start_step = self.restore_or_init(self.cfg.seed)
        total = steps if steps is not None else self.cfg.steps
        ctx = self.mesh if self.mesh is not None else _nullcontext()
        step = start_step
        status = "completed"
        with ctx:
            while step < total:
                t0 = time.monotonic()
                batch = {k: jnp.asarray(v)
                         for k, v in self.pipeline.batch(step).items()}
                if self.model.cfg.is_encdec and "frames" not in batch:
                    batch = {k: jnp.asarray(v) for k, v in
                             self.pipeline.batch_with_frames(
                                 step, self.model.cfg.d_model).items()}
                params, opt_state, metrics = self._jit_step(
                    params, opt_state, jnp.asarray(step), batch)
                dur = time.monotonic() - t0
                action = self.watchdog.observe(step, dur)
                if step % self.cfg.log_every == 0 or step == total - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update({"step": step, "sec": dur})
                    self.metrics_log.append(m)
                step += 1
                want_ckpt = (step % self.cfg.checkpoint_every == 0
                             or step == total)
                if self.guard.check() or \
                   action == fault.ACTION_CHECKPOINT_AND_RESHARD:
                    ckpt_lib.save(self.cfg.ckpt_dir, step,
                                  {"params": params, "opt": opt_state},
                                  extra={"next_step": step, "reason": action})
                    status = ("preempted" if self.guard.check()
                              else "straggler_reshard")
                    break
                if want_ckpt:
                    ckpt_lib.save(self.cfg.ckpt_dir, step,
                                  {"params": params, "opt": opt_state},
                                  extra={"next_step": step})
        return {"params": params, "opt_state": opt_state, "step": step,
                "status": status, "metrics": self.metrics_log}


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
