import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first
#   init, and ONLY the dry-run runs with 512 placeholder devices.
#
# Multi-pod dry-run driver (deliverable (e)): for every assigned
# (architecture x input-shape) cell, build the real train/prefill/decode step
# function, lower + compile it against the production mesh (16x16 single-pod
# and 2x16x16 multi-pod), print memory_analysis() / cost_analysis(), extract
# trip-count-aware FLOPs/bytes/collective-bytes from the optimized HLO, and
# derive the three roofline terms.  Results cache as JSON per cell under
# --out so the full grid is resumable.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
#       --cell train_4k [--multi-pod] [--out results/dryrun]
#   PYTHONPATH=src python -m repro.launch.dryrun --all  # every runnable cell
#
# Perf-iteration knobs (EXPERIMENTS.md §Perf): --attn-impl triangular,
# --moe-dispatch gather, --no-sp, --no-remat, --variant <tag>.

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hlo_lib
from repro.analysis import roofline as roof
from repro.configs import CELLS_BY_NAME, ARCH_IDS, cells_for, get_config
from repro.configs.base import ModelConfig, ShapeCell
from repro.distribution import partitioning as part
from repro.launch.mesh import fit_spec, make_production_mesh, sanitize_spec
from repro.models.model import build_model, input_specs
from repro.optim import base as optim_lib
from repro.train.trainer import TrainConfig, make_train_step

ENCDEC_DECODE_SRC = 4096


def _fsdp_weights_at_serve(cfg: ModelConfig) -> bool:
    """2-D shard weights at serving (model x data).

    Always on: several archs have head counts that do not divide the
    16-wide model axis (qwen2.5: 40, hymba: 25, arctic: 56), so their q/o
    projections cannot shard on `model` and must shard on `data` instead —
    XLA lowers the contractions to partial-sum + all-reduce over data, which
    the collective roofline term prices honestly."""
    return True


def _sds_tree(annotated_tree, mesh, rules):
    """Annotated pytree -> ShapeDtypeStruct pytree with NamedShardings.
    Unannotated leaves (scalar bookkeeping like src_len) replicate."""
    def make(a):
        if isinstance(a, part.Annotated):
            spec = fit_spec(rules.spec(a.logical), a.value.shape, mesh)
            return jax.ShapeDtypeStruct(a.value.shape, a.value.dtype,
                                        sharding=NamedSharding(mesh, spec))
        return jax.ShapeDtypeStruct(a.shape, a.dtype,
                                    sharding=NamedSharding(mesh, P()))
    return jax.tree.map(make, annotated_tree,
                        is_leaf=lambda x: isinstance(x, part.Annotated))


def _batch_sds(specs, mesh):
    """Input batch ShapeDtypeStructs sharded on the batch dim."""
    out = {}
    for k, s in specs.items():
        spec = fit_spec(P(("pod", "data")), s.shape, mesh)
        out[k] = jax.ShapeDtypeStruct(s.shape, s.dtype,
                                      sharding=NamedSharding(mesh, spec))
    return out


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh, *,
               attn_impl: str = "blockwise", moe_dispatch: str = "einsum",
               sequence_parallel: bool = True, ssm_impl: str = "chunked",
               attn_block: int = 512):
    """Returns (step_fn, kwargs of ShapeDtypeStruct arguments)."""
    model = build_model(cfg)
    rng = jax.random.key(0)

    if cell.kind == "train":
        rules = part.train_rules(sequence_parallel=sequence_parallel)
        residual_spec = None
        if sequence_parallel:
            residual_spec = sanitize_spec(
                rules.spec(("batch", "act_seq", None)), mesh)
        opt = optim_lib.make_optimizer(cfg.optimizer)
        tc = TrainConfig(attn_impl=attn_impl, moe_dispatch=moe_dispatch,
                         ssm_impl=ssm_impl, attn_block=attn_block)
        step_fn = make_train_step(model, opt, tc, residual_spec=residual_spec)
        params_ann = jax.eval_shape(model.init, rng)
        params_sds = _sds_tree(params_ann, mesh, rules)
        params_stripped = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.value.shape, a.value.dtype),
            params_ann, is_leaf=lambda x: isinstance(x, part.Annotated))
        opt_sds = _opt_sds(opt, params_stripped, params_sds, mesh)
        batch = _batch_sds(input_specs(cfg, cell), mesh)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        # outputs: (params, opt_state, metrics) — pin state to its input
        # shardings (donation aliases them anyway)
        out_sh = (jax.tree.map(lambda s: s.sharding, params_sds),
                  jax.tree.map(lambda s: s.sharding, opt_sds), None)
        return step_fn, dict(params=params_sds, opt_state=opt_sds,
                             step=step_sds, batch=batch), out_sh

    rules = part.serve_rules(fsdp_weights=_fsdp_weights_at_serve(cfg))
    model_kwargs = dict(attn_impl=attn_impl, moe_dispatch=moe_dispatch)
    params_ann = jax.eval_shape(model.init, rng)
    # inference runs from a bf16 checkpoint
    params_sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape,
            jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype,
            sharding=s.sharding),
        _sds_tree(params_ann, mesh, rules))

    if cell.kind == "prefill":
        cache_ann = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len,
                                     src_len=cell.seq_len if cfg.is_encdec else 0))
        cache_sds = _sds_tree(cache_ann, mesh, rules)
        batch = _batch_sds(input_specs(cfg, cell), mesh)

        def prefill_fn(params, cache, batch):
            return model.prefill(params, batch, cache,
                                 attn_impl=model_kwargs["attn_impl"],
                                 moe_dispatch=model_kwargs["moe_dispatch"],
                                 attn_block=attn_block)

        # pin the output cache to the input cache's shardings (XLA would
        # otherwise pick its own, often replicated, output layout)
        out_sh = (None, jax.tree.map(lambda s: s.sharding, cache_sds))
        return prefill_fn, dict(params=params_sds, cache=cache_sds,
                                batch=batch), out_sh

    # decode: one new token against a seq_len cache
    src = ENCDEC_DECODE_SRC if cfg.is_encdec else 0
    cache_ann = jax.eval_shape(
        lambda: model.init_cache(cell.global_batch, cell.seq_len,
                                 src_len=src))
    cache_sds = _sds_tree(cache_ann, mesh, rules)
    batch = _batch_sds(input_specs(cfg, cell), mesh)

    def decode_fn(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"],
                                 moe_dispatch=model_kwargs["moe_dispatch"])

    out_sh = (None, jax.tree.map(lambda s: s.sharding, cache_sds))
    return decode_fn, dict(params=params_sds, cache=cache_sds,
                           batch=batch), out_sh


def _opt_sds(opt, params_stripped, params_sds, mesh):
    """Optimizer state SDS: leaves mirroring a param shape inherit its
    sharding; factored/scalar leaves replicate."""
    opt_shapes = jax.eval_shape(opt.init, params_stripped)
    by_shape = {}
    for p in jax.tree.leaves(params_sds):
        by_shape.setdefault(p.shape, p.sharding)
    rep = NamedSharding(mesh, P())

    def make(leaf):
        sh = by_shape.get(leaf.shape, rep)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    return jax.tree.map(make, opt_shapes)


# ---------------------------------------------------------------------------

def run_cell(arch: str, cell_name: str, *, multi_pod: bool = False,
             attn_impl: str = "blockwise", moe_dispatch: str = "einsum",
             sequence_parallel: bool = True, variant: str = "baseline",
             keep_hlo: bool = False, moe_group: int = -1,
             remat: bool = True, ssm_impl: str = "chunked",
             attn_block: int = 512) -> dict:
    cfg = get_config(arch)
    if moe_group >= 0 and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, group_size=moe_group))
    if not remat:
        cfg = dataclasses.replace(cfg, remat=False)
    cell = CELLS_BY_NAME[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.monotonic()
    step_fn, kwargs, out_sh = build_cell(
        cfg, cell, mesh, attn_impl=attn_impl, moe_dispatch=moe_dispatch,
        sequence_parallel=sequence_parallel, ssm_impl=ssm_impl,
        attn_block=attn_block)
    # donate the state the production step donates: params+opt for train,
    # the KV cache for decode — memory_analysis must reflect the aliasing.
    if cell.kind == "train":
        donate = ("params", "opt_state")
    elif cell.kind == "decode":
        donate = ("cache",)
    else:
        donate = ()
    with mesh:
        lowered = jax.jit(step_fn, donate_argnames=donate,
                          out_shardings=out_sh).lower(**kwargs)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    text = compiled.as_text()
    hlo_cost = hlo_lib.analyze_hlo(text)
    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_fields[f] = int(getattr(mem, f, 0) or 0)
    peak = mem_fields["argument_size_in_bytes"] + \
        mem_fields["temp_size_in_bytes"] + mem_fields["output_size_in_bytes"] \
        - mem_fields["alias_size_in_bytes"]
    terms = roof.derive_terms(
        arch=arch, cell=cell_name, mesh_name=mesh_name, chips=chips,
        cost={"flops": hlo_cost.flops, "bytes accessed": hlo_cost.bytes},
        collective=roof.CollectiveStats(hlo_cost.collective_by_kind,
                                        hlo_cost.collective_count),
        model_flops=roof.model_flops_for(cfg, cell),
        peak_memory_bytes=peak)
    result = {
        "arch": arch, "cell": cell_name, "mesh": mesh_name, "chips": chips,
        "variant": variant,
        "attn_impl": attn_impl, "moe_dispatch": moe_dispatch,
        "ssm_impl": ssm_impl,
        "sequence_parallel": sequence_parallel,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_fields,
        "peak_bytes_per_device": peak,
        "fits_hbm": peak <= 16 * (1 << 30),
        "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed",
                                        "optimal_seconds", "utilization")},
        "hlo_flops_per_device": hlo_cost.flops,
        "hlo_bytes_per_device": hlo_cost.bytes,
        "collective_bytes_per_device": hlo_cost.collective_bytes,
        "collective_by_kind": hlo_cost.collective_by_kind,
        "collective_count": hlo_cost.collective_count,
        "roofline": terms.row(),
    }
    if keep_hlo:
        result["hlo_text_head"] = text[:20000]
    return result


def cell_list():
    out = []
    for arch in ARCH_IDS:
        for cell in cells_for(get_config(arch)):
            out.append((arch, cell.name))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--cell", choices=sorted(CELLS_BY_NAME))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every runnable cell on both meshes (in-proc)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--attn-impl", default="blockwise",
                    choices=["blockwise", "triangular"])
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=["einsum", "gather"])
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel residuals")
    ap.add_argument("--moe-group", type=int, default=-1,
                    help="override MoE dispatch group size")
    ap.add_argument("--ssm-impl", default="chunked",
                    choices=["chunked", "fused", "fused_serial"])
    ap.add_argument("--attn-block", type=int, default=512)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        todo = [(a, c, mp) for a, c in cell_list() for mp in (False, True)]
    else:
        assert args.arch and args.cell, "--arch and --cell (or --all)"
        todo = [(args.arch, args.cell, args.multi_pod)]

    failures = []
    for arch, cell, mp in todo:
        tag = f"{arch}__{cell}__{'multi' if mp else 'single'}__{args.variant}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip] {tag} (cached)")
            continue
        print(f"[run ] {tag}", flush=True)
        try:
            res = run_cell(arch, cell, multi_pod=mp,
                           attn_impl=args.attn_impl,
                           moe_dispatch=args.moe_dispatch,
                           sequence_parallel=not args.no_sp,
                           variant=args.variant,
                           moe_group=args.moe_group,
                           remat=not args.no_remat,
                           ssm_impl=args.ssm_impl,
                           attn_block=args.attn_block)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(f"[ok  ] {tag}: compile={res['compile_s']}s "
                  f"peak={res['peak_bytes_per_device']/(1<<30):.2f}GiB "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s "
                  f"dominant={r['dominant']} "
                  f"roofline={r['roofline_fraction']:.3f}", flush=True)
        except Exception as e:  # noqa: BLE001 — record, continue the grid
            failures.append((tag, repr(e)))
            with open(os.path.join(args.out, tag + ".FAILED"), "w") as f:
                f.write(traceback.format_exc())
            print(f"[FAIL] {tag}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
