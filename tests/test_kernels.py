"""Per-kernel validation: shape/dtype sweeps in interpret mode vs the
pure-jnp oracles (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.filco_mm import kernel as fm_kernel
from repro.kernels.filco_mm import ref as fm_ref
from repro.kernels.flash_attention import kernel as fa_kernel
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.mamba_scan import kernel as ms_kernel
from repro.kernels.mamba_scan import ref as ms_ref

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# filco_mm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [
    (256, 256, 384), (100, 200, 300), (8, 24, 16), (1, 1, 1),
    (130, 129, 257), (64, 64, 64), (255, 1, 255),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flex_mm_matches_oracle(mkn, dtype):
    m, k, n = mkn
    a = jnp.asarray(RNG.normal(size=(256, 256)), dtype)
    b = jnp.asarray(RNG.normal(size=(256, 384)), dtype)
    dims = jnp.asarray([m, k, n], jnp.int32)
    out = fm_kernel.flex_mm(a, b, dims, bm=64, bk=64, bn=128, interpret=True)
    ref = fm_ref.flex_mm_ref(a, b, dims)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 32)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 192), k=st.integers(1, 192), n=st.integers(1, 192))
def test_flex_mm_property_random_dims(m, k, n):
    """One compiled kernel serves every (m,k,n) <= buffer — zero recompile."""
    a = jnp.asarray(RNG.normal(size=(192, 192)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(192, 192)), jnp.float32)
    dims = jnp.asarray([m, k, n], jnp.int32)
    out = fm_kernel.flex_mm(a, b, dims, bm=64, bk=64, bn=64, interpret=True)
    ref = fm_ref.flex_mm_ref(a, b, dims)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)


def test_flex_mm_zero_outside_valid_region():
    a = jnp.ones((128, 128))
    b = jnp.ones((128, 128))
    out = fm_kernel.flex_mm(a, b, jnp.asarray([40, 50, 60], jnp.int32),
                            bm=64, bk=64, bn=64, interpret=True)
    assert float(jnp.abs(out[40:, :]).max()) == 0.0
    assert float(jnp.abs(out[:, 60:]).max()) == 0.0
    np.testing.assert_allclose(out[:40, :60], 50.0)


def test_static_mm_matches_oracle():
    a = jnp.asarray(RNG.normal(size=(128, 128)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(128, 128)), jnp.float32)
    out = fm_kernel.static_mm(a, b, bm=64, bk=64, bn=64, interpret=True)
    np.testing.assert_allclose(out, fm_ref.static_mm_ref(a, b),
                               rtol=1e-5, atol=1e-4)


def test_atom_accounting_flexible_vs_static():
    # 8x24x16: 1x1x... on (8,128,128) atoms -> quantized; static pays the
    # full buffer.  Flexible must never exceed static.
    flex = fm_kernel.atoms_issued_flexible(8, 24, 16)
    static = fm_kernel.atoms_issued_static(256, 256, 384)
    assert flex < static
    full = fm_kernel.atoms_issued_flexible(256, 256, 384)
    assert full == static


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
@pytest.mark.parametrize("shape", [(3, 256, 64), (2, 128, 32)])
def test_flash_attention_matches_oracle(causal, window, shape):
    BH, S, D = shape
    q = jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32)
    out = fa_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                    bq=64, bk=64, interpret=True)
    ref = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    BH, S, D = 2, 128, 64
    q = jnp.asarray(RNG.normal(size=(BH, S, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(BH, S, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(BH, S, D)), dtype)
    out = fa_kernel.flash_attention(q, k, v, causal=True, bq=64, bk=64,
                                    interpret=True)
    ref = fa_ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_gqa_wrapper():
    from repro.kernels.flash_attention.ops import mha
    B, S, Hq, Hkv, D = 2, 128, 8, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    out = mha(q, k, v, causal=True, impl="interpret", bq=64, bk=64)
    kx = jnp.repeat(k, Hq // Hkv, axis=2)
    vx = jnp.repeat(v, Hq // Hkv, axis=2)
    ref = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D),
        kx.transpose(0, 2, 1, 3).reshape(B * Hq, S, D),
        vx.transpose(0, 2, 1, 3).reshape(B * Hq, S, D), causal=True)
    np.testing.assert_allclose(
        out, ref.reshape(B, Hq, S, D).transpose(0, 2, 1, 3),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 64, 32, 8), (1, 128, 16, 4),
                                   (3, 32, 64, 16)])
def test_mamba_scan_matches_oracle(shape):
    B, S, D, N = shape
    x = jnp.asarray(RNG.normal(size=(B, S, D)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(B, S, D)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    a_log = jnp.asarray(np.log(RNG.uniform(0.5, 4.0, size=(D, N))), jnp.float32)
    d = jnp.asarray(RNG.normal(size=(D,)), jnp.float32)
    out = ms_kernel.mamba_scan(x, dt, b, c, a_log, d, bd=min(16, D),
                               bs=min(16, S), interpret=True)
    ref = ms_ref.mamba_scan_ref(x, dt, b, c, a_log, d)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_mamba_scan_state_continuity_across_blocks():
    """Sequential grid blocks must carry h across chunk boundaries."""
    B, S, D, N = 1, 64, 8, 4
    x = jnp.ones((B, S, D))
    dt = jnp.full((B, S, D), 0.05)
    b = jnp.ones((B, S, N))
    c = jnp.ones((B, S, N))
    a_log = jnp.zeros((D, N))
    d = jnp.zeros((D,))
    out_one = ms_kernel.mamba_scan(x, dt, b, c, a_log, d, bd=8, bs=64,
                                   interpret=True)
    out_chunked = ms_kernel.mamba_scan(x, dt, b, c, a_log, d, bd=8, bs=8,
                                       interpret=True)
    np.testing.assert_allclose(out_one, out_chunked, rtol=1e-5, atol=1e-5)


def test_mamba_scan_vs_model_reference():
    """The kernel oracle agrees with the model-layer chunked scan."""
    from repro.models.ssm import selective_scan
    B, S, D, N = 2, 48, 12, 4
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(B, S, D)), jnp.float32)
    a_log = jnp.asarray(np.log(RNG.uniform(0.5, 4.0, size=(D, N))), jnp.float32)
    bmat = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(B, S, D)), jnp.float32)
    A = -jnp.exp(a_log)
    deltaA = jnp.exp(dt[..., None] * A)
    deltaBx = (dt * x)[..., None] * bmat[:, :, None, :]
    h_all, _ = selective_scan(deltaA, deltaBx,
                              jnp.zeros((B, D, N)), chunk=16)
    c = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y_model = jnp.einsum("bsdn,bsn->bsd", h_all, c) + 0.0 * x
    y_ref = ms_ref.mamba_scan_ref(x, dt, bmat, c, a_log, jnp.zeros((D,)))
    np.testing.assert_allclose(y_model, y_ref, rtol=1e-4, atol=1e-4)
