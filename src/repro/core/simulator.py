"""Functional data-plane simulator: executes FILCO instruction streams
against numpy DDR / FMU-arena state (paper Fig. 2's data plane in software).

This is the semantic ground truth for the ISA: running the generated program
for a workload must reproduce the workload's reference numerics (layer-chain
matmuls).  The CU's flexible matmul is executed through the same
``filco_mm`` reference/kernel path used on TPU, so kernel, ISA and arena
semantics are tested together.

The simulator executes instruction streams in program order per unit with a
simple dataflow handshake (FMU send -> CU consume -> FMU receive), which is
sufficient for numerics; timing is the analytical model's job, not ours.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import instructions as isa
from repro.core.codegen import Program


@dataclasses.dataclass
class FMUState:
    """1-D addressed double buffer (we model the ping buffer; pong is used
    for overlap, which does not change numerics)."""

    data: np.ndarray                       # flat elements
    view_cols: int = 0                     # current runtime view stride


class DataPlaneSim:
    def __init__(self, ddr_elems: int, num_fmus: int, fmu_capacity: int,
                 num_cus: int, *, use_kernel: bool = False):
        self.ddr = np.zeros(ddr_elems, np.float32)
        self.fmus = {u: FMUState(np.zeros(fmu_capacity, np.float32))
                     for u in range(num_fmus)}
        self.num_cus = num_cus
        self.use_kernel = use_kernel
        # in-flight operand views per CU: cu -> {"a": (mat), "b": (mat)}
        self._cu_in: Dict[int, Dict[str, np.ndarray]] = {}
        # results waiting to be received: (cu, fmu) -> flat data
        self._cu_out: Dict[int, np.ndarray] = {}

    # -- IOM ---------------------------------------------------------------
    def _iom_load(self, ins: isa.IOMLoad) -> None:
        rows = ins.end_row - ins.start_row
        cols = ins.end_col - ins.start_col
        full = self.ddr[ins.ddr_addr: ins.ddr_addr + ins.m * ins.n]
        mat = full.reshape(ins.m, ins.n)[ins.start_row:ins.end_row,
                                         ins.start_col:ins.end_col]
        fmu = self.fmus[ins.des_fmu]
        fmu.data[: rows * cols] = mat.reshape(-1)
        fmu.view_cols = cols

    def _iom_store(self, ins: isa.IOMStore) -> None:
        rows = ins.end_row - ins.start_row
        cols = ins.end_col - ins.start_col
        fmu = self.fmus[ins.src_fmu]
        mat = fmu.data[: rows * cols].reshape(rows, cols)
        full = self.ddr[ins.ddr_addr: ins.ddr_addr + ins.m * ins.n]
        view = full.reshape(ins.m, ins.n)
        view[ins.start_row:ins.end_row, ins.start_col:ins.end_col] = mat

    # -- FMU ----------------------------------------------------------------
    def _fmu_send(self, fmu_id: int, ins: isa.FMUInstr) -> None:
        fmu = self.fmus[fmu_id]
        cols = fmu.view_cols or (ins.end_col - ins.start_col)
        total_rows = (np.count_nonzero(fmu.data) // max(cols, 1)) or ins.end_row
        # 1-D addressed window: rows [start_row, end_row) x cols
        # [start_col, end_col) of the runtime (.., cols) view (FMV).
        r = ins.end_row - ins.start_row
        c = ins.end_col - ins.start_col
        start = ins.start_row * cols + ins.start_col
        rows = np.stack([
            fmu.data[start + i * cols: start + i * cols + c]
            for i in range(r)]) if r else np.zeros((0, c), np.float32)
        slot = self._cu_in.setdefault(ins.des_cu, {})
        slot["b" if "a" in slot else "a"] = rows

    def _fmu_recv_cu(self, fmu_id: int, ins: isa.FMUInstr) -> None:
        fmu = self.fmus[fmu_id]
        data = self._cu_out.pop(ins.src_cu)
        cols = ins.end_col - ins.start_col
        start = ins.start_row * cols + ins.start_col
        fmu.data[start: start + data.size] = data.reshape(-1)
        fmu.view_cols = cols

    # -- CU -------------------------------------------------------------------
    def _cu_mm(self, cu_id: int, ins: isa.CUInstr) -> None:
        ops = self._cu_in.pop(cu_id)
        a, b = ops["a"], ops["b"]
        assert a.shape[1] == b.shape[0], (a.shape, b.shape)
        if self.use_kernel:
            import jax.numpy as jnp

            from repro.kernels.filco_mm import kernel as K

            pad = lambda x, r, c: np.pad(x, ((0, r - x.shape[0]),
                                             (0, c - x.shape[1])))
            Mx = -(-a.shape[0] // 64) * 64
            Kx = -(-a.shape[1] // 64) * 64
            Nx = -(-b.shape[1] // 64) * 64
            out = K.flex_mm(jnp.asarray(pad(a, Mx, Kx)),
                            jnp.asarray(pad(b, Kx, Nx)),
                            jnp.asarray([a.shape[0], a.shape[1], b.shape[1]],
                                        jnp.int32),
                            bm=64, bk=64, bn=64, interpret=True)
            res = np.asarray(out)[: a.shape[0], : b.shape[1]]
        else:
            res = a @ b
        self._cu_out[cu_id] = res

    # -- program execution ------------------------------------------------
    def run(self, prog: Program) -> None:
        """Replay the layer-ordered micro-programs.  Dataflow order within a
        layer: IOM loads -> FMU recv -> per-CU (send A, send B, compute,
        recv C) -> IOM store.  Layers execute in schedule order; concurrency
        does not change numerics (disjoint units by Eq. 4), so sequential
        replay is the semantic reference."""
        assert prog.layer_programs, "program has no layer micro-programs"
        for lp in prog.layer_programs:
            for ins in lp.loads:
                self._iom_load(ins)
            for w in lp.cu_work:
                self._fmu_send(w.compute.src_fmu, w.send_a)
                self._fmu_send(w.compute.src_fmu_b, w.send_b)
                self._cu_mm(w.cu_id, w.compute)
                self._fmu_recv_cu(lp.fmu_c, dataclasses.replace(
                    w.recv_c, src_cu=w.cu_id))
            self._iom_store(lp.store)
