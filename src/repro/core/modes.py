"""Stage-1 Runtime Parameter Optimizer (paper §3.1).

For every layer, brute-force the runtime-configurable parameters — CU count,
FMU count (= on-chip capacity share), and the on-chip tile split — under the
FMU/CU constraints, pricing each with the analytical model.  The output is
the paper's per-layer table of candidate modes (f_ik, c_ik, e_ik) with the
optimal runtime parameters attached, which Stage 2 schedules.

Dominated modes (>= resources and >= latency of another) are pruned so the
MILP/GA search space stays tight.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.common.platform import PlatformProfile
from repro.configs.paper_workloads import MMLayer, MMWorkload
from repro.core.analytical import AccelConfig, layer_latency
from repro.core.schedule import Mode, ScheduleProblem

MIN_FMUS = 3     # an MM layer needs at least A/B/C views live


def _tile_candidates(m: int, k: int, n: int, capacity: int
                     ) -> List[Tuple[int, int, int]]:
    """Candidate on-chip tile splits fitting A+B+C in `capacity` elements."""
    sizes = [64, 128, 256, 512, 1024]
    out = []
    for tm in sizes:
        if tm > 2 * m:
            continue
        for tk in sizes:
            if tk > 2 * k:
                continue
            for tn in sizes:
                if tn > 2 * n:
                    continue
                if tm * tk + tk * tn + tm * tn <= capacity:
                    out.append((min(tm, m), min(tk, k), min(tn, n)))
    if not out:
        out.append((min(64, m), min(64, k), min(64, n)))
    return sorted(set(out))


def enumerate_modes(layer: MMLayer, accel: AccelConfig,
                    platform: PlatformProfile, *, f_max: int, c_max: int,
                    max_modes: int = 16) -> List[Mode]:
    """Brute-force (cus, fmus, tile) for one layer; return Pareto modes."""
    cu_opts = [c for c in (1, 2, 4, 8, 16) if c <= min(accel.num_cus, c_max)]
    fmu_opts = [f for f in range(MIN_FMUS, min(accel.num_fmus, f_max) + 1)]
    cand: List[Mode] = []
    for cus in cu_opts:
        for fmus in fmu_opts:
            cap = fmus * accel.fmu_capacity
            best = None
            for tile in _tile_candidates(layer.m, layer.k, layer.n, cap):
                cfg = dataclasses.replace(accel, onchip_elems=cap,
                                          num_fmus=fmus)
                lb = layer_latency(cfg, platform, layer.m, layer.k, layer.n,
                                   num_cus=cus, tile_override=tile)
                if best is None or lb.total_s < best[0].total_s:
                    best = (lb, tile)
            assert best is not None
            cand.append(Mode(fmus=fmus, cus=cus, latency=best[0].total_s,
                             meta=best[1]))
    # Pareto prune: drop modes dominated in (fmus, cus, latency)
    cand.sort(key=lambda mo: (mo.latency, mo.fmus, mo.cus))
    kept: List[Mode] = []
    for mo in cand:
        if not any(k.fmus <= mo.fmus and k.cus <= mo.cus and
                   k.latency <= mo.latency for k in kept):
            kept.append(mo)
    return kept[:max_modes]


def build_problem(workload: MMWorkload, accel: AccelConfig,
                  platform: PlatformProfile, *, f_max: int, c_max: int,
                  max_modes: int = 16) -> ScheduleProblem:
    """Stage 1 for a whole workload DAG -> a Stage-2 scheduling problem."""
    deps = tuple(tuple(l.deps) for l in workload.layers)
    modes = tuple(
        tuple(enumerate_modes(l, accel, platform, f_max=f_max, c_max=c_max,
                              max_modes=max_modes))
        for l in workload.layers)
    return ScheduleProblem(deps=deps, modes=modes, f_max=f_max, c_max=c_max)
