"""jit'd public wrappers for filco_mm with CPU fallback.

On TPU the Pallas kernel runs natively; elsewhere (this CPU container) it
runs in interpret mode for correctness work, or falls back to the jnp oracle
for speed (``impl='ref'``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.filco_mm import kernel as K
from repro.kernels.filco_mm import ref as R


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def flex_mm(a_buf, b_buf, m, k, n, *, bm=128, bk=128, bn=128, impl="auto"):
    """Flexible matmul; (m,k,n) may be traced int32 scalars."""
    dims = jnp.asarray(jnp.stack([jnp.asarray(m, jnp.int32),
                                  jnp.asarray(k, jnp.int32),
                                  jnp.asarray(n, jnp.int32)]))
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.flex_mm_ref(a_buf, b_buf, dims)
    interpret = impl == "interpret" or not _on_tpu()
    return K.flex_mm(a_buf, b_buf, dims, bm=bm, bk=bk, bn=bn,
                     interpret=interpret)


def static_mm(a_buf, b_buf, *, bm=128, bk=128, bn=128, impl="auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.static_mm_ref(a_buf, b_buf)
    interpret = impl == "interpret" or not _on_tpu()
    return K.static_mm(a_buf, b_buf, bm=bm, bk=bk, bn=bn, interpret=interpret)
