"""filco_mm — runtime-flexible tiled matmul (FILCO §2.2 "Flexible
Computation Parallelism", re-derived for the TPU MXU).

The paper's insight: pack an *atomic* matmul (2x8x8 on AIE) inside nested
loops whose bounds arrive at runtime through a few bytes of instruction, so
one compiled kernel serves every operand shape with no padded (invalid)
compute and no recompilation (= bitstream reload).

TPU adaptation: the atom is one MXU macro-op (8x128 @ 128x128); the
"instruction" is a scalar-prefetch operand (SMEM) carrying the *valid*
(m, k, n); the "nested loops with dynamic boundaries" are the Pallas grid
over the maximum buffer shape, with every grid step *predicated off* when its
tile lies outside the valid bounds (``pl.when``).  Edge tiles mask the
partial rows/cols with iota masks, exactly like the paper's flexible tile
sizes in Fig. 3(b).

A "static" reference kernel (the CHARM-style baseline) computes the full
padded buffer unconditionally; the fig8 benchmark counts issued atoms of
both to reproduce the single-kernel efficiency curve.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Atom shape of the MXU macro-op this kernel predicates on (see
# repro.common.platform.TPU_V5E.atom_shape).
ATOM_M, ATOM_K, ATOM_N = 8, 128, 128


def _flex_mm_kernel(dims_ref, a_ref, b_ref, o_ref, acc_ref, *, bm, bk, bn,
                    nk_grid):
    """Grid: (M_max/bm, N_max/bn, K_max/bk); dims_ref (SMEM) = [m, k, n]."""
    i, j, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    m, k, n = dims_ref[0], dims_ref[1], dims_ref[2]

    # Valid-tile predicate: the FILCO runtime loop bound.  Tiles fully
    # outside (m, k, n) issue no MXU work at all.
    row_live = i * bm < m
    col_live = j * bn < n
    red_live = kk * bk < k
    live = row_live & col_live & red_live

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _compute():
        a = a_ref[...]
        b = b_ref[...]
        # mask the partial reduction tile (edge of k)
        kid = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
        a = jnp.where(kid < k, a, 0)
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(kk == nk_grid - 1)
    def _finalize():
        rid = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        cid = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
        mask = (rid < m) & (cid < n)
        o_ref[...] = jnp.where(mask, acc_ref[...], 0).astype(o_ref.dtype)


def _static_mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk_grid):
    """CHARM-style static baseline: computes the full padded buffer."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(kk == nk_grid - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def flex_mm(a_buf, b_buf, dims, *, bm: int = 128, bk: int = 128, bn: int = 128,
            interpret: bool = False):
    """Flexible matmul over padded operand buffers.

    a_buf: (M_max, K_max); b_buf: (K_max, N_max); dims: (3,) int32 = [m,k,n].
    Returns (M_max, N_max): out[:m, :n] = a[:m, :k] @ b[:k, :n], zeros
    elsewhere.  One compiled program serves *all* (m, k, n) <= buffer shape —
    reconfiguration cost is writing 12 bytes (cf. bitstream reload / XLA
    recompile).
    """
    Mx, Kx = a_buf.shape
    Kx2, Nx = b_buf.shape
    assert Kx == Kx2
    assert Mx % bm == 0 and Kx % bk == 0 and Nx % bn == 0
    grid = (Mx // bm, Nx // bn, Kx // bk)
    kernel = functools.partial(_flex_mm_kernel, bm=bm, bk=bk, bn=bn,
                               nk_grid=grid[2])
    # PrefetchScalarGridSpec: the (m,k,n) "instruction" lands in SMEM before
    # any tile is fetched — the TPU analogue of FILCO's instruction decode
    # preceding FMU/CU execution.
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk, dims: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk, dims: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, dims: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mx, Nx), a_buf.dtype),
        interpret=interpret,
    )(dims, a_buf, b_buf)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def static_mm(a_buf, b_buf, *, bm: int = 128, bk: int = 128, bn: int = 128,
              interpret: bool = False):
    """Static padded matmul over the full buffers (baseline)."""
    Mx, Kx = a_buf.shape
    _, Nx = b_buf.shape
    grid = (Mx // bm, Nx // bn, Kx // bk)
    kernel = functools.partial(_static_mm_kernel, nk_grid=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mx, Nx), a_buf.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_buf, b_buf)


def atoms_issued_flexible(m: int, k: int, n: int, *, bm=128, bk=128, bn=128):
    """MXU atoms actually issued by the flexible kernel for valid dims
    (m,k,n): live tiles only, each bm x bk x bn tile = (bm/8)(bk/128)(bn/128)
    atoms.  Edge tiles still issue whole atoms (MXU granularity) — the same
    quantization the paper's 2x8x8 atom imposes (Fig. 8 x-axis granularity)."""
    ceil = lambda x, a: -(-x // a)
    live_tiles = ceil(m, bm) * ceil(k, bk) * ceil(n, bn)
    atoms_per_tile = (bm // ATOM_M) * (bk // ATOM_K) * (bn // ATOM_N)
    return live_tiles * atoms_per_tile


def atoms_issued_static(Mx: int, Kx: int, Nx: int, *, bm=128, bk=128, bn=128):
    """Atoms issued by the static baseline: the whole padded buffer."""
    return atoms_issued_flexible(Mx, Kx, Nx, bm=bm, bk=bk, bn=bn)
