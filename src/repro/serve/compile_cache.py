"""Compatibility shim: the AOT executable cache moved to
``repro.workloads.compile_cache`` when the workload-class subsystem landed —
the cache is shared fabric-wide across heterogeneous tenant engines, so it
lives with the engines, below the serving layer."""
from repro.workloads.compile_cache import ExecutableCache

__all__ = ["ExecutableCache"]
