"""Logical-axis partitioning: the bridge between model code and meshes.

Model code annotates every parameter with *logical* axis names
("embed", "heads", "mlp", "expert", ...).  A :class:`ShardingRules` maps
logical names to physical mesh axes.  This is how one model definition runs
unchanged on a single CPU device, the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh — only the rules change.

This mirrors FILCO's split between *static parameters* (mesh topology, fixed
before launch) and *runtime parameters* (which sharding/mode each layer uses,
chosen by the DSE and applied per-layer at dispatch time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# A logical sharding annotation: tuple of logical axis names (or None) per dim.
LogicalSpec = Tuple[Optional[Union[str, Tuple[str, ...]]], ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names -> physical mesh axis name(s) (or None)."""

    rules: Mapping[str, Optional[Union[str, Tuple[str, ...]]]]

    def physical(self, logical: Optional[Union[str, Tuple[str, ...]]]):
        if logical is None:
            return None
        if isinstance(logical, tuple):
            out: list = []
            for l in logical:
                p = self.rules.get(l)
                if p is None:
                    continue
                out.extend(p if isinstance(p, tuple) else (p,))
            if not out:
                return None
            return tuple(out) if len(out) > 1 else out[0]
        p = self.rules.get(logical)
        return p

    def spec(self, logical_spec: LogicalSpec) -> P:
        return P(*(self.physical(ax) for ax in logical_spec))

    def shard(self, mesh: Mesh, logical_spec: LogicalSpec) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_spec))


# ---------------------------------------------------------------------------
# Default rule sets.  Axis vocabulary used across the model zoo:
#   batch        — global batch                       -> (pod, data)
#   act_seq      — residual-stream sequence dim       -> model in training
#                  (Megatron-style sequence parallelism keeps the 80-layer
#                  remat-saved residuals within HBM; DESIGN.md §6)
#   kv_seq       — KV-cache sequence dim (decode)     -> model (split-K decode)
#   embed        — weight d_model dim                 -> data under FSDP
#                  (ZeRO-3: params/grads/opt-state sharded over data; XLA
#                  inserts the per-layer all-gather / reduce-scatter)
#   vocab        — embedding / logits vocab dim       -> model
#   heads        — attention query heads              -> model
#   kv_heads     — attention kv heads                 -> None (replicated; GQA
#                  kv<=8 never divides a 16-wide model axis — K/V are expanded
#                  to q-heads inside the attention block instead)
#   mlp          — dense FFN hidden dim               -> model
#   expert       — MoE expert dim                     -> data (train EP) /
#                                                        model (serve EP)
#   expert_embed — expert weight d_model dim          -> None / data
#   expert_mlp   — expert FFN hidden dim              -> model / None
#   ssm_inner    — mamba inner dim                    -> model
#   lora         — MLA latent dim                     -> None
# ---------------------------------------------------------------------------

def train_rules(fsdp: bool = True, sequence_parallel: bool = True) -> ShardingRules:
    """Training: DP over (pod,data); TP over model; FSDP(ZeRO-3) over data;
    expert-parallelism over data; sequence-parallel residual stream."""
    return ShardingRules(
        rules={
            "batch": ("pod", "data"),
            "act_seq": "model" if sequence_parallel else None,
            "kv_seq": None,
            "embed": "data" if fsdp else None,
            "vocab": "model",
            "heads": "model",
            "kv_heads": None,
            "mlp": "model",
            "expert": "data",
            "expert_embed": None,
            "expert_mlp": "model",
            "ssm_inner": "model",
            "layers": None,
            "conv_w": None,
            "state": None,
            "lora": None,
        }
    )


def serve_rules(fsdp_weights: bool = False) -> ShardingRules:
    """Serving: batch over (pod,data); TP over model; KV cache split-K over
    model on the sequence dim (mandatory for MQA, used uniformly).

    fsdp_weights: additionally shard weight d_model dims over data — required
    when bf16 weights / model-axis exceed HBM (qwen1.5-110b, arctic-480b);
    XLA lowers the contractions to partial-sum + all-reduce over data (2-D
    tensor parallelism), the right trade at decode where activations are tiny.
    """
    return ShardingRules(
        rules={
            "batch": ("pod", "data"),
            "act_seq": None,
            "kv_seq": "model",
            "embed": "data" if fsdp_weights else None,
            "vocab": "model",
            "heads": "model",
            "kv_heads": None,
            "mlp": "model",
            "expert": "model",
            "expert_embed": "data" if fsdp_weights else None,
            "expert_mlp": None,
            "ssm_inner": "model",
            "layers": None,
            "conv_w": None,
            "state": None,
            "lora": None,
        }
    )


def single_device_rules() -> ShardingRules:
    return ShardingRules(rules={})


# ---------------------------------------------------------------------------
# Annotation plumbing: models return pytrees of (array, logical_spec) at init
# time via ``Annotated`` leaves; helpers below strip/extract them.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Annotated:
    """An array leaf carrying its logical sharding annotation."""

    value: Any
    logical: LogicalSpec

    def tree_flatten(self):
        return (self.value,), self.logical

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def strip(tree):
    """Annotated pytree -> plain array pytree."""
    return jax.tree.map(
        lambda x: x.value if isinstance(x, Annotated) else x,
        tree,
        is_leaf=lambda x: isinstance(x, Annotated),
    )


def logical_specs(tree):
    """Annotated pytree -> pytree of LogicalSpec (None for unannotated)."""
    return jax.tree.map(
        lambda x: x.logical if isinstance(x, Annotated) else None,
        tree,
        is_leaf=lambda x: isinstance(x, Annotated),
    )


def physical_specs(tree, rules: ShardingRules):
    """Annotated pytree -> pytree of PartitionSpec."""
    return jax.tree.map(
        lambda x: rules.spec(x.logical) if isinstance(x, Annotated) else P(),
        tree,
        is_leaf=lambda x: isinstance(x, Annotated),
    )


def shardings(tree, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        physical_specs(tree, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain(x, rules: ShardingRules, logical: LogicalSpec):
    """In-graph sharding constraint by logical axes (no-op without mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(logical))
    except (ValueError, RuntimeError):
        return x


def validate_divisibility(shape: Sequence[int], spec: P, mesh: Mesh) -> bool:
    """True iff every sharded dim divides evenly on the mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes[a] for a in axes]))
        if dim % total:
            return False
    return True


def sanitize_spec(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes a PartitionSpec references that this mesh lacks (the
    'pod' axis on single-pod meshes, and on composed sub-meshes)."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in names)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def fit_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """sanitize_spec + divisibility: drop sharded axes whose product does not
    evenly divide the array dim (hymba's 25 heads on a 16-wide model axis,
    batch=1 long-context cells, odd vocabularies).  Explicit NamedShardings
    must divide evenly; replication is the graceful degradation, and the
    roofline table shows its cost."""
    spec = sanitize_spec(spec, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))

    def fit(dim, entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        if not kept:
            return None
        return tuple(kept) if len(kept) > 1 else kept[0]

    return P(*(fit(d, e) for d, e in zip(shape, entries)))


def tp_submesh(mesh: Optional[Mesh], degree: Optional[int],
               axis: str = "model") -> Optional[Mesh]:
    """Restrict a (sub-)mesh's ``axis`` to its first ``degree`` columns.

    The serving-side DSE Stage 1 optimizes each tenant's tensor-parallel
    degree *independently of its CU grant*: a tenant whose analytical
    all-reduce cost outweighs the bandwidth gain runs at ``tp < cus`` on a
    slice of its granted sub-accelerator (the remaining columns idle rather
    than slow the step down).  ``degree`` of None/0, or >= the axis size,
    returns the mesh unchanged; meshes without ``axis`` are returned as-is.
    """
    if mesh is None or not degree or axis not in mesh.axis_names:
        return mesh
    ax = mesh.axis_names.index(axis)
    if degree >= mesh.devices.shape[ax]:
        return mesh
    idx = [slice(None)] * mesh.devices.ndim
    idx[ax] = slice(0, degree)
    return Mesh(mesh.devices[tuple(idx)], mesh.axis_names)


def replica_submesh(mesh: Optional[Mesh], index: int, replicas: int,
                    axis: str = "model") -> Optional[Mesh]:
    """Slice ``mesh`` into ``replicas`` disjoint equal-width tiles along
    ``axis`` and return tile ``index`` (the data-parallel counterpart of
    :func:`tp_submesh`: a ``ReplicaGroup`` runs one independent engine per
    tile).  Columns past ``replicas * (size // replicas)`` are left idle
    when the axis does not divide evenly; ``replicas`` <= 1 returns the
    mesh unchanged, and meshes without ``axis`` are returned as-is."""
    if mesh is None or replicas <= 1 or axis not in mesh.axis_names:
        return mesh
    ax = mesh.axis_names.index(axis)
    width = mesh.devices.shape[ax] // replicas
    if width < 1:
        raise ValueError(
            f"cannot tile {mesh.devices.shape[ax]} '{axis}' columns into "
            f"{replicas} replica slices")
    if not 0 <= index < replicas:
        raise ValueError(f"replica index {index} out of range for "
                         f"{replicas} replicas")
    idx = [slice(None)] * mesh.devices.ndim
    idx[ax] = slice(index * width, (index + 1) * width)
    return Mesh(mesh.devices[tuple(idx)], mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """The sharding-relevant skeleton of a pytree — treedef plus per-leaf
    (shape, dtype, logical spec) — captured once from an annotated tree and
    reusable for any target mesh after the values have been stripped.

    This is what lets a live serving engine recompute NamedShardings for an
    arbitrary composed sub-mesh (grow/shrink/unify) without carrying the
    Annotated wrappers through the hot path: `shardings(mesh, rules)` fits
    every leaf's logical spec to the mesh (axis filtering + divisibility
    fallback to replication) and `avals(mesh, rules)` produces the
    ShapeDtypeStructs an ahead-of-time lowering needs.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    logicals: Tuple[Optional[LogicalSpec], ...]

    @classmethod
    def of(cls, tree) -> "ShardingPlan":
        leaves, treedef = jax.tree_util.tree_flatten(
            tree, is_leaf=lambda x: isinstance(x, Annotated))
        shapes, dtypes, logicals = [], [], []
        for leaf in leaves:
            val = leaf.value if isinstance(leaf, Annotated) else leaf
            shapes.append(tuple(getattr(val, "shape", ())))
            dtypes.append(np.dtype(getattr(val, "dtype", np.float32)))
            logicals.append(leaf.logical if isinstance(leaf, Annotated)
                            else None)
        return cls(treedef, tuple(shapes), tuple(dtypes), tuple(logicals))

    @property
    def annotated(self) -> bool:
        return any(l is not None for l in self.logicals)

    def specs(self, mesh: Mesh, rules: ShardingRules) -> list:
        return [fit_spec(rules.spec(l) if l is not None else P(), shape, mesh)
                for shape, l in zip(self.shapes, self.logicals)]

    def shardings(self, mesh: Mesh, rules: ShardingRules):
        """Pytree of NamedShardings on `mesh` (matches the stripped tree)."""
        return self.treedef.unflatten(
            [NamedSharding(mesh, s) for s in self.specs(mesh, rules)])

    def avals(self, mesh: Optional[Mesh], rules: Optional[ShardingRules]):
        """Pytree of ShapeDtypeStructs (with shardings when mesh is given)
        for ahead-of-time lowering."""
        if mesh is None:
            leaves = [jax.ShapeDtypeStruct(s, d)
                      for s, d in zip(self.shapes, self.dtypes)]
        else:
            rules = rules or ShardingRules(rules={})
            leaves = [jax.ShapeDtypeStruct(s, d, sharding=NamedSharding(mesh, p))
                      for s, d, p in zip(self.shapes, self.dtypes,
                                         self.specs(mesh, rules))]
        return self.treedef.unflatten(leaves)
