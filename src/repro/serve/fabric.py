"""Real-time recomposition controller — the serving-side face of FILCO's
"reconfigured in real-time and flexibly composed into a unified or multiple
independent accelerators" (paper §1, §2.1).

A :class:`ComposedServer` owns the full device mesh.  Each tenant runs one
continuous-batching :class:`~repro.serve.engine.ServeEngine` on a
:class:`~repro.core.composer.MeshComposer` sub-accelerator, tensor-parallel
over its sub-mesh's model axis (``serve_engine_rules``), so a tenant's
measured tokens/s actually tracks the CUs it holds.  Between decode steps
the controller samples per-tenant load (queue depth, owed decode work, arena
pressure) and asks a policy — by default the analytical model driving the
DSE Stage-2 search — for a new CU split.  When the predicted gain clears the
hysteresis threshold it *live-recomposes*: the affected tenants' params and
pooled decode caches are reshard (sharded→sharded device_put) onto their new
sub-meshes while unaffected tenants keep their exact devices (delta
recomposition).

Reconfiguration cost is attacked on both ends, mirroring the paper's
real-time story: state migration is a ~10 ms device_put, and the dominant
post-recomposition XLA recompile (0.7-2.3 s measured cold) is hoisted off
the serving path by pre-compiling the target composition's decode/prefill
executables *before* the switch commits (``warm_compile``), optionally in a
background thread (``prewarm_async``) so compilation overlaps serving.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
import math
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.common.platform import TPU_V5E, PlatformProfile
from repro.configs import get_config, get_reduced
from repro.configs.base import ModelConfig
from repro.core.analytical import AccelConfig, layer_latency
from repro.core.composer import MeshComposer
from repro.distribution import partitioning as part
from repro.models import build_model
from repro.serve.engine import ServeConfig, ServeEngine


def serve_engine_rules() -> part.ShardingRules:
    """serve_rules() tuned for the decode engine's composed sub-meshes.

    Two deltas vs the static-analysis serving rules: the KV cache shards
    over kv *heads* rather than split-K sequence (a dynamic-position scatter
    into a sequence-sharded cache forces SPMD to rematerialize the whole
    cache every step), and head counts that don't divide a given sub-mesh
    fall back to replication per-leaf at reshard time (fit_spec), so the
    same rules serve a 1-CU and an 8-CU composition.
    """
    rules = dict(part.serve_rules().rules)
    rules["kv_seq"] = None
    rules["kv_heads"] = "model"
    return part.ShardingRules(rules=rules)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant model co-resident on the fabric."""

    name: str
    arch: str                        # architecture registry id
    reduced: bool = True
    serve: ServeConfig = ServeConfig()
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TenantLoad:
    """Observed load signals the policy decides on."""

    pending_tokens: int              # decode steps of work owed
    queue_depth: int                 # requests awaiting admission
    active: int                      # live decode slots
    arena_utilization: float         # KV arena pressure, 0..1


@dataclasses.dataclass(frozen=True)
class RecompositionEvent:
    """One applied recomposition, for logs/benchmarks."""

    step: int
    sizes_before: Dict[str, int]
    sizes_after: Dict[str, int]
    moved: Tuple[str, ...]
    unchanged: Tuple[str, ...]
    parked: Tuple[str, ...]
    seconds: float                   # state migration (device_put) only
    reason: str
    # moved tenant -> wall time of its first step on the new composition;
    # with a cold executable cache this is where the XLA recompile stall
    # lands — filled in by ComposedServer.step()
    post_step_seconds: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # ahead-of-time compilation performed before the switch committed
    warm_compile_seconds: float = 0.0
    warm_builds: int = 0             # cold executables compiled while warming
    overlapped: bool = False         # warmed in the background thread


# ---------------------------------------------------------------------------
# policy: Stage-2-style split search on the analytical model
# ---------------------------------------------------------------------------

class AnalyticalPolicy:
    """Chooses a CU split by pricing each tenant's decode step on candidate
    sub-accelerator design points with the analytical latency model (the same
    machinery DSE Stage 2 schedules with, §3.1) and minimizing the predicted
    makespan of the owed work.

    Hysteresis: a new split is only worth a live recomposition when the
    predicted speedup clears ``min_gain`` — resharding has a real cost
    (device_put + one warm compile per new composition).
    """

    def __init__(self, platform: PlatformProfile = TPU_V5E,
                 min_gain: float = 1.25):
        self.platform = platform
        self.min_gain = min_gain
        self._cost_cache: Dict[Tuple[str, int, int], float] = {}

    # -- per-tenant decode-step cost on a c-CU sub-accelerator -------------
    def step_cost(self, cfg: ModelConfig, batch: int, cus: int) -> float:
        if cus <= 0:
            return float("inf")
        # full and reduced configs share a name: key on the priced dims too
        key = (cfg.name, cfg.num_layers, cfg.d_model, max(batch, 1), cus)
        if key not in self._cost_cache:
            accel = AccelConfig(
                name=f"tpu-sub{cus}", num_cus=cus,
                aies_per_cu=self.platform.num_compute_units,
                onchip_elems=cus * (self.platform.onchip_bytes // 4),
                num_fmus=max(cus, 1), fp=True, fmv=True, fmf=True)
            d = cfg.d_model
            # dominant decode GEMMs per layer: attention out/in (d x d) and
            # the MLP pair (d x d_ff), batched over live slots
            lb_attn = layer_latency(accel, self.platform,
                                    max(batch, 1), d, d)
            lb_mlp = layer_latency(accel, self.platform,
                                   max(batch, 1), d, cfg.d_ff or 4 * d)
            self._cost_cache[key] = cfg.num_layers * (
                2 * lb_attn.total_s + 2 * lb_mlp.total_s)
        return self._cost_cache[key]

    # -- split search ------------------------------------------------------
    def decide(self, loads: Mapping[str, TenantLoad],
               cfgs: Mapping[str, ModelConfig],
               current: Mapping[str, int],
               num_cus: int) -> Tuple[Dict[str, int], str]:
        """Return (target sizes, reason).  Tenants with no load are parked
        (size 0); returning ``current`` means "leave the fabric alone"."""
        # arena pressure inflates demand: a hot arena means queued work the
        # pending-token count can't see yet
        demand = {t: ld.pending_tokens * (1.0 + ld.arena_utilization)
                  for t, ld in loads.items()}
        busy = [t for t, d in demand.items() if d > 0]
        if not busy:
            return dict(current), "idle"

        def makespan(sizes: Mapping[str, int]) -> float:
            return max(demand[t] * self.step_cost(
                cfgs[t], loads[t].active or 1, sizes.get(t, 0))
                for t in busy)

        best_sizes, best_cost = None, float("inf")
        for split in _candidate_splits(num_cus, busy, demand):
            sizes = dict(zip(busy, split))
            cost = makespan(sizes)
            if cost < best_cost:
                best_sizes, best_cost = sizes, cost
        assert best_sizes is not None

        cur_cost = makespan(current)
        if cur_cost == float("inf"):
            return best_sizes, "admit"          # a parked tenant got work
        if cur_cost / max(best_cost, 1e-12) >= self.min_gain:
            if len(busy) == 1:
                return best_sizes, "unify"
            return best_sizes, "rebalance"
        return dict(current), "hysteresis"


def _compositions(total: int, parts: int):
    """All ways to write ``total`` as ``parts`` positive integers."""
    if parts == 1:
        yield (total,)
        return
    for cuts in itertools.combinations(range(1, total), parts - 1):
        prev, out = 0, []
        for c in cuts:
            out.append(c - prev)
            prev = c
        out.append(total - prev)
        yield tuple(out)


# exhaustive Stage-2-style enumeration is C(num_cus-1, tenants-1): fine on a
# board-scale fabric, explosive on a pod.  Past this budget, fall back to a
# demand-proportional water-filling split (the argmax of the monotone
# makespan model in the common case, computed in O(cus x tenants)).
MAX_ENUMERATED_SPLITS = 20_000


def _candidate_splits(num_cus: int, busy: Sequence[str],
                      demand: Mapping[str, float]):
    if math.comb(num_cus - 1, len(busy) - 1) <= MAX_ENUMERATED_SPLITS:
        yield from _compositions(num_cus, len(busy))
        return
    total = sum(demand[t] for t in busy)
    shares = [max(1, int(num_cus * demand[t] / total)) for t in busy]
    spare = num_cus - sum(shares)
    order = sorted(range(len(busy)), key=lambda i: -demand[busy[i]])
    i = 0
    while spare != 0:                    # hand leftovers to (or claw back
        j = order[i % len(order)]        # from) the most-loaded tenants
        step = 1 if spare > 0 else (-1 if shares[j] > 1 else 0)
        shares[j] += step
        spare -= step
        i += 1
    yield tuple(shares)


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

class ComposedServer:
    """Multi-tenant serving on one composable fabric with live, delta
    recomposition between decode steps.

    tp: shard each tenant's engine (params + pooled KV cache) over its
        sub-mesh with ``serve_engine_rules`` so granted CUs buy measured
        tokens/s; off -> replicated engines (bit-identical resharding).
    warm: pre-compile a target composition's executables before committing
        a recomposition, so the first post-move step skips the XLA stall.
    prewarm_async: compile candidate compositions in a background thread
        while the old composition keeps serving; the switch commits on a
        later autoscale tick once the executables are ready.
    """

    def __init__(self, mesh, tenants: Sequence[TenantSpec], *,
                 policy: Optional[AnalyticalPolicy] = None,
                 decide_every: int = 4, cu_axis: str = "model",
                 tp: bool = True, warm: bool = True,
                 prewarm_async: bool = False):
        self.composer = MeshComposer(mesh, cu_axis=cu_axis)
        self.policy = policy
        self.decide_every = decide_every
        self.rules = serve_engine_rules() if tp else None
        self.warm = warm
        self.prewarm_async = prewarm_async
        self.specs = {t.name: t for t in tenants}
        self.events: List[RecompositionEvent] = []
        self.step_seconds: Dict[str, List[float]] = {t.name: [] for t in tenants}
        self._stall_probe: Dict[str, RecompositionEvent] = {}
        self._step_no = 0
        self._tokens_emitted: Dict[str, int] = {t.name: 0 for t in tenants}
        self._executor: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._pending_prewarm: Optional[Tuple[Dict[str, int], str, list]] = None

        # initial composition: equal shares, remainder to the first tenants
        n = len(tenants)
        if n > self.composer.num_cus:
            raise ValueError(
                f"{n} tenants need at least {n} CUs; the fabric has "
                f"{self.composer.num_cus} (on CPU, fake more host devices "
                f"with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        base, extra = divmod(self.composer.num_cus, n)
        sizes = {t.name: base + (1 if i < extra else 0)
                 for i, t in enumerate(tenants)}
        self.subs, _ = self.composer.recompose({}, sizes)

        self.cfgs: Dict[str, ModelConfig] = {}
        self.engines: Dict[str, ServeEngine] = {}
        for spec in tenants:
            cfg = (get_reduced(spec.arch) if spec.reduced
                   else get_config(spec.arch))
            model = build_model(cfg)
            params = model.init(jax.random.key(spec.seed))  # annotated: TP
            self.cfgs[spec.name] = cfg
            self.engines[spec.name] = ServeEngine(
                model, params, spec.serve, mesh=self.subs[spec.name],
                rules=self.rules)

    # ------------------------------------------------------------------
    def submit(self, tenant: str, tokens, max_new_tokens: int = 16) -> int:
        return self.engines[tenant].submit(tokens, max_new_tokens)

    def sizes(self) -> Dict[str, int]:
        return {t: len(self.subs[t].cu_ids) if t in self.subs else 0
                for t in self.engines}

    def loads(self) -> Dict[str, TenantLoad]:
        return {t: TenantLoad(eng.pending_tokens(), eng.queue_depth,
                              eng.active_count, eng.arena.utilization())
                for t, eng in self.engines.items()}

    # ------------------------------------------------------------------
    def step(self) -> Dict[str, List[Tuple[int, int]]]:
        """One fabric iteration: step every composed (non-parked) tenant,
        then maybe recompose.  Returns per-tenant emitted (rid, token)."""
        emitted = {}
        for t, eng in self.engines.items():
            if t not in self.subs:
                continue                      # parked: no CUs this interval
            probe = self._stall_probe.pop(t, None)
            busy = eng.has_work
            q0 = eng.queue_depth
            t0 = time.monotonic()
            out = eng.step()
            if probe is not None:
                # pipelined dispatch returns before the step executes; the
                # probed post-move step must cover the whole step (compile
                # when cold + execution), not just the async dispatch
                jax.block_until_ready(eng.cache)
            dt = time.monotonic() - t0
            if probe is not None:
                probe.post_step_seconds[t] = dt
            elif busy and eng.queue_depth == q0:
                # decode percentiles only: idle no-op steps would deflate
                # them; admission steps (blocking prefill) and probed
                # full-sync steps would inflate them
                times = self.step_seconds[t]
                times.append(dt)
                if len(times) > 10_000:
                    del times[:5_000]
            self._tokens_emitted[t] += len(out)
            if out:
                emitted[t] = out
        self._step_no += 1
        if (self.policy is not None and self.decide_every > 0
                and self._step_no % self.decide_every == 0):
            self.autoscale()
        return emitted

    def autoscale(self) -> Optional[RecompositionEvent]:
        """Consult the policy; apply the recomposition it asks for.

        With ``prewarm_async`` the switch is two-phase: kick background
        compiles for the chosen composition, keep serving on the current
        one, and commit on a later tick once every executable is warm."""
        if self._pending_prewarm is not None:
            target, reason, futures = self._pending_prewarm
            if not all(f.done() for f in futures):
                return None               # still compiling in the background
            self._pending_prewarm = None
            for f in futures:
                f.result()                # surface background build errors
            if self._normalized(target) == self._normalized(self.sizes()):
                return None
            return self.recompose(target, reason=reason, overlapped=True)

        target, reason = self.policy.decide(
            self.loads(), self.cfgs, self.sizes(), self.composer.num_cus)
        target = {t: s for t, s in target.items() if s > 0}
        if target == self._normalized(self.sizes()):
            return None
        if self.warm and self.prewarm_async:
            new_subs, delta = self.composer.recompose(self.subs, target)
            futures = [self._pool().submit(self.engines[t].warm_compile,
                                           new_subs[t])
                       for t in delta.moved + delta.admitted]
            self._pending_prewarm = (target, reason, futures)
            return None
        return self.recompose(target, reason=reason)

    @staticmethod
    def _normalized(sizes: Mapping[str, int]) -> Dict[str, int]:
        return {t: s for t, s in sizes.items() if s > 0}

    def _pool(self) -> concurrent.futures.ThreadPoolExecutor:
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="prewarm")
        return self._executor

    def recompose(self, target_sizes: Mapping[str, int], *,
                  reason: str = "manual",
                  overlapped: bool = False) -> RecompositionEvent:
        """Live recomposition: grow/shrink/admit/park tenants.  Only moved
        tenants pay a state migration; unchanged ones keep their devices.
        With warming on, the target composition's executables are compiled
        before any state moves, so the post-move step is stall-free."""
        before = self.sizes()
        new_subs, delta = self.composer.recompose(self.subs, target_sizes)
        touched = delta.moved + delta.admitted
        warm_s, warm_builds = 0.0, 0
        if self.warm:
            w0 = time.monotonic()
            for t in touched:
                warm_builds += self.engines[t].warm_compile(new_subs[t])
            warm_s = time.monotonic() - w0
        t0 = time.monotonic()
        for t in touched:
            eng = self.engines[t]
            eng.reshard_to(new_subs[t])
            jax.block_until_ready((eng.params, eng.cache))
        self.subs = new_subs
        seconds = time.monotonic() - t0
        event = RecompositionEvent(
            step=self._step_no, sizes_before=before, sizes_after=self.sizes(),
            moved=touched, unchanged=delta.unchanged,
            parked=delta.evicted, seconds=seconds, reason=reason,
            warm_compile_seconds=warm_s, warm_builds=warm_builds,
            overlapped=overlapped)
        for t in event.moved:
            self._stall_probe[t] = event
        self.events.append(event)
        return event

    def unify(self, tenant: str, *, reason: str = "unify"
              ) -> RecompositionEvent:
        """The monolithic composition: the whole fabric for one tenant."""
        return self.recompose({tenant: self.composer.num_cus}, reason=reason)

    # ------------------------------------------------------------------
    def pending(self) -> int:
        return sum(ld.pending_tokens for ld in self.loads().values())

    def drain(self, max_steps: int = 10_000) -> Dict[str, Dict[int, List[int]]]:
        """Step until every tenant's queue, slots and in-flight dispatches
        are empty; returns per-tenant {rid: tokens} for all requests seen."""
        for _ in range(max_steps):
            busy = [t for t, eng in self.engines.items() if eng.has_work]
            if not busy:
                break
            if any(t not in self.subs for t in busy) and self.policy is None:
                # no policy to re-admit a parked tenant: give it CUs back
                self.recompose({t: 0 for t in self.engines} |
                               {t: self.composer.num_cus // max(len(busy), 1)
                                for t in busy}, reason="drain")
            self.step()
        return self.results()

    def results(self) -> Dict[str, Dict[int, List[int]]]:
        return {t: eng.snapshot() for t, eng in self.engines.items()}

    def decode_step_ms(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant decode step latency percentiles (milliseconds)."""
        out = {}
        for t, times in self.step_seconds.items():
            if not times:
                continue
            arr = np.asarray(times) * 1e3
            out[t] = {"p50": round(float(np.percentile(arr, 50)), 3),
                      "p95": round(float(np.percentile(arr, 95)), 3),
                      "n": len(times)}
        return out

    def stats(self) -> Dict[str, object]:
        return {
            "steps": self._step_no,
            "tokens_emitted": dict(self._tokens_emitted),
            "recompositions": len(self.events),
            "recompose_seconds": [round(e.seconds, 4) for e in self.events],
            "warm_compile_seconds": [round(e.warm_compile_seconds, 4)
                                     for e in self.events],
            "reshards_per_tenant": {t: eng.reshard_count
                                    for t, eng in self.engines.items()},
            "compile_builds": {t: eng.compile_builds
                               for t, eng in self.engines.items()},
            "decode_step_ms": self.decode_step_ms(),
            "composition": {t: list(self.subs[t].cu_ids)
                            for t in self.subs},
        }
