"""FlexArena (FMU) tests: views never overlap, shape-agnostic storage (FMV),
role fungibility (FMF), device-side store/load roundtrips."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import arena as ar


def test_same_bytes_any_shape():
    """256x256 and 128x512 occupy identical storage (paper Fig. 4b)."""
    a = ar.FlexArena(capacity=256 * 256)
    v1 = a.alloc(256, 256)
    assert a.free == 0
    a.free_view(v1)
    v2 = a.alloc(128, 512)
    assert a.free == 0
    a.free_view(v2)


def test_static_padding_overhead():
    # static 256x256 buffer storing 128x512 wastes 50% (paper §2.3)
    waste = ar.FlexArena.static_padding_overhead((128, 512), (256, 256))
    assert waste == pytest.approx(0.5)
    assert ar.FlexArena.static_padding_overhead((256, 256), (256, 256)) == 0.0


def test_fmf_role_rebinding_and_fits():
    a = ar.FlexArena(capacity=1000)
    v = a.alloc(10, 50, ar.ROLE_WEIGHT)
    v = a.reshape_view(v, 25, 20, ar.ROLE_ACT)
    assert v.rows == 25 and v.role == ar.ROLE_ACT
    with pytest.raises(ar.AllocationError):
        a.reshape_view(v, 100, 100)
    assert a.fits([(10, 40), (5, 20)])
    assert not a.fits([(40, 40)])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 20), st.integers(1, 20)),
                min_size=1, max_size=12))
def test_views_never_overlap(shapes):
    a = ar.FlexArena(capacity=4096)
    views = []
    for r, c in shapes:
        try:
            views.append(a.alloc(r, c))
        except ar.AllocationError:
            break
    spans = sorted((v.offset, v.offset + v.size) for v in views)
    for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
        assert e1 <= s2, "views overlap"
    assert all(e <= a.capacity for _, e in spans)


def test_alignment():
    a = ar.FlexArena(capacity=10000, align=1024)
    v1 = a.alloc(10, 10)
    v2 = a.alloc(10, 10)
    assert v1.offset % 1024 == 0 and v2.offset % 1024 == 0


def test_device_store_load_roundtrip():
    a = ar.FlexArena(capacity=4096)
    buf = jnp.zeros(4096, jnp.float32)
    v1 = a.alloc(16, 32)
    v2 = a.alloc(8, 64)
    m1 = jnp.arange(16 * 32, dtype=jnp.float32).reshape(16, 32)
    m2 = -jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64)
    buf = ar.store_view(buf, v1, m1)
    buf = ar.store_view(buf, v2, m2)
    np.testing.assert_array_equal(ar.load_view(buf, v1), m1)
    np.testing.assert_array_equal(ar.load_view(buf, v2), m2)
    padded = ar.load_padded(buf, v2, (64, 64))
    np.testing.assert_array_equal(padded[:8, :64], m2)
    assert float(jnp.abs(padded[8:]).sum()) == 0.0


def test_fragmentation_first_fit():
    a = ar.FlexArena(capacity=100)
    v1 = a.alloc(1, 40)
    v2 = a.alloc(1, 40)
    a.free_view(v1)
    v3 = a.alloc(1, 30)           # fits in the freed gap
    assert v3.offset == 0
    assert a.utilization() == pytest.approx(0.7)
