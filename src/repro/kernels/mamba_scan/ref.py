"""Pure-jnp oracle for mamba_scan (materializes the state; small shapes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(x, dt, b, c, a_log, d):
    """x, dt: (B,S,D); b,c: (B,S,N); a_log: (D,N); d: (D,) -> (B,S,D)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dt32 = dt.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    deltaA = jnp.exp(dt32[..., None] * a)                       # (B,S,D,N)
    deltaBx = (dt32 * x32)[..., None] * b.astype(jnp.float32)[:, :, None, :]

    def step(h, inputs):
        da, dbx = inputs
        h = da * h + dbx
        return h, h

    B, S, D, N = deltaA.shape
    h0 = jnp.zeros((B, D, N), jnp.float32)
    _, hs = jax.lax.scan(step,
                         h0,
                         (deltaA.transpose(1, 0, 2, 3),
                          deltaBx.transpose(1, 0, 2, 3)))
    hs = hs.transpose(1, 0, 2, 3)                               # (B,S,D,N)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c.astype(jnp.float32))
    y = y + d.astype(jnp.float32) * x32
    return y.astype(x.dtype)
