"""granite-34b — deep/narrow dense code model, MQA [arXiv:2405.04324].

88L d_model=6144 48H (GQA kv=1 — multi-query) d_ff=24576 vocab=49152.
MQA means the KV cache cannot shard over heads: decode shards KV over the
*sequence* dim (flash-decoding split-K over the model axis), DESIGN.md §6.3.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    attn_type="full",
    act="gelu",
    glu=False,
)

REDUCED = ModelConfig(
    name="granite-reduced",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=256,
    vocab_size=256,
    head_dim=16,
    attn_type="full",
    act="gelu",
    glu=False,
)
