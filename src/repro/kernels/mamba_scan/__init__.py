from repro.kernels.mamba_scan.kernel import mamba_scan, mamba_step_kernel
from repro.kernels.mamba_scan.ops import mamba_step_fused, selective_scan_fused
from repro.kernels.mamba_scan.ref import mamba_scan_ref, mamba_step_ref

__all__ = ["mamba_scan", "mamba_step_kernel", "selective_scan_fused",
           "mamba_step_fused", "mamba_scan_ref", "mamba_step_ref"]
