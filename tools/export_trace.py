#!/usr/bin/env python
"""Validate and summarize a serving-fabric Perfetto trace.

The fabric's span tracer (``repro.obs.SpanTracer``) exports Chrome
trace-event JSON — ``ComposedServer.dump_trace(path)`` or the launcher's
``--trace-out``.  This tool checks the file actually loads in a trace
viewer (schema validation) and prints a per-span-name summary, so CI can
gate on "the run produced recompose spans" without opening a UI:

  python tools/export_trace.py trace.json
  python tools/export_trace.py trace.json --require-span recompose \
      --require-span decode_step

Exit codes: 0 valid (and all required spans present), 1 schema violation
or a required span missing, 2 unreadable input.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def validate(trace: dict) -> list:
    """Schema check: the subset of the Chrome trace-event format the
    tracer emits (complete events, microsecond timestamps).  Returns a
    list of violations (empty = loadable in chrome://tracing/Perfetto)."""
    errors = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errors.append(f"event {i}: not an object")
            continue
        if e.get("ph") != "X":
            errors.append(f"event {i}: ph={e.get('ph')!r} (expected 'X')")
        if not e.get("name"):
            errors.append(f"event {i}: missing name")
        for k in ("ts", "dur"):
            if not isinstance(e.get(k), (int, float)):
                errors.append(f"event {i}: {k} not numeric")
        for k in ("pid", "tid"):
            if not isinstance(e.get(k), int):
                errors.append(f"event {i}: {k} not an int")
        if len(errors) > 20:
            errors.append("... (truncated)")
            break
    return errors


def summarize(events: list) -> dict:
    """Per-span-name counts and total/max duration (milliseconds)."""
    out: dict = defaultdict(lambda: {"count": 0, "total_ms": 0.0,
                                     "max_ms": 0.0})
    for e in events:
        row = out[e["name"]]
        dur_ms = e["dur"] / 1e3
        row["count"] += 1
        row["total_ms"] = round(row["total_ms"] + dur_ms, 3)
        row["max_ms"] = round(max(row["max_ms"], dur_ms), 3)
    return dict(sorted(out.items()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace-event JSON file "
                                  "(ComposedServer.dump_trace output)")
    ap.add_argument("--require-span", action="append", default=[],
                    metavar="NAME",
                    help="fail unless at least one span with this name is "
                         "present (repeatable)")
    args = ap.parse_args(argv)

    try:
        with open(args.trace) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"unreadable trace {args.trace}: {e}", file=sys.stderr)
        return 2

    errors = validate(trace)
    if errors:
        for e in errors:
            print(f"schema: {e}", file=sys.stderr)
        return 1
    events = trace["traceEvents"]
    summary = summarize(events)
    missing = [n for n in args.require_span if n not in summary]
    print(json.dumps({"trace": args.trace, "events": len(events),
                      "spans": summary,
                      "required_missing": missing}, indent=1))
    if missing:
        print(f"missing required spans: {', '.join(missing)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
