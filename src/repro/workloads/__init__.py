"""Workload-class subsystem: heterogeneous tenant engines for the composed
serving fabric (transformer decode / SSM recurrent decode / encoder
embedding / enc-dec encode→decode), behind one :class:`Engine` protocol.
See ``base.py`` for the workload taxonomy, ``docs/workloads.md`` for the
protocol contract, and ``repro.serve.fabric`` for the fabric that mixes
them.
"""
from repro.workloads.base import (DECODE, ENCDEC, ENCODER, SSM,
                                  WORKLOAD_CLASSES, Engine, build_engine,
                                  length_buckets, pick_bucket,
                                  workload_class_of)
from repro.workloads.compile_cache import ExecutableCache
from repro.workloads.decode import DecodeEngine, Request, ServeConfig
from repro.workloads.encdec import EncDecEngine
from repro.workloads.encoder import EncodeJob, EncoderEngine
from repro.workloads.ssm import SSMEngine

__all__ = [
    "DECODE", "ENCDEC", "ENCODER", "SSM", "WORKLOAD_CLASSES",
    "Engine", "build_engine", "workload_class_of",
    "length_buckets", "pick_bucket",
    "DecodeEngine", "Request", "ServeConfig",
    "EncodeJob", "EncoderEngine",
    "EncDecEngine",
    "ExecutableCache",
    "SSMEngine",
]
