"""Mesh composer — FILCO's "composed into a unified or multiple independent
accelerators" (paper §1, §2.1) at pod scale.

On the Versal board, CUs behind a fully-connected stream topology are grouped
per layer by the scheduler.  On a TPU pod, the allocatable unit is a slice of
the device mesh: the composer partitions the mesh's model axis (and/or data
axis) into disjoint sub-meshes, one per concurrently-scheduled layer group or
per tenant model, and reunifies them when a large uniform workload wants the
monolithic accelerator (the CHARM-1 operating point is *one* composition of
the same fabric).

Pure device-array math + jax.sharding.Mesh construction; exercised by the
multi-tenant serving example and tested under a host-device-count subprocess.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.core.dse import ExecutionPlan, PlannedLayer


def mesh_fingerprint(mesh: Optional[Mesh]) -> Optional[Tuple]:
    """Identity of a composed mesh for executable caching: axis names, axis
    sizes, and the exact device ids.  Two recompositions that land a tenant
    on the same devices in the same arrangement share compiled executables;
    anything else (different CUs, different count) is a different program."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


@dataclasses.dataclass(frozen=True)
class SubAccelerator:
    """A composed accelerator: a contiguous slice of mesh CUs."""

    name: str
    cu_ids: Tuple[int, ...]          # columns of the model axis
    mesh: Optional[Mesh]             # None when constructed without devices

    def fingerprint(self) -> Optional[Tuple]:
        return mesh_fingerprint(self.mesh)


def split_axis(devices: np.ndarray, axis: int,
               sizes: Sequence[int]) -> List[np.ndarray]:
    """Split a device array along `axis` into blocks of the given sizes."""
    assert sum(sizes) == devices.shape[axis], (sizes, devices.shape)
    out = []
    start = 0
    for s in sizes:
        idx = [slice(None)] * devices.ndim
        idx[axis] = slice(start, start + s)
        out.append(devices[tuple(idx)])
        start += s
    return out


class MeshComposer:
    """Carves sub-accelerators out of a (data, model) or (pod, data, model)
    mesh.  CU granularity: one CU = one model-axis column (a data-parallel
    group of chips), matching the scheduler's C_max."""

    def __init__(self, mesh: Mesh, *, cu_axis: str = "model"):
        self.mesh = mesh
        self.cu_axis = cu_axis
        self.axis_index = mesh.axis_names.index(cu_axis)
        self.num_cus = mesh.devices.shape[self.axis_index]

    def unified(self) -> SubAccelerator:
        """The monolithic composition: all CUs as one accelerator."""
        return SubAccelerator("unified", tuple(range(self.num_cus)), self.mesh)

    def compose(self, sizes: Sequence[int],
                names: Optional[Sequence[str]] = None) -> List[SubAccelerator]:
        """Partition the CU axis into independent accelerators of the given
        sizes (must sum to the axis size)."""
        blocks = split_axis(self.mesh.devices, self.axis_index, sizes)
        out = []
        start = 0
        for i, (blk, size) in enumerate(zip(blocks, sizes)):
            name = names[i] if names else f"sub{i}"
            sub = Mesh(blk, self.mesh.axis_names)
            out.append(SubAccelerator(name, tuple(range(start, start + size)),
                                      sub))
            start += size
        return out

    def submesh(self, cu_ids: Sequence[int], name: str) -> SubAccelerator:
        """A sub-accelerator over an arbitrary (possibly non-contiguous) set
        of CU columns — delta recomposition routinely produces gaps."""
        ids = tuple(sorted(cu_ids))
        if not ids or ids[0] < 0 or ids[-1] >= self.num_cus:
            raise ValueError(f"cu_ids {ids} outside fabric of {self.num_cus}")
        idx = [slice(None)] * self.mesh.devices.ndim
        idx[self.axis_index] = list(ids)
        return SubAccelerator(name, ids,
                              Mesh(self.mesh.devices[tuple(idx)],
                                   self.mesh.axis_names))

    def recompose(self, current: Mapping[str, SubAccelerator],
                  target_sizes: Mapping[str, int],
                  ) -> Tuple[Dict[str, SubAccelerator], RecompositionDelta]:
        """Delta recomposition: grow/shrink/admit/evict tenants while leaving
        every unaffected tenant's device assignment untouched (the same
        SubAccelerator object, hence the same Mesh and the same devices).

        Returns the new composition plus the delta describing who moved.
        """
        cur_ids = {t: sub.cu_ids for t, sub in current.items()}
        new_ids = plan_recomposition(cur_ids, target_sizes, self.num_cus)
        delta = recomposition_delta(cur_ids, new_ids)
        out: Dict[str, SubAccelerator] = {}
        for t, ids in new_ids.items():
            if t in delta.unchanged:
                out[t] = current[t]
            else:
                out[t] = self.submesh(ids, t)
        return out, delta

    def for_plan(self, plan: ExecutionPlan) -> Dict[int, SubAccelerator]:
        """Map every planned layer's CU set to a sub-mesh.  Layers sharing a
        CU set share the sub-accelerator (ping-pong reuse across time)."""
        cache: Dict[Tuple[int, ...], SubAccelerator] = {}
        result: Dict[int, SubAccelerator] = {}
        for pl in plan.layers:
            key = tuple(sorted(pl.cu_ids))
            if key not in cache:
                if max(key) >= self.num_cus:
                    raise ValueError(
                        f"plan uses CU {max(key)} but mesh has {self.num_cus}")
                cache[key] = self.submesh(key, f"cus{key}")
            result[pl.layer] = cache[key]
        return result


@dataclasses.dataclass(frozen=True)
class RecompositionDelta:
    """Which tenants a recomposition touches.  ``unchanged`` tenants keep the
    exact same CU ids (their params/state never move); only ``moved`` and
    ``admitted`` tenants pay the resharding cost — FILCO's real-time
    reconfiguration is cheap precisely because the delta is partial."""

    unchanged: Tuple[str, ...]
    moved: Tuple[str, ...]
    admitted: Tuple[str, ...]
    evicted: Tuple[str, ...]


def plan_recomposition(current: Mapping[str, Sequence[int]],
                       target_sizes: Mapping[str, int],
                       num_cus: int) -> Dict[str, Tuple[int, ...]]:
    """Assign CU ids for ``target_sizes`` (tenant -> CU count), minimizing
    movement relative to ``current`` (tenant -> CU ids).

    Pure integer math (no devices): tenants whose size is unchanged keep
    their exact CU set when it doesn't collide with an earlier claim; resized
    tenants prefer CUs they already own, then the lowest free ids.  Tenants
    with target size 0 (parked/evicted) get no entry.  Deterministic in the
    iteration order of ``target_sizes``.
    """
    sizes = {t: s for t, s in target_sizes.items() if s > 0}
    total = sum(sizes.values())
    if total > num_cus:
        raise ValueError(f"target sizes {dict(sizes)} need {total} CUs, "
                         f"fabric has {num_cus}")
    for t, s in sizes.items():
        old = current.get(t)
        if old is not None and any(c >= num_cus for c in old):
            raise ValueError(f"tenant {t} holds CU >= {num_cus}")

    out: Dict[str, Tuple[int, ...]] = {}
    claimed: set = set()
    # pass 1: same-size tenants keep their CUs outright
    for t, s in sizes.items():
        old = tuple(current.get(t, ()))
        if len(old) == s and not (set(old) & claimed):
            out[t] = old
            claimed |= set(old)
    # pass 2: everyone else — prefer owned CUs, then lowest free ids
    for t, s in sizes.items():
        if t in out:
            continue
        keep = [c for c in current.get(t, ()) if c not in claimed][:s]
        free = (c for c in range(num_cus)
                if c not in claimed and c not in keep)
        ids = sorted(keep + [next(free) for _ in range(s - len(keep))])
        out[t] = tuple(ids)
        claimed |= set(ids)
    return out


def recomposition_delta(current: Mapping[str, Sequence[int]],
                        new: Mapping[str, Sequence[int]]) -> RecompositionDelta:
    unchanged, moved, admitted = [], [], []
    for t, ids in new.items():
        if t not in current:
            admitted.append(t)
        elif tuple(current[t]) == tuple(ids):
            unchanged.append(t)
        else:
            moved.append(t)
    evicted = [t for t in current if t not in new]
    return RecompositionDelta(tuple(unchanged), tuple(moved),
                              tuple(admitted), tuple(evicted))


def concurrent_groups(plan: ExecutionPlan) -> List[List[PlannedLayer]]:
    """Maximal sets of layers whose schedule intervals overlap — these run
    simultaneously on disjoint compositions (validation: Eq. 4 guarantees
    disjoint CU sets)."""
    events = sorted({pl.start for pl in plan.layers})
    groups = []
    for t in events:
        live = [pl for pl in plan.layers if pl.start <= t < pl.end]
        if live and live not in groups:
            groups.append(live)
    return groups
