"""Deterministic stand-in for `hypothesis`, installed into ``sys.modules`` by
``conftest.py`` ONLY when the real package is missing (air-gapped containers
that cannot ``pip install``).  The pinned dev requirements declare the real
`hypothesis`, so CI always runs the genuine engine; this shim exists so tier-1
still *collects and passes* without it.

Scope: exactly the API surface the test suite uses — ``given``, ``settings``
(including profiles), ``assume`` and the ``integers`` / ``booleans`` /
``floats`` / ``lists`` / ``tuples`` / ``sampled_from`` / ``just`` strategies.
Examples are drawn from a per-test CRC32-seeded generator (stable across
processes and runs, PYTHONHASHSEED-independent), with an extra all-minima /
all-maxima boundary pass where the strategies expose bounds.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib
from typing import Any, Callable, Dict, Tuple

import numpy as np


class _UnsatisfiedAssumption(Exception):
    pass


def assume(condition: Any) -> bool:
    if not condition:
        raise _UnsatisfiedAssumption
    return True


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any],
                 boundary: Tuple[Any, ...] = ()):
        self._draw = draw
        self.boundary = tuple(boundary)

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        boundary=(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                     boundary=(False, True))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored: Any) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        boundary=(min_value, max_value))


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: np.random.Generator):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def sampled_from(seq) -> _Strategy:
    pool = list(seq)
    return _Strategy(lambda rng: pool[int(rng.integers(0, len(pool)))],
                     boundary=(pool[0], pool[-1]) if pool else ())


def just(value: Any) -> _Strategy:
    return _Strategy(lambda rng: value, boundary=(value,))


# ---------------------------------------------------------------------------
# settings + profiles
# ---------------------------------------------------------------------------

_PROFILES: Dict[str, Dict[str, Any]] = {"default": {"max_examples": 25}}
_ACTIVE_PROFILE = "default"


class settings:
    """Decorator + profile registry mirroring ``hypothesis.settings``."""

    def __init__(self, max_examples: int | None = None,
                 deadline: Any = None, derandomize: bool = True,
                 **_ignored: Any):
        self.max_examples = max_examples

    def __call__(self, fn):
        # applied above @given: cap the wrapper's example budget
        if self.max_examples is not None:
            fn._stub_max_examples = self.max_examples
        return fn

    @staticmethod
    def register_profile(name: str, **kwargs: Any) -> None:
        _PROFILES[name] = kwargs

    @staticmethod
    def load_profile(name: str) -> None:
        global _ACTIVE_PROFILE
        if name not in _PROFILES:
            raise KeyError(f"unknown hypothesis profile {name!r}")
        _ACTIVE_PROFILE = name


def _profile_cap() -> int:
    return int(_PROFILES[_ACTIVE_PROFILE].get("max_examples", 25))


# ---------------------------------------------------------------------------
# given
# ---------------------------------------------------------------------------

def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*fixtures, **fixture_kw):
            budget = min(
                getattr(wrapper, "_stub_max_examples", None) or 10 ** 9,
                _profile_cap())
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode("utf-8")))

            def run_one(args, kwargs):
                try:
                    fn(*fixtures, *args, **fixture_kw, **kwargs)
                except _UnsatisfiedAssumption:
                    pass

            strats = list(arg_strategies) + list(kw_strategies.values())
            if strats and all(s.boundary for s in strats):
                for pick in (0, -1):   # all-minima, then all-maxima
                    run_one(
                        tuple(s.boundary[pick] for s in arg_strategies),
                        {k: s.boundary[pick]
                         for k, s in kw_strategies.items()})
            for _ in range(budget):
                run_one(tuple(s.draw(rng) for s in arg_strategies),
                        {k: s.draw(rng) for k, s in kw_strategies.items()})

        # pytest must only see genuine fixture params: positional strategies
        # bind the rightmost args (hypothesis semantics), keywords by name.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n_pos = len(arg_strategies)
        bound = {p.name for p in params[len(params) - n_pos:]} if n_pos else set()
        bound |= set(kw_strategies)
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in params if p.name not in bound])
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return decorate


class _AnyAttr:
    """Stands in for enums like ``HealthCheck`` — any attribute resolves."""

    def __getattr__(self, name: str) -> str:
        return name


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for s in (integers, booleans, floats, tuples, lists, sampled_from, just):
        setattr(st, s.__name__, s)
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st
    mod.HealthCheck = _AnyAttr()
    mod.__version__ = "0.0.0-stub"
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
