"""Roofline-term extraction from compiled XLA artifacts (EXPERIMENTS.md
§Roofline).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw x links)

``compiled.cost_analysis()`` gives per-device FLOPs / bytes accessed.
Collective bytes are not in cost_analysis: ``collective_bytes_from_hlo``
parses the (optimized) HLO text, summing the on-wire payload of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
with a ring-model correction by replica-group size g:

  all-gather       result_bytes x (g-1)/g
  all-reduce       2 x bytes x (g-1)/g        (reduce-scatter + all-gather)
  reduce-scatter   operand_bytes x (g-1)/g
  all-to-all       bytes x (g-1)/g
  collective-permute  bytes

Hardware constants come from repro.common.platform.TPU_V5E.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.common.platform import TPU_V5E, PlatformProfile

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

# shapes like  bf16[16,128,8192]{2,1,0}  or tuples ( ... )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Sum on-wire collective payload (per device) from optimized HLO."""
    bytes_by: Dict[str, float] = {}
    count_by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = _group_size(line)
        if g <= 1 and kind != "collective-permute":
            continue
        frac = (g - 1) / g if g > 1 else 1.0
        if kind == "all-gather":
            wire = size * frac                 # result is the gathered buffer
        elif kind == "all-reduce":
            wire = 2.0 * size * frac
        elif kind == "reduce-scatter":
            wire = size * g * frac             # result is the scattered shard
        elif kind == "all-to-all":
            wire = size * frac
        else:                                   # collective-permute
            wire = size
        bytes_by[kind] = bytes_by.get(kind, 0.0) + wire
        count_by[kind] = count_by.get(kind, 0) + 1
    return CollectiveStats(bytes_by, count_by)


def scan_trip_multiplier(hlo_text: str) -> List[Tuple[int, str]]:
    """Best-effort: find while-loop trip counts so collectives inside scans
    can be scaled (XLA unrolls nothing; the while body appears once).
    Returns [(trip_count, body_name)] for known-trip-count loops."""
    out = []
    for m in re.finditer(
            r'while\(.*?\), condition=.*?, body=([%\w.\-]+)'
            r'.*?trip_count=(\d+)', hlo_text):
        out.append((int(m.group(2)), m.group(1)))
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    cell: str
    mesh: str
    chips: int
    # per-device quantities
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    # derived terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    # model-level accounting
    model_flops: float                 # 6*N*D (or 6*N_active*D)
    hlo_flops_total: float
    peak_memory_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops_total if self.hlo_flops_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the program runs at
        its bound: (useful FLOPs / chips / peak) / bound_s."""
        if self.bound_s <= 0:
            return 0.0
        ideal_s = self.model_flops / (self.chips * TPU_V5E.peak_flops)
        return ideal_s / self.bound_s

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.hlo_flops_total,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_gib": self.peak_memory_bytes / (1 << 30),
        }


def derive_terms(*, arch: str, cell: str, mesh_name: str, chips: int,
                 cost: Dict[str, float], collective: CollectiveStats,
                 model_flops: float, peak_memory_bytes: float = 0.0,
                 platform: PlatformProfile = TPU_V5E) -> RooflineTerms:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = collective.total_bytes
    return RooflineTerms(
        arch=arch, cell=cell, mesh=mesh_name, chips=chips,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_dev,
        compute_s=flops_dev / platform.peak_flops,
        memory_s=bytes_dev / platform.hbm_bw,
        collective_s=coll_dev / (platform.ici_bw * platform.ici_links),
        model_flops=model_flops,
        hlo_flops_total=flops_dev * chips,
        peak_memory_bytes=peak_memory_bytes,
    )


def model_flops_for(cfg, cell) -> float:
    """MODEL_FLOPS: 6*N*D for training; 2*N*D for inference (fwd only),
    with N = active params (MoE) and D = processed tokens."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch
