"""Encoder engine: prefill-only / embedding workloads on the composed fabric.

The third workload class (FILCO's diverse-workload story): encoder jobs are
**compute-bound full-sequence matmuls** — no decode loop, no growing cache,
no per-token host round-trips.  A tenant serving embedding traffic therefore
wants CUs for raw FLOP/s, while a decode tenant wants them for weight/KV
bandwidth and an SSM tenant for state bandwidth; the class-aware policy
prices each accordingly, and the split search allocates the fabric by each
class's actual bound resource.

Design (throughput-oriented):

* jobs queue on the host; each ``step()`` runs ONE batched encoder forward
  over up to ``max_slots`` jobs and completes them — there is no in-flight
  device state between steps, so ``reshard_to`` only moves params;
* the batch compiles at ``(max_slots, bucket)`` for each sequence-length
  bucket of ``ServeConfig.len_buckets`` (always including ``max_len``); a
  step groups its jobs by each job's OWN smallest fitting bucket and runs
  one batched forward per group, cutting the padded FLOPs of short
  embedding jobs.  The bucket ladder is static, so ``warm_compile`` still
  fully covers a candidate composition — and the ladder is a *runtime
  design knob*: ``apply(point.buckets)`` swaps it live (the serving-side
  DSE Stage 1 picks it from observed job lengths).  ``stats()`` reports
  per-bucket hit counts (jobs served per bucket);
* each job's output is the masked mean over its valid positions of
  :meth:`Model.encode` hidden states, in fp32 — a (d_model,) embedding.
  Causal stacks are padding-proof by construction; bidirectional encoder
  stacks mask each row's own key padding (``Model.encode(lens=...)``), so a
  job's embedding is bit-identical across bucket ladders — which is what
  makes the live ladder swap numerics-safe.

Jobs longer than ``max_len`` are rejected-but-recorded (empty embedding),
mirroring the decode engine's contract that requests never vanish.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.composer import mesh_fingerprint
from repro.core.dse import DesignPoint
from repro.distribution import partitioning as part
from repro.models.model import Model
from repro.obs import Telemetry
from repro.workloads.base import (DecayedLengthEstimator, EngineTelemetry,
                                  length_buckets, pick_bucket,
                                  sanitize_check, sanitize_guard)
from repro.workloads.compile_cache import ExecutableCache
from repro.workloads.decode import ServeConfig, _mesh_of, _rules_fp


@dataclasses.dataclass
class EncodeJob:
    """One embedding job's host-side record (``embedding`` is the fp32
    mean-pooled (d_model,) vector once done; ``[]`` marks a reject)."""

    rid: int
    tokens: np.ndarray
    embedding: Optional[List[float]] = None
    done: bool = False
    # perf_counter() at submit — SLO telemetry (queue wait / time-to-result);
    # survives adoption by a sibling replica.  0.0 = unknown.
    submitted_s: float = 0.0


class EncoderEngine(EngineTelemetry):
    """Prefill-only embedding serving (the ``encoder`` workload class):
    each step batches queued jobs through one bucketed compiled
    ``Model.encode`` forward and completes them — no decode loop, no
    in-flight device state (see the module docstring; the Engine-protocol
    contract is docs/workloads.md)."""

    workload_class = "encoder"

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 mesh=None, rules: Optional[part.ShardingRules] = None,
                 exec_cache: Optional[ExecutableCache] = None,
                 obs: Optional[Telemetry] = None):
        self.model = model
        self.cfg = cfg
        self.rules = rules
        self._obs = obs if obs is not None else Telemetry()
        self._rules_eff = rules or part.ShardingRules(rules={})
        self.reshard_count = 0
        self._param_plan = part.ShardingPlan.of(params)
        self.params = part.strip(params)
        if rules is not None and not self._param_plan.annotated:
            raise ValueError(
                "tensor-parallel serving needs annotated params: pass "
                "model.init(...) without strip() when rules are given")
        self._exec = exec_cache if exec_cache is not None else ExecutableCache()
        self._own_builds = 0
        self._tp: Optional[int] = None
        self._granted = None
        self._recent_lens = DecayedLengthEstimator()
        self._buckets = length_buckets(cfg.len_buckets, cfg.max_len)
        self._bucket_hits: Dict[int, int] = {b: 0 for b in self._buckets}
        self._cfg_key = self._config_key(cfg.max_slots)
        self._queue: List[EncodeJob] = []
        self._finished: Dict[int, List[float]] = {}
        self.finished_cap = 10_000
        self._next_rid = 0
        self._seqs_done = 0
        self.mesh: Optional[Mesh] = None
        self.reshard_to(mesh)
        self.reshard_count = 0         # construction placement isn't a move

    def _config_key(self, slots: int, buckets=None) -> Tuple:
        """Shared-executable-cache config fingerprint at a (possibly
        prospective) design point — batch size and bucket ladder shape the
        compiled programs, so both are in the key."""
        ladder = (length_buckets(buckets, self.cfg.max_len)
                  if buckets is not None else self._buckets)
        return (self.workload_class, self.model.cfg, slots,
                self.cfg.max_len, ladder, _rules_fp(self.rules))

    # ------------------------------------------------------------------
    def reshard_to(self, sub) -> None:
        """Move the engine onto a new composed sub-accelerator.  Encoder
        jobs complete within the step that runs them, so the only device
        state is the params pytree — one sharded→sharded device_put (onto
        the grant restricted to the engine's TP degree)."""
        with self._obs.span("reshard"):
            self._granted = _mesh_of(sub)
            mesh = part.tp_submesh(self._granted, self._tp)
            self.mesh = mesh
            self._mesh_fp = mesh_fingerprint(mesh)
            if mesh is not None:
                self.params = jax.device_put(
                    self.params,
                    self._param_plan.shardings(mesh, self._rules_eff))
        self.reshard_count += 1
        self._obs.inc("reshards")

    def sync(self) -> None:
        """No in-flight device state: step() already syncs on device_get."""
        jax.block_until_ready(self.params)

    # ------------------------------------------------------------------
    # live design-point reconfiguration (serving DSE Stage 1's knobs)
    # ------------------------------------------------------------------
    def design(self) -> Dict[str, Any]:
        """Currently applied design point: TP degree (None = whole grant),
        batch slots per step, and the sequence-length bucket ladder."""
        return {"tp": self._tp, "slots": self.cfg.max_slots,
                "buckets": self._buckets}

    def apply(self, sub=None,
              point: Optional[DesignPoint] = None) -> Dict[str, Any]:
        """Apply a design-point delta live (``point`` fields of ``None`` =
        keep).  Encoder jobs hold no cross-step device state, so every knob
        is a host-side swap (plus a params reshard for ``sub``/``tp``):
        ``slots`` resizes the batched program's job count per step,
        ``buckets`` swaps the padded-length program ladder (numerics-safe —
        encodes mask their key padding, so embeddings are bucket-invariant);
        ``dp`` is a group knob, consumed by the ReplicaGroup.  Returns the
        applied knobs."""
        point = point if point is not None else DesignPoint(cus=0)
        applied: Dict[str, Any] = {}
        if point.tp is not None and point.tp != (self._tp or 0):
            self._tp = max(int(point.tp), 1)
            applied["tp"] = self._tp
        if sub is not None or "tp" in applied:
            self.reshard_to(sub if sub is not None else self._granted)
        if point.slots is not None and int(point.slots) != self.cfg.max_slots:
            self.cfg = dataclasses.replace(self.cfg,
                                           max_slots=max(int(point.slots), 1))
            applied["slots"] = self.cfg.max_slots
        if point.buckets is not None:
            ladder = length_buckets(point.buckets, self.cfg.max_len)
            if ladder != self._buckets:
                self._buckets = ladder
                self._bucket_hits = {b: self._bucket_hits.get(b, 0)
                                     for b in ladder}
                applied["buckets"] = ladder
        if applied:
            self._cfg_key = self._config_key(self.cfg.max_slots)
        return applied

    # ------------------------------------------------------------------
    # cross-replica migration (ReplicaGroup dp retune): encoder jobs hold
    # no cross-step device state, so only the host queue moves
    # ------------------------------------------------------------------
    def evacuate(self) -> Tuple[List, List[EncodeJob]]:
        """Strip this engine of its queued jobs for adoption by sibling
        replicas; the live list is always empty (jobs complete within the
        step that runs them).  Finished records stay readable."""
        queued, self._queue = self._queue, []
        return [], queued

    def adopt_queued(self, job: EncodeJob) -> int:
        """Adopt a queued job from a sibling replica under a fresh engine
        rid (the ReplicaGroup owns the stable group-level rid)."""
        rid = self._next_rid
        self._next_rid += 1
        job.rid = rid
        self._queue.append(job)
        return rid

    def export_queued(self) -> List[EncodeJob]:
        """Hand back the queued jobs (ReplicaGroup queue rebalance on a dp
        grow)."""
        queued, self._queue = self._queue, []
        return queued

    def recent_lengths(self) -> Tuple[int, ...]:
        """Recently submitted job lengths, exponentially decayed toward the
        newest traffic — what the serving DSE's Stage-1 bucket-ladder search
        optimizes against."""
        return self._recent_lens.lengths()

    # ------------------------------------------------------------------
    # compiled executable: one fixed-shape batched encode per mesh
    # (build counting: EngineTelemetry)
    # ------------------------------------------------------------------
    def _encode_fn(self, params, tokens, lens):
        """(B, S) padded tokens + (B,) valid lengths -> (B, d) fp32 masked
        mean-pooled embeddings.  ``lens`` both masks the mean-pool AND (on
        bidirectional stacks) the attention's key padding, so a job's
        embedding is independent of the bucket it ran in."""
        x = self.model.encode(params, {"tokens": tokens}, lens=lens)
        S = x.shape[1]
        mask = (jnp.arange(S)[None, :] < lens[:, None]).astype(jnp.float32)
        pooled = jnp.einsum("bsd,bs->bd", x.astype(jnp.float32), mask)
        return pooled / jnp.maximum(lens, 1).astype(jnp.float32)[:, None]

    def _build_encode(self, mesh, sb: int, slots: Optional[int] = None):
        B, S = slots or self.cfg.max_slots, sb
        kwargs = {}
        if mesh is not None:
            kwargs["out_shardings"] = NamedSharding(mesh, P())
        fn = jax.jit(self._encode_fn, **kwargs)

        def aval(dtype, shape):
            if mesh is None:
                return jax.ShapeDtypeStruct(shape, dtype)
            return jax.ShapeDtypeStruct(shape, dtype,
                                        sharding=NamedSharding(mesh, P()))

        return fn.lower(
            self._param_plan.avals(mesh, self._rules_eff),
            aval(jnp.int32, (B, S)),
            aval(jnp.int32, (B,)),
        ).compile()

    def _encode_exec(self, mesh, sb: int):
        key = ("encode", self._cfg_key, self._mesh_fp, sb)
        return self._exec.get_or_build(
            key, self._counted(lambda: self._build_encode(mesh, sb)))

    def warm_compile(self, sub,
                     point: Optional[DesignPoint] = None) -> int:
        """Pre-compile the batched encode program of every sequence-length
        bucket for a candidate sub-accelerator — at a candidate design
        point when one is given.  The ladder is finite, so this fully
        covers the composition.  Returns cold builds performed."""
        point = point if point is not None else DesignPoint(cus=0)
        with self._obs.timed("warm_compile", "warm_compile_s") as sp:
            mesh = part.tp_submesh(
                _mesh_of(sub), point.tp if point.tp is not None else self._tp)
            B = point.slots or self.cfg.max_slots
            key = self._config_key(B, point.buckets)
            ladder = (length_buckets(point.buckets, self.cfg.max_len)
                      if point.buckets is not None else self._buckets)
            fp = mesh_fingerprint(mesh)
            built = sum(self._exec.ensure(
                ("encode", key, fp, sb),
                self._counted(lambda sb=sb: self._build_encode(mesh, sb, B)))
                for sb in ladder)
            if sp is not None:
                sp["builds"] = built
        return built

    # ------------------------------------------------------------------
    # load signals
    # ------------------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def active_count(self) -> int:
        return 0                       # jobs complete within their step

    @property
    def has_work(self) -> bool:
        return bool(self._queue)

    def pending_tokens(self) -> int:
        """Prefill tokens of work owed: encoder demand is full-sequence
        compute, so the signal is prompt tokens, not decode steps."""
        return sum(len(j.tokens) for j in self._queue)

    def arena_utilization(self) -> float:
        """Batch-fill pressure: how far the queue over-subscribes one step's
        batch (the encoder has no growing per-request device state)."""
        return min(1.0, len(self._queue) / max(self.cfg.max_slots, 1))

    def stats(self) -> Dict[str, Any]:
        """Load/telemetry snapshot: queue depth (jobs), owed prompt tokens,
        batch-fill pressure (0..1), migrations, cold builds, completed
        sequences, and jobs served per sequence-length bucket."""
        return {
            "workload_class": self.workload_class,
            "queue_depth": self.queue_depth,
            "active": self.active_count,
            "pending_tokens": self.pending_tokens(),
            "arena_utilization": round(self.arena_utilization(), 4),
            "reshard_count": self.reshard_count,
            "compile_builds": self.compile_builds,
            "seqs_done": self._seqs_done,
            "bucket_hits": {str(b): n for b, n in self._bucket_hits.items()},
            "design": self.design(),
        }

    # ------------------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int = 0) -> int:
        """Queue one embedding job.  ``max_new_tokens`` is accepted for
        Engine-protocol compatibility and ignored (nothing is generated)."""
        del max_new_tokens
        rid = self._next_rid
        self._next_rid += 1
        toks = np.asarray(tokens, np.int32)
        self._recent_lens.append(len(toks))
        self._queue.append(EncodeJob(rid, toks,
                                     submitted_s=time.perf_counter()))
        self._obs.inc("requests_submitted")
        return rid

    def step(self) -> List[Tuple[int, List[float]]]:
        """One engine iteration: batch up to max_slots queued jobs through
        one compiled encode and complete them.  Returns [(rid, embedding)]."""
        emitted: List[Tuple[int, List[float]]] = []
        batch: List[EncodeJob] = []
        while self._queue and len(batch) < self.cfg.max_slots:
            job = self._queue.pop(0)
            if len(job.tokens) > self.cfg.max_len:
                # rejected-but-recorded (empty embedding), like the decode
                # engine's oversized requests — and like them NOT emitted:
                # emitted entries are completed sequences and feed the
                # fabric's per-class throughput accounting
                job.done = True
                job.embedding = []
                self._record_finished(job)
                continue
            batch.append(job)
        if not batch:
            return emitted
        obs = self._obs
        if obs.enabled:
            now = time.perf_counter()
            for job in batch:
                if job.submitted_s > 0.0:
                    obs.observe("queue_wait_s", now - job.submitted_s)
        # group by each job's OWN smallest fitting bucket (NOT the batch
        # max) so a short job never pays a co-batched long job's padded
        # FLOPs; numerically the bucket doesn't matter — encode masks each
        # row's key padding, so embeddings are bucket-invariant
        groups: Dict[int, List[EncodeJob]] = {}
        for job in batch:
            groups.setdefault(pick_bucket(self._buckets, len(job.tokens)),
                              []).append(job)
        B = self.cfg.max_slots
        # the encoder's "decode step" is its batched encode iteration — the
        # uniform decode_step_s metric keeps per-class step latency
        # comparable across the fleet; each group's device_get is an
        # existing sync point, so the timings add no synchronization
        with obs.timed("encode_step", "decode_step_s", jobs=len(batch)), \
                sanitize_guard():
            for sb in sorted(groups):
                jobs = groups[sb]
                self._bucket_hits[sb] += len(jobs)
                toks = np.zeros((B, sb), np.int32)
                lens = np.zeros((B,), np.int32)
                for i, job in enumerate(jobs):
                    toks[i, :len(job.tokens)] = job.tokens
                    lens[i] = len(job.tokens)
                with obs.timed("encode", "encode_s", bucket=sb, n=len(jobs)):
                    exe = self._encode_exec(self.mesh, sb)
                    emb = np.asarray(
                        jax.device_get(exe(self.params, toks, lens)))
                for i, job in enumerate(jobs):
                    job.embedding = [float(v) for v in emb[i]]
                    job.done = True
                    self._record_finished(job)
                    emitted.append((job.rid, job.embedding))
        sanitize_check(self)
        if obs.enabled:
            done = time.perf_counter()
            for job in batch:
                if job.submitted_s > 0.0:
                    obs.observe("ttft_s", done - job.submitted_s)
            obs.set_gauge("slot_utilization", len(batch) / max(B, 1))
            obs.inc("tokens_emitted", len(batch))
        self._seqs_done += len(batch)
        return emitted

    def _record_finished(self, job: EncodeJob) -> None:
        # copy: the job's list is handed to callers via step()'s emitted
        # pairs — a caller mutating it must not corrupt the engine's record
        self._finished[job.rid] = list(job.embedding)
        self._evict_finished()

    def run_to_completion(self, max_steps: int = 1000) -> Dict[int, List[float]]:
        """Step until idle (or ``max_steps``); returns ``snapshot()``."""
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()
        return self.snapshot()

    def results(self) -> Dict[int, List[float]]:
        """Completed (or rejected) jobs' embeddings (copies, like the
        decode engine's token streams)."""
        return {rid: list(e) for rid, e in self._finished.items()}

    def snapshot(self) -> Dict[int, List[float]]:
        out: Dict[int, List[float]] = {j.rid: [] for j in self._queue}
        out.update(self.results())
        return out
