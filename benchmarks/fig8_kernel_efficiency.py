"""Fig. 8 reproduction: single-kernel computational efficiency across MM
sizes, flexible vs static programming.

Paper setup: FP32 MM from 8x24x16 to 32x32x32 at 2x8x8-atom granularity on
one AIE; flexible sustains >= 6x operation-count variation with <= 5%
efficiency loss while static pays full-tile padding.

We reproduce the curve with the analytical single-engine cycle model
(atoms + pipeline fill, VCK190 profile) and validate numerics of the
flexible kernel at the same sizes through the interpret-mode Pallas
``filco_mm`` against its oracle.  A second sweep reports the TPU-atom
(8x128x128) analogue — the hardware-adaptation view (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np

from repro.common.platform import TPU_V5E, VCK190
from repro.core.analytical import PIPELINE_FILL_ATOMS


def _ceil(a, b):
    return -(-a // b)


def efficiency(platform, m, k, n, *, static_tile=None):
    """Valid-FLOP efficiency of one engine executing (m,k,n)."""
    am, ak, an = platform.atom_shape
    valid = 2.0 * m * k * n
    if static_tile is None:
        atoms = _ceil(m, am) * _ceil(k, ak) * _ceil(n, an)
    else:
        tm, tk, tn = static_tile
        atoms = (_ceil(tm, am) * _ceil(tk, ak) * _ceil(tn, an)
                 * _ceil(m, tm) * _ceil(k, tk) * _ceil(n, tn))
    cycles = (atoms + PIPELINE_FILL_ATOMS) * platform.atom_cycles
    peak_flops_per_cycle = platform.atom_flops / platform.atom_cycles
    return valid / (cycles * peak_flops_per_cycle)


def sweep_sizes():
    """MM sizes 8x24x16 -> 32x32x32 at atom granularity (paper's x-axis):
    grow m in 2x8x8-atom steps, then k, then n — covering the paper's >6x
    operation-count range between 14x24x16 and 32x32x32."""
    sizes = [(m, 24, 16) for m in range(8, 33, 2)]
    sizes += [(32, 32, 16), (32, 32, 24), (32, 32, 32)]
    return sizes


def run(check: bool = True):
    rows = []
    static_tile = (32, 32, 32)
    for (m, k, n) in sweep_sizes():
        e_flex = efficiency(VCK190, m, k, n)
        e_static = efficiency(VCK190, m, k, n, static_tile=static_tile)
        rows.append({
            "mm": f"{m}x{k}x{n}", "ops": 2 * m * k * n,
            "eff_flexible": e_flex, "eff_static": e_static,
        })
    # paper claim: >=6x op variation from 14x24x16 up with <=5% loss
    usable = [r for r in rows if r["ops"] >= 2 * 14 * 24 * 16]
    op_range = max(r["ops"] for r in usable) / min(r["ops"] for r in usable)
    worst = min(r["eff_flexible"] for r in usable)
    best = max(r["eff_flexible"] for r in usable)
    # TPU-atom analogue sweep (one MXU, 8x128x128 atoms)
    tpu_rows = []
    for (m, k, n) in [(8, 128, 128), (64, 256, 256), (256, 512, 512),
                      (512, 1024, 1024), (1024, 1024, 1024)]:
        tpu_rows.append({
            "mm": f"{m}x{k}x{n}",
            "eff_flexible": efficiency(TPU_V5E, m, k, n),
            "eff_static": efficiency(TPU_V5E, m, k, n,
                                     static_tile=(1024, 1024, 1024)),
        })
    summary = {
        "op_count_range": op_range,
        "flexible_loss_vs_peak": 1.0 - worst / best,
        "static_min_eff": min(r["eff_static"] for r in usable),
    }
    if check:
        assert op_range >= 6.0, op_range
        assert summary["flexible_loss_vs_peak"] <= 0.06, summary
        assert summary["static_min_eff"] < 0.5 * worst
    return {"rows": rows, "tpu_rows": tpu_rows, "summary": summary}


def kernel_numerics_check(sizes=((8, 24, 16), (16, 24, 16), (32, 32, 32))):
    """Interpret-mode filco_mm at the paper's sizes vs the oracle."""
    import jax.numpy as jnp

    from repro.kernels.filco_mm import kernel as K
    from repro.kernels.filco_mm import ref as R
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    worst = 0.0
    for (m, k, n) in sizes:
        dims = jnp.asarray([m, k, n], jnp.int32)
        out = K.flex_mm(a, b, dims, bm=8, bk=8, bn=8, interpret=True)
        ref = R.flex_mm_ref(a, b, dims)
        worst = max(worst, float(jnp.abs(out - ref).max()))
    return worst


def main():
    res = run()
    for r in res["rows"]:
        print(f"fig8,{r['mm']},{r['eff_flexible']:.4f},{r['eff_static']:.4f}")
    err = kernel_numerics_check()
    print(f"fig8_kernel_maxerr,,{err:.2e},")
    s = res["summary"]
    print(f"fig8_summary,op_range={s['op_count_range']:.1f},"
          f"flex_loss={s['flexible_loss_vs_peak']*100:.1f}%,"
          f"static_min_eff={s['static_min_eff']*100:.1f}%")
    return res


if __name__ == "__main__":
    main()
