from repro.core.dse import DesignPoint
from repro.obs import (MetricsRegistry, PredictionLedger, SpanTracer,
                       Telemetry)
from repro.serve.dse import Stage1Optimizer, TenantDesignSpace, design_key
from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                RecompositionEvent, ReplicaGroup, SLOTarget,
                                TenantLoad, TenantObservation, TenantSpec,
                                serve_engine_rules)
from repro.serve.traffic import PROFILES, Arrival, arrival_schedule
from repro.workloads import (DecodeEngine, EncDecEngine, EncoderEngine,
                             ExecutableCache, Request, ServeConfig, SSMEngine)

# the PR-1/2 serving engine is the transformer decode workload class; the
# name stays public (engines live in repro.workloads — the old
# repro.serve.engine / repro.serve.compile_cache shims are gone)
ServeEngine = DecodeEngine

__all__ = [
    "ExecutableCache",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "DecodeEngine",
    "SSMEngine",
    "EncoderEngine",
    "EncDecEngine",
    "AnalyticalPolicy",
    "Arrival",
    "ComposedServer",
    "PROFILES",
    "SLOTarget",
    "arrival_schedule",
    "DesignPoint",
    "MetricsRegistry",
    "PredictionLedger",
    "RecompositionEvent",
    "ReplicaGroup",
    "SpanTracer",
    "Stage1Optimizer",
    "Telemetry",
    "design_key",
    "TenantDesignSpace",
    "TenantLoad",
    "TenantObservation",
    "TenantSpec",
    "serve_engine_rules",
]
