"""End-to-end training driver: a ~100M-parameter llama-style model trained
for a few hundred steps with the full production stack — sharded-state
trainer, deterministic pipeline, checkpoint/restart, straggler watchdog.

  PYTHONPATH=src python examples/train_100m.py               # full run
  PYTHONPATH=src python examples/train_100m.py --steps 20    # smoke

On this CPU container a full 300-step run takes a while; the default is
sized so loss visibly drops.  The config is exactly the qwen2.5 family
shape scaled to ~100M params (--arch switches family).
"""
import argparse
import dataclasses
import json
import tempfile

from repro.configs import get_reduced
from repro.configs.base import ModelConfig
from repro.data import make_pipeline
from repro.models import build_model
from repro.train import TrainConfig, Trainer

CONFIG_100M = ModelConfig(
    name="llama-100m",
    family="dense",
    num_layers=12,
    d_model=640,
    num_heads=10,
    num_kv_heads=5,
    d_ff=1792,
    vocab_size=32000,
    head_dim=64,
    attn_type="full",
    act="silu",
    glu=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--arch", default=None,
                    help="use a reduced assigned-arch config instead")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.arch else CONFIG_100M
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")
    pipe = make_pipeline(cfg, args.seq_len, args.global_batch, seed=0)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train100m_")
    tr = Trainer(model,
                 TrainConfig(steps=args.steps, lr=args.lr,
                             warmup=max(args.steps // 20, 5),
                             log_every=max(args.steps // 20, 1),
                             checkpoint_every=max(args.steps // 3, 10),
                             ckpt_dir=ckpt_dir),
                 mesh=None, pipeline=pipe)
    out = tr.fit()
    first, last = out["metrics"][0], out["metrics"][-1]
    print(json.dumps({"status": out["status"], "steps": out["step"],
                      "loss_first": round(first["loss"], 3),
                      "loss_last": round(last["loss"], 3),
                      "tokens_per_step": args.seq_len * args.global_batch,
                      "ckpt_dir": ckpt_dir}, indent=1))
    assert last["loss"] < first["loss"], "loss must decrease"


if __name__ == "__main__":
    main()
