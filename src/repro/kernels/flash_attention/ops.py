"""Public wrapper: GQA-aware multihead flash attention with CPU fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def mha(q, k, v, *, causal=True, window=0, bq=256, bk=256, impl="auto"):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) -> (B, S, Hq, D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    groups = Hq // Hkv
    kx = jnp.repeat(k, groups, axis=2)
    vx = jnp.repeat(v, groups, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        of = R.attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        interpret = impl == "interpret" or not _on_tpu()
        bq_, bk_ = min(bq, S), min(bk, S)
        of = K.flash_attention(qf, kf, vf, causal=causal, window=window,
                               bq=bq_, bk=bk_, interpret=interpret)
    return of.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
