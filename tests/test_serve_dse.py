"""Serving-side two-stage DSE: Stage-1 design-point search (TP-degree /
slot-count / bucket-ladder trades on the analytical model), Stage-2 split
search over Stage-1-optimal points (AnalyticalPolicy.decide returning
per-tenant DesignPoints, retune decisions), and design-aware warm compiles.

Pure analytical tests (no devices) plus engine-level cache checks; the
live-application path is covered by tests/test_workloads.py
(test_live_reconfigure_stream_invariance, mixed-fleet e2e) and the CI
``dse-smoke`` job (repro.launch.serve --dse-smoke)."""
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_reduced
from repro.core.dse import DesignPoint, dp_candidates, tp_candidates
from repro.core.analytical import tp_collective_latency
from repro.common.platform import TPU_V5E
from repro.distribution import strip
from repro.models import build_model
from repro.serve.dse import Stage1Optimizer, TenantDesignSpace, padded_factor
from repro.serve.fabric import AnalyticalPolicy, TenantObservation
from repro.workloads import (DECODE, ENCDEC, ENCODER, SSM, DecodeEngine,
                             ServeConfig)


def _load(pending, active=1, util=0.0, queue=0, space=None, lengths=()):
    return TenantObservation(pending_tokens=pending, queue_depth=queue,
                             active=active, arena_utilization=util,
                             space=space, recent_lengths=tuple(lengths))


def _space(**kw):
    base = dict(wclass=DECODE, max_len=64, base_slots=2,
                per_slot_elems=64 * 128, tp_allowed=True)
    base.update(kw)
    return TenantDesignSpace(**base)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def test_tp_candidates_and_design_point_knobs():
    assert tp_candidates(1) == (1,)
    assert tp_candidates(4) == (1, 2, 4)
    assert tp_candidates(6) == (1, 2, 4, 6)
    assert tp_candidates(0) == ()
    p = DesignPoint(cus=4, tp=2, slots=8, buckets=(8, 64))
    assert p.knobs() == {"tp": 2, "slots": 8, "buckets": [8, 64]}
    assert DesignPoint(cus=4).knobs() == {}      # split-only: no knobs
    p2 = DesignPoint(cus=4, tp=1, slots=4, dp=4)
    assert p2.knobs() == {"tp": 1, "slots": 4, "dp": 4}


def test_dp_candidates():
    assert dp_candidates(4, 1) == (1, 2, 4)
    assert dp_candidates(6, 1) == (1, 2, 4, 6)   # max packing always in
    assert dp_candidates(8, 2) == (1, 2, 4)      # bounded by tp * dp <= cus
    assert dp_candidates(3, 2) == (1,)
    assert dp_candidates(0, 1) == ()
    assert dp_candidates(2, 4) == ()             # replica wider than grant


def test_tp_collective_latency_shape():
    assert tp_collective_latency(TPU_V5E, 1, 1e6) == 0.0
    one = tp_collective_latency(TPU_V5E, 2, 4096)
    two = tp_collective_latency(TPU_V5E, 4, 4096)
    assert 0.0 < one < two          # more phases at higher degree


def test_padded_factor():
    assert padded_factor((64,), ()) == 1.0
    assert padded_factor((64,), (8, 8)) == 8.0          # capacity-only pads 8x
    assert padded_factor((8, 64), (8, 8)) == 1.0        # fitted ladder: none
    assert padded_factor((8, 64), (8, 60)) == (8 + 64) / 68
    assert padded_factor((8,), (100,)) == 1.0           # oversized: ignored


# ---------------------------------------------------------------------------
# Stage 1: the three trades
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stage1():
    pol = AnalyticalPolicy()
    return pol, pol.stage1


def test_stage1_slots_cover_queue(stage1):
    """A deep queue pulls the slot count up: batching amortizes the step's
    weight traffic over min(slots, queue) streams."""
    pol, s1 = stage1
    cfg = get_reduced("minitron-4b")
    sp = _space()
    deep = s1.best(cfg, sp, 12, 2)
    shallow = s1.best(cfg, sp, 1, 2)
    assert deep.slots * (deep.dp or 1) >= 8 and shallow.slots <= 2
    assert deep.cost < s1.cost_of(cfg, sp, 12,
                                  DesignPoint(cus=2, tp=2, slots=2))


def test_stage1_tp_below_grant_for_tiny_models(stage1):
    """The all-reduce phases dominate a reduced model's µs-scale step, so
    Stage 1 caps the TP degree below a large grant instead of sharding the
    step into collective overhead."""
    pol, s1 = stage1
    cfg = get_reduced("minitron-4b")
    best = s1.best(cfg, _space(), 4, 8)
    assert best.tp < 8
    full = s1.cost_of(cfg, _space(), 4,
                      DesignPoint(cus=8, tp=8, slots=best.slots))
    assert best.cost < full


def test_stage1_cost_monotone_in_grant(stage1):
    """More CUs never hurt: the design space at grant c contains every
    design at c' < c (Stage 2's split search relies on this)."""
    pol, s1 = stage1
    for arch, wc in (("minitron-4b", DECODE), ("falcon-mamba-7b", SSM)):
        cfg = get_reduced(arch)
        sp = _space(wclass=wc)
        costs = [s1.best(cfg, sp, 6, c).cost for c in (1, 2, 4, 8)]
        assert all(a >= b - 1e-18 for a, b in zip(costs, costs[1:])), costs


def test_stage1_ladder_fits_observed_lengths(stage1):
    """Observed short jobs pull a quantile bucket into the ladder, cutting
    the encode phase's padded FLOPs vs the capacity-only program."""
    pol, s1 = stage1
    cfg = get_reduced("qwen2.5-32b")
    sp = _space(wclass=ENCODER, max_len=64, base_buckets=())
    lengths = (5, 7, 6, 8, 30)
    best = s1.best(cfg, sp, 4, 2, lengths)
    assert best.buckets is not None and len(best.buckets) >= 2
    assert best.buckets[-1] == 64                      # capacity always last
    assert padded_factor(best.buckets, lengths) \
        < padded_factor((64,), lengths)
    cap_only = s1.cost_of(cfg, sp, 4,
                          DesignPoint(cus=2, tp=best.tp,
                                      slots=best.slots, buckets=()),
                          lengths)
    assert best.cost < cap_only


def test_stage1_encdec_prices_src_by_expected_bucket(stage1):
    """An enc-dec tenant's cross-attention read prices at the ladder's
    expected bucket of the observed sources, not blindly at capacity."""
    pol, s1 = stage1
    cfg = dataclasses.replace(get_reduced("seamless-m4t-medium"),
                              dtype="float32")
    sp = _space(wclass=ENCDEC, max_len=16, max_src=16, base_buckets=(8,))
    short = s1.cost_of(cfg, sp, 4,
                       DesignPoint(cus=2, tp=2, slots=2, buckets=(8, 16)),
                       lengths=(5, 6), src_cap=16)
    cap = s1.cost_of(cfg, sp, 4,
                     DesignPoint(cus=2, tp=2, slots=2, buckets=(8, 16)),
                     lengths=(), src_cap=16)
    assert short < cap


def test_stage1_replicated_fabric_pays_no_collectives(stage1):
    """tp_allowed=False (replicated engines, no sharding rules) must price
    zero collective cost — otherwise larger grants look like regressions
    and the policy freezes (regression test for the mixed-fleet fabric)."""
    pol, s1 = stage1
    cfg = get_reduced("minitron-4b")
    sp = _space(tp_allowed=False)
    assert s1.collective_s(cfg, 2, 8, sp) == 0.0
    costs = [s1.best(cfg, sp, 4, c).cost for c in (1, 2, 4, 8)]
    assert all(a >= b - 1e-18 for a, b in zip(costs, costs[1:])), costs


def test_stage1_slot_memory_feasibility(stage1):
    """Slot counts are bounded by the pool the compute CUs' HBM can pin."""
    pol, s1 = stage1
    cfg = get_reduced("minitron-4b")
    tight = Stage1Optimizer(pol.step_cost, mem_budget_bytes=4 * 64 * 128 * 3)
    sp = _space()                                    # per_slot_elems 64*128
    best = tight.best(cfg, sp, 12, 1)
    assert best.slots <= 3, best


def test_stage1_dp_fills_grant_past_the_slot_cap(stage1):
    """When one engine's step program can't batch past ``slot_cap``, a deep
    queue on a wide grant is served by tiling the grant into data-parallel
    replicas (the Herald trade): total concurrency multiplies by dp while
    each replica stays at a cheap low TP degree."""
    pol, s1 = stage1
    cfg = get_reduced("minitron-4b")
    sp = _space(slot_cap=4)
    best = s1.best(cfg, sp, 16, 4)
    assert best.dp and best.dp >= 2, best
    assert best.slots * best.dp >= 8, best
    forced = s1.cost_of(cfg, sp, 16,
                        DesignPoint(cus=4, tp=4, slots=4, dp=1))
    assert best.cost < forced


def test_stage1_respects_dp_cap(stage1):
    """dp_cap=1 pins the tenant to a single engine regardless of grant."""
    pol, s1 = stage1
    cfg = get_reduced("minitron-4b")
    best = s1.best(cfg, _space(slot_cap=4, dp_cap=1), 16, 4)
    assert best.dp == 1, best


# ---------------------------------------------------------------------------
# Stage 2: decide over design points
# ---------------------------------------------------------------------------

def test_decide_returns_design_points_with_knobs():
    cfgs = {"a": get_reduced("minitron-4b"), "b": get_reduced("minitron-4b")}
    pol = AnalyticalPolicy()
    points, reason = pol.decide(
        {"a": _load(100, queue=10, space=_space()),
         "b": _load(100, queue=10, space=_space())}, cfgs,
        {"a": 4, "b": 4}, 8)
    assert all(isinstance(p, DesignPoint) for p in points.values())
    if reason != "hysteresis":
        assert any(p.slots not in (None, 2) or (p.tp or p.cus) < p.cus
                   for p in points.values()), points
    assert pol.predicted is not None and pol.predicted["best_s"] > 0


def test_decide_retunes_same_split_on_knob_gain():
    """When the best composition keeps the CU split but better per-tenant
    knobs clear the gain bar, decide returns reason='retune' — a pure
    Stage-1 delta the fabric applies with no CU move."""
    cfg = get_reduced("minitron-4b")
    pol = AnalyticalPolicy()
    sp = _space()
    current = {"a": DesignPoint(cus=8, tp=8, slots=1)}
    points, reason = pol.decide(
        {"a": _load(200, active=1, queue=15, space=sp)}, {"a": cfg},
        current, 8)
    assert reason == "retune"
    assert points["a"].cus == 8 and points["a"].slots > 1


def test_decide_split_only_matches_pre_dse_shape():
    """two_stage=False: design points carry no knobs (the CU count is the
    whole design point) and the split dynamics are the pre-DSE ones."""
    cfgs = {"a": get_reduced("minitron-4b"), "b": get_reduced("minitron-4b")}
    pol = AnalyticalPolicy(two_stage=False)
    assert pol.stage1 is None
    points, reason = pol.decide(
        {"a": _load(100, space=_space()), "b": _load(0, space=_space())},
        cfgs, {"a": 4, "b": 4}, 8)
    live = {t: p for t, p in points.items() if p.cus > 0}
    assert live == {"a": DesignPoint(cus=8, cost=live["a"].cost)}
    assert reason == "unify"
    assert all(p.tp is None and p.slots is None for p in points.values())


# ---------------------------------------------------------------------------
# design-aware warm compile: prewarmed programs are reused after the
# matching reconfigure (the stall-free retune path)
# ---------------------------------------------------------------------------

def test_warm_compile_covers_candidate_design_point():
    cfg = dataclasses.replace(get_reduced("minitron-4b"), dtype="float32")
    model = build_model(cfg)
    params = strip(model.init(jax.random.key(0)))
    eng = DecodeEngine(model, params, ServeConfig(max_slots=2, max_len=32,
                                                  eos_id=-1))
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(1, cfg.vocab_size, size=8), max_new_tokens=3)
    eng.run_to_completion(50)                        # seed prefill lengths
    built = eng.warm_compile(None, DesignPoint(cus=0, slots=4))
    assert built >= 1
    before = eng.compile_builds
    eng.apply(None, DesignPoint(cus=0, slots=4))
    eng.submit(rng.integers(1, cfg.vocab_size, size=8), max_new_tokens=3)
    eng.run_to_completion(50)
    assert eng.compile_builds == before, \
        "reconfigured engine re-compiled a program warm_compile had built"


# ---------------------------------------------------------------------------
# predicted-vs-measured accounting: a dse-driven retune must leave a ledger
# entry pairing Stage 1's predicted unit cost with the measured step p50
# (8 fake host devices, subprocess — device count is fixed at first init)
# ---------------------------------------------------------------------------

def test_design_key_is_compact_and_total():
    from repro.serve.dse import design_key
    assert design_key(4, {"tp": 2, "dp": 1, "slots": 8,
                          "buckets": None}) == "c4-tp2-dp1-s8"
    assert design_key(2, {"tp": None, "dp": None, "slots": 4,
                          "buckets": (128, 512)}) == "c2-tp0-dp1-s4-b128.512"


def test_predicted_vs_measured_after_dse_retune():
    import json
    import subprocess
    import sys
    import textwrap
    prelude = (
        "import os\n"
        'os.environ["XLA_FLAGS"] = '
        '"--xla_force_host_platform_device_count=8"\n'
        "import sys\n"
        'sys.path.insert(0, "src")\n'
        "import json\n"
        "import jax\n"
        "import numpy as np\n")
    body = textwrap.dedent("""
    import dataclasses
    from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                    TenantSpec)
    from repro.serve import ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=48, eos_id=-1)
    tenants = [TenantSpec("a", "minitron-4b",
                          serve=dataclasses.replace(sc, slot_cap=4)),
               TenantSpec("b", "qwen2.5-32b", seed=1, serve=sc)]
    srv = ComposedServer(mesh, tenants, policy=AnalyticalPolicy(),
                         decide_every=3)
    rng = np.random.default_rng(0)
    for t, n in (("a", 16), ("b", 6)):      # queue depth >> default slots
        vocab = srv.cfgs[t].vocab_size
        for _ in range(n):
            srv.submit(t, rng.integers(1, vocab, size=8), max_new_tokens=10)
    srv.drain(max_steps=500)
    pvm = srv.stats()["predicted_vs_measured"]
    committed = {k: e for k, e in pvm["entries"].items()
                 if e["commits"] > 0 and e["ratio"] is not None}
    print(json.dumps({
        "recompositions": srv.stats()["recompositions"],
        "n_entries": len(pvm["entries"]),
        "n_committed_with_ratio": len(committed),
        "classes": sorted({e["class"] for e in committed.values()}),
        "ratios_finite": all(e["ratio"] > 0 for e in committed.values()),
        "agg": pvm["aggregate"],
    }))
    """)
    out = subprocess.run([sys.executable, "-c", prelude + body],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["recompositions"] >= 1
    # at least one policy-committed design point accumulated measured
    # steps under the same key -> a predicted/measured ratio exists
    assert res["n_committed_with_ratio"] >= 1
    assert res["ratios_finite"]
    assert res["agg"]["entries_with_both"] >= 1
    assert res["agg"]["mean_abs_log2_error"] >= 0
