"""Model facade: ``build_model(cfg)`` -> a :class:`Model` with a uniform API
for training, prefill and decode across all 10 assigned architectures.

``input_specs(cfg, cell)`` provides ShapeDtypeStruct stand-ins for every model
input of a shape cell (the dry-run contract): token ids for LM/VLM archs,
precomputed frame embeddings for the audio enc-dec (frontend STUB).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.distribution.partitioning import Annotated
from repro.models import layers as L
from repro.models import transformer as T

PyTree = Any


def _embed_init(rng, cfg: ModelConfig):
    std = cfg.d_model ** -0.5
    return Annotated(
        jax.random.normal(rng, (cfg.padded_vocab, cfg.d_model)) * std,
        ("vocab", "embed"))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    def init(self, rng) -> PyTree:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        params: Dict[str, PyTree] = {
            "embed": _embed_init(ks[0], cfg),
            "decoder": T.decoder_init(ks[1], cfg, cross=cfg.cross_attention),
            "final_norm": L.norm_init(cfg.norm, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(
                ks[2], cfg.d_model, cfg.padded_vocab, ("embed", "vocab"),
                std=cfg.d_model ** -0.5)
        if cfg.is_encdec:
            params["encoder"] = T.encoder_init(ks[3], cfg)
            if cfg.frontend == "frames":
                params["frame_norm"] = L.norm_init(cfg.norm, cfg.d_model)
        pd = jnp.dtype(self.cfg.param_dtype)
        if pd != jnp.float32:
            params = jax.tree.map(
                lambda a: Annotated(
                    a.value.astype(pd)
                    if jnp.issubdtype(a.value.dtype, jnp.floating) else a.value,
                    a.logical),
                params, is_leaf=lambda x: isinstance(x, Annotated))
        return params

    # ------------------------------------------------------------------
    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _mask_pad(self, logits):
        """-inf on vocab-padding columns so sampling never emits them."""
        V = self.cfg.vocab_size
        if logits.shape[-1] == V:
            return logits
        ok = jnp.arange(logits.shape[-1]) < V
        return jnp.where(ok, logits, -1e30)

    def _encode(self, params, frames, attn_impl="blockwise", src_len=None):
        """src_len: optional per-row (B,) valid frame counts when the batch
        is right-padded — the bidirectional stack then masks each row's own
        key padding, so valid rows are independent of the padded shape
        (bucket-invariant encodes; ROADMAP enc-dec follow-up)."""
        cfg = self.cfg
        x = L.apply_norm(cfg.norm, params["frame_norm"],
                         frames.astype(cfg.activation_dtype), cfg.norm_eps)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        return T.encoder_fwd(params["encoder"], cfg, x, pos,
                             attn_impl=attn_impl, kv_len=src_len), pos

    # ------------------------------------------------------------------
    def loss(self, params, batch, *, attn_impl: str = "blockwise",
             moe_dispatch: str = "einsum", residual_spec=None,
             aux_weight: float = 0.01, ssm_impl: str = "chunked",
             attn_block: int = 512):
        """batch: {tokens, labels[, frames]} -> (loss, metrics)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        enc_out = enc_pos = None
        if cfg.is_encdec:
            enc_out, enc_pos = self._encode(params, batch["frames"], attn_impl)
        x, aux = T.decoder_fwd(params["decoder"], cfg, x, pos,
                               attn_impl=attn_impl, enc_out=enc_out,
                               enc_positions=enc_pos,
                               moe_dispatch=moe_dispatch,
                               residual_spec=residual_spec,
                               ssm_impl=ssm_impl, attn_block=attn_block)
        x = L.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        xent = T.chunked_softmax_xent(x, self._head(params),
                                      jnp.maximum(labels, 0), mask,
                                      logit_softcap=cfg.logit_softcap)
        loss = xent + aux_weight * aux
        return loss, {"xent": xent, "aux": aux}

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *, src_len: int = 0):
        """Pooled decode cache for ``batch`` slots of ``max_len`` tokens.

        src_len: cross-attention source capacity (enc-dec archs only) —
        allocates per-layer (batch, src_len, kv_heads, head_dim) cross-K/V
        buffers and a per-row ``src_len`` int32 vector recording each slot's
        *valid* source length (continuous batching mixes source lengths, so
        the mask bound is per row, not per pool).
        """
        cfg = self.cfg
        dtype = cfg.activation_dtype
        cache = T.decoder_cache_init(cfg, batch, max_len, dtype,
                                     cross_src=src_len if cfg.is_encdec else 0)
        if cfg.is_encdec:
            cache["src_len"] = jnp.full((batch,), src_len, jnp.int32)
        return cache

    @staticmethod
    def cache_slot_axes(cache):
        """Batch-slot axis per cache leaf (see transformer.cache_slot_axes)."""
        return T.cache_slot_axes(cache)

    def prefill(self, params, batch, cache, *, attn_impl: str = "blockwise",
                moe_dispatch: str = "einsum", residual_spec=None,
                true_len=None, enc_out=None, src_len=None,
                attn_block: int = 512):
        """Run the prompt through the model, filling the cache.

        true_len: optional (B,) or scalar valid prompt lengths when the
        prompt is right-padded (continuous batching).  Returns logits at the
        last *valid* position per row, and the cache with per-row positions.

        Enc-dec archs additionally accept:

        * enc_out — precomputed encoder hidden states (B, S_src, d); when
          given the encoder stack is skipped (the serving engine encodes
          sources in a separate batched, bucketed program and prefills the
          decoder per slot from the shared output);
        * src_len — int32 scalar or (B,) valid source lengths when the
          encoder output is right-padded: masks cross-attention reads and
          is recorded per row in the returned cache's ``src_len`` vector
          (the bound ``decode_step``'s cross-attention reads honour).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
        pos = jnp.broadcast_to(jnp.arange(S), tokens.shape)
        enc_pos = None
        if cfg.is_encdec:
            if enc_out is None:
                enc_out, enc_pos = self._encode(params, batch["frames"],
                                                attn_impl)
            else:
                enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                           enc_out.shape[:2])
        else:
            enc_out = None
        x, cache = T.decoder_prefill(params["decoder"], cfg, x, pos, cache,
                                     attn_impl=attn_impl, enc_out=enc_out,
                                     enc_positions=enc_pos, src_len=src_len,
                                     moe_dispatch=moe_dispatch,
                                     residual_spec=residual_spec,
                                     true_len=true_len,
                                     attn_block=attn_block)
        x = L.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        if true_len is None:
            last = x[:, -1]
        else:
            idx = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32), (B,)) - 1
            last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
        logits = self._mask_pad(jnp.einsum(
            "bd,dv->bv", last, self._head(params).astype(x.dtype)))
        out_cache = dict(cache)
        if cfg.is_encdec:
            src = enc_out.shape[1] if src_len is None else src_len
            out_cache["src_len"] = jnp.broadcast_to(
                jnp.asarray(src, jnp.int32), (B,))
        return logits, out_cache

    def encode(self, params, batch, *, attn_impl: str = "blockwise",
               lens=None):
        """Full-sequence hidden states for prefill-only / embedding
        workloads (no cache, no decode loop) -> (B, S, d).

        Enc-dec archs run the bidirectional encoder stack (over ``frames``
        when provided, else the token embeddings stand in for the
        precomputed frame embeddings — the frontend is a STUB); decoder-only
        archs (dense/MoE/SSM alike) run the causal decoder stack and return
        the final-norm hidden states.  This is what the throughput-oriented
        EncoderEngine batches: compute-bound full-sequence matmuls, priced
        as such by the class-aware recomposition policy.

        lens: optional per-row (B,) valid lengths for right-padded batches.
        A bidirectional stack masks each row's key padding with them, making
        a row's encode independent of the padded program shape (the serving
        engines' bucketed programs are then bucket-invariant); causal stacks
        are padding-proof by construction, so lens is ignored there.
        """
        cfg = self.cfg
        if cfg.is_encdec:
            frames = batch.get("frames")
            if frames is None:
                frames = jnp.take(params["embed"], batch["tokens"], axis=0)
            enc_out, _ = self._encode(params, frames, attn_impl,
                                      src_len=lens)
            return enc_out
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        x, _ = T.decoder_fwd(params["decoder"], cfg, x, pos,
                             attn_impl=attn_impl)
        return L.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)

    def decode_step(self, params, cache, tokens, *, moe_dispatch: str = "einsum",
                    use_kernels: bool = False, kv_bound=None, src_bound=None,
                    live_mask=None):
        """tokens: (B, 1) -> (logits (B, V), cache).

        use_kernels enables the ragged decode path: KV (and enc-dec
        cross-KV) reads are bounded to the static ``kv_bound``/``src_bound``
        prefixes the engine derives from true lengths, and ``live_mask``
        (B,) lets kernels skip empty slots.  Live rows are bit-identical to
        the padded path."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
        src_len = cache.get("src_len") if cfg.is_encdec else None
        extra = {k: v for k, v in cache.items()
                 if k in ("prologue", "scanned", "pos")}
        x, new_cache = T.decoder_step(params["decoder"], cfg, x, extra,
                                      src_len=src_len, moe_dispatch=moe_dispatch,
                                      use_kernels=use_kernels,
                                      kv_bound=kv_bound, src_bound=src_bound,
                                      live=live_mask)
        x = L.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        logits = self._mask_pad(jnp.einsum(
            "bd,dv->bv", x[:, 0], self._head(params).astype(x.dtype)))
        if cfg.is_encdec:
            new_cache["src_len"] = src_len
        return logits, new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

ENCDEC_DECODE_SRC = 4096   # source frames for enc-dec decode cells (DESIGN.md §4)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    act = cfg.activation_dtype
    if cell.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.is_encdec and cfg.frontend == "frames":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), act)
        return specs
    if cell.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec and cfg.frontend == "frames":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), act)
        return specs
    if cell.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    raise ValueError(cell.kind)
