"""Fabric-wide telemetry: metrics registry, span tracer, prediction ledger.

The one object threaded through the serving stack is :class:`Telemetry` —
a thin handle bundling a :class:`MetricsRegistry`, a :class:`SpanTracer`,
a pre-bound label set, and an ``enabled`` flag.  Engines call
``obs.observe(...)`` / ``obs.span(...)`` unconditionally; when telemetry
is disabled every call is a constant-time no-op (and ``span`` returns a
shared null context manager), so token streams are bit-identical with
telemetry on or off.

Scoping rules:

* ``scoped(**labels)`` shares the registry and tracer but appends labels
  (e.g. the fabric hands each tenant's group ``scoped(tenant=..,
  wclass=..)``).
* ``fresh()`` keeps labels and tracer but allocates a *new* registry —
  used per dp replica so :class:`~repro.serve.fabric.ReplicaGroup` can
  merge replica histograms (and harvest a retired replica's registry on
  a dp shrink) without double counting.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      bucket_bounds, metric_key)
from .tracing import NULL_SPAN, SpanTracer, trace_span
from .accounting import PredictionLedger

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "PredictionLedger",
    "SpanTracer",
    "Telemetry",
    "bucket_bounds",
    "metric_key",
    "trace_span",
]


class Telemetry:
    """Handle = (registry, tracer, bound labels, enabled flag)."""

    __slots__ = ("registry", "tracer", "labels", "enabled")

    def __init__(self,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[SpanTracer] = None,
                 labels: Tuple[Tuple[str, str], ...] = (),
                 enabled: bool = True) -> None:
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else SpanTracer()
        self.labels = labels
        self.enabled = enabled

    @classmethod
    def off(cls) -> "Telemetry":
        """Disabled handle: every record call is a no-op."""
        return cls(enabled=False)

    # -- scoping ----------------------------------------------------------
    def scoped(self, **labels: str) -> "Telemetry":
        """Same registry/tracer, extra bound labels."""
        merged = tuple(sorted(dict(self.labels, **{
            k: str(v) for k, v in labels.items()}).items()))
        return Telemetry(self.registry, self.tracer, merged, self.enabled)

    def fresh(self) -> "Telemetry":
        """Same labels/tracer, new registry (one per dp replica)."""
        return Telemetry(MetricsRegistry(), self.tracer, self.labels,
                         self.enabled)

    # -- record path ------------------------------------------------------
    def observe(self, name: str, value: float) -> None:
        if self.enabled:
            self.registry.histogram_at(name, self.labels).observe(value)

    def inc(self, name: str, n=1) -> None:
        if self.enabled:
            self.registry.counter_at(name, self.labels).inc(n)

    def set_gauge(self, name: str, value: float) -> None:
        if self.enabled:
            self.registry.gauge_at(name, self.labels).value = value

    def span(self, name: str, **args: Any):
        """Trace-only context manager (null CM when disabled)."""
        if not self.enabled:
            return NULL_SPAN
        return self.tracer.span(name, **args)

    @contextmanager
    def _timed(self, span_name: str, hist_name: Optional[str],
               args: Dict[str, Any]):
        t0 = time.perf_counter()
        try:
            yield args
        finally:
            t1 = time.perf_counter()
            self.tracer.record(span_name, t0, t1, args or None)
            if hist_name is not None:
                self.registry.histogram_at(
                    hist_name, self.labels).observe(t1 - t0)

    def timed(self, span_name: str, hist_name: Optional[str] = None,
              **args: Any):
        """Span + latency histogram in one context manager.

        Yields the span's args dict so callers can attach fields computed
        inside the block."""
        if not self.enabled:
            return NULL_SPAN
        return self._timed(span_name, hist_name, dict(args) if args else {})
