"""cache-key: ServeConfig reads in program builders must be keyed.

The PR-5 shape-poisoning bug class: a program builder (``_build_*``) reads
a ``ServeConfig`` field that shapes the compiled program, but the field is
missing from ``_config_key`` — so two design points share one executable
and the second one runs the first one's shapes.  This rule collects, per
engine class, the set of ``cfg.*`` attributes that reach the cache key
(reads inside any ``_config_key`` in the MRO, plus ``cfg.*`` arguments at
``_config_key(...)`` call sites — ``max_slots`` enters the decode key that
way) and flags any ``self.cfg.X`` read inside a builder — transitively
through self-calls *including jit-traced fns*, whose reads are literally
baked into the program — that never reaches the key.

``self.model.cfg`` appearing in a key covers all model-config reads.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from tools.fabriclint import Finding
from tools.fabriclint.walker import ClassInfo, FuncInfo, Index, snippet

RULE = "cache-key"

KEY_FN = "_config_key"
BUILDER_PREFIX = "_build"
MAX_DEPTH = 6


def _cfg_reads(fn: ast.AST) -> List[Tuple[str, int, ast.AST]]:
    """(attr, line, node) for every ``self.cfg.X`` / bare ``cfg.X`` read."""
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Attribute):
            continue
        base = node.value
        if isinstance(base, ast.Attribute) and base.attr == "cfg" \
                and isinstance(base.value, ast.Name) \
                and base.value.id == "self":
            out.append((node.attr, node.lineno, node))
        elif isinstance(base, ast.Name) and base.id == "cfg":
            out.append((node.attr, node.lineno, node))
    return out


def _keyed_attrs(index: Index, chain: List[ClassInfo]) -> Set[str]:
    keyed: Set[str] = set()
    for cls in chain:
        key_fn = cls.methods.get(KEY_FN)
        if key_fn is not None:
            for attr, _, _ in _cfg_reads(key_fn.node):
                keyed.add(attr)
    # call sites: self._config_key(cfg.max_slots, ...) keys the argument
    for cls in chain:
        for fn in cls.methods.values():
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr == KEY_FN:
                    for arg in node.args:
                        for attr, _, _ in _cfg_reads(ast.Expression(body=arg)):
                            keyed.add(attr)
    return keyed


def _model_cfg_keyed(chain: List[ClassInfo]) -> bool:
    for cls in chain:
        key_fn = cls.methods.get(KEY_FN)
        if key_fn is None:
            continue
        for node in ast.walk(key_fn.node):
            if isinstance(node, ast.Attribute) and node.attr == "cfg" \
                    and isinstance(node.value, ast.Attribute) \
                    and node.value.attr == "model":
                return True
    return False


def _builder_closure(index: Index, cls: ClassInfo,
                     builder: FuncInfo) -> List[FuncInfo]:
    """The builder plus self-methods it transitively calls within the
    class's MRO — jit-traced fns included (their cfg reads are baked into
    the compiled program, the exact thing the key must cover)."""
    chain = index.mro_chain(cls)
    seen: Dict[str, FuncInfo] = {}
    frontier = [builder]
    depth = 0
    while frontier and depth < MAX_DEPTH:
        nxt: List[FuncInfo] = []
        for fn in frontier:
            if fn.name in seen:
                continue
            seen[fn.name] = fn
            for callee in sorted(fn.calls | fn.lambda_calls):
                if callee in seen:
                    continue
                for c in chain:
                    if callee in c.methods:
                        nxt.append(c.methods[callee])
                        break
        frontier = nxt
        depth += 1
    return list(seen.values())


def check(index: Index, config: Dict) -> List[Finding]:
    findings: List[Finding] = []
    for classes in index.classes.values():
        for cls in classes:
            chain = index.mro_chain(cls)
            if not any(KEY_FN in c.methods for c in chain):
                continue
            builders = [fn for name, fn in cls.methods.items()
                        if name.startswith(BUILDER_PREFIX)]
            if not builders:
                continue
            keyed = _keyed_attrs(index, chain)
            model_keyed = _model_cfg_keyed(chain)
            seen_sites = set()
            for builder in builders:
                for fn in _builder_closure(index, cls, builder):
                    for attr, line, node in _cfg_reads(fn.node):
                        if attr in keyed:
                            continue
                        site = (fn.path, line, attr)
                        if site in seen_sites:
                            continue
                        seen_sites.add(site)
                        findings.append(Finding(
                            rule=RULE, path=fn.path, line=line,
                            symbol=f"{cls.name}.{fn.name}",
                            code=f"cfg.{attr}",
                            message=(f"builder `{builder.name}` reads "
                                     f"`self.cfg.{attr}` (via `{fn.name}`) "
                                     f"but `{KEY_FN}` never keys it — "
                                     "two design points could share one "
                                     "executable (PR-5 shape poisoning)")))
    return findings
