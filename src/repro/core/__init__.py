"""FILCO core: the paper's contribution as composable JAX/Python modules.

  instructions — the Table-1 ISA with binary encode/decode
  arena        — FlexArena: 1-D buffers + runtime 2-D views (FMV + FMF)
  analytical   — latency model over accelerator design points (FILCO,
                 CHARM-1/2/3, RSN) on VCK190 and TPU v5e profiles
  modes        — Stage-1 Runtime Parameter Optimizer (brute force)
  schedule     — scheduling problem + validator (Eq. 1-6 semantics)
  milp         — explicit MILP formulation + exact branch-and-bound solver
  ga           — the paper's GA heuristic (Encode/Candidate chromosome)
  dse          — two-stage DSE driver -> ExecutionPlan
  codegen      — ExecutionPlan -> per-unit instruction streams
  simulator    — functional data-plane simulator (numerics ground truth)
  composer     — mesh composition into unified / independent accelerators
"""
from repro.core import (
    analytical,
    arena,
    codegen,
    composer,
    dse,
    ga,
    instructions,
    milp,
    modes,
    schedule,
    simulator,
)

__all__ = [
    "analytical", "arena", "codegen", "composer", "dse", "ga",
    "instructions", "milp", "modes", "schedule", "simulator",
]
