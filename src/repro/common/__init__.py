from repro.common.platform import PROFILES, TPU_V5E, VCK190, PlatformProfile, get_profile

__all__ = [
    "PROFILES",
    "TPU_V5E",
    "VCK190",
    "PlatformProfile",
    "get_profile",
]
