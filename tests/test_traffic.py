"""Traffic-generator determinism (satellite of the paged-KV / SLO PR).

The benchmark's paired arms (paged + preemptive vs slot-granular baseline)
only compare cleanly if both replay the *identical* offered load, so the
generator must be a pure function of ``(profile, tenants, R, seed)``.
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.traffic import PROFILES, arrival_schedule


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       profile=st.sampled_from(list(PROFILES)),
       n=st.integers(1, 12))
def test_same_seed_replays_identical_schedule(seed, profile, n):
    ts = ["a", "b", "c"]
    assert (arrival_schedule(profile, ts, n, seed)
            == arrival_schedule(profile, ts, n, seed))


def test_different_seeds_differ():
    assert (arrival_schedule("bursty", ["x", "y"], 8, 0)
            != arrival_schedule("bursty", ["x", "y"], 8, 1))


def test_schedules_sorted_and_complete():
    for p in PROFILES:
        s = arrival_schedule(p, ["x", "y"], 8, 3)
        assert len(s) == 16
        assert [a.step for a in s] == sorted(a.step for a in s)
        assert all(0 <= a.step < 32 for a in s)          # horizon = 4R
        assert all(4 <= a.prompt_len < 24 for a in s)
        assert all(a.max_new >= 1 for a in s)
        per = {t: sum(a.tenant == t for a in s) for t in ("x", "y")}
        assert per == {"x": 8, "y": 8}


def test_flash_crowd_compresses_first_tenant():
    s = arrival_schedule("flash-crowd", ["victim", "bg"], 16, 0)
    v = [a.step for a in s if a.tenant == "victim"]
    bg = [a.step for a in s if a.tenant == "bg"]
    assert max(v) - min(v) < max(16 // 8, 1)             # inside the window
    assert max(bg) - min(bg) > max(v) - min(v)           # others spread out


def test_heavy_tail_draws_long_budgets():
    s = arrival_schedule("heavy-tail", ["x"], 64, 1, max_new=16)
    assert all(16 <= a.max_new <= 8 * 16 for a in s)     # tail >= base, capped
    assert any(a.max_new > 2 * 16 for a in s)            # and actually heavy


def test_unknown_profile_raises():
    with pytest.raises(ValueError):
        arrival_schedule("nope", ["x"], 1, 0)
