"""Fig. 9 reproduction: throughput over a (#operations x diversity) grid of
Transformer-style MM workloads, FILCO vs CHARM-1/2/3 vs RSN.

Per paper §4.2, workloads vary sequence length, head count, head dim and MLP
ratio; we bucket them by total ops and by the shape-diversity metric and
report modeled throughput per design point (best-sub-accelerator latency per
layer, the same routing the paper's baselines get).
"""
from __future__ import annotations

import numpy as np

from repro.common.platform import VCK190
from repro.configs.paper_workloads import MMWorkload, bert
from repro.core.analytical import (best_accel_latency, charm_monolithic,
                                   charm_three, charm_two, filco_vck190,
                                   rsn_overlay)

SYSTEMS = {
    "CHARM-1": charm_monolithic(),
    "CHARM-2": charm_two(),
    "CHARM-3": charm_three(),
    "RSN": rsn_overlay(),
    "FILCO": [filco_vck190()],
}


def synth_workloads():
    """Grid over (seq, d_model, heads, mlp_ratio) per paper §4.2."""
    out = []
    for seq in (32, 64, 128, 256, 512):
        for d, heads in ((256, 4), (512, 8), (768, 12)):
            for ratio in (2, 4):
                wl = bert(seq, d=d, heads=heads, d_ff=ratio * d, layers=2,
                          name=f"tf_s{seq}_d{d}_r{ratio}")
                out.append(wl)
    return out


def throughput(accels, wl: MMWorkload) -> float:
    t = sum(best_accel_latency(accels, VCK190, l.m, l.k, l.n).total_s
            for l in wl.layers)
    return wl.total_flops / t


def run(check: bool = True):
    wls = synth_workloads()
    rows = []
    for wl in wls:
        entry = {"workload": wl.name, "gflop": wl.total_flops / 1e9,
                 "diversity": wl.diversity()}
        for name, acc in SYSTEMS.items():
            entry[name] = throughput(acc, wl) / 1e9
        rows.append(entry)
    # paper claims: 1.3x on large/low-diversity; >=5x on small/diverse
    big = max(rows, key=lambda r: r["gflop"])
    small = min(rows, key=lambda r: r["gflop"])
    gain_big = big["FILCO"] / max(big["CHARM-1"], big["RSN"])
    gain_small = small["FILCO"] / max(small["CHARM-1"], small["RSN"])
    summary = {"gain_large_low_div": gain_big, "gain_small_diverse": gain_small}
    if check:
        assert gain_big >= 1.0
        assert gain_small >= 2.0, summary
        for r in rows:
            assert r["FILCO"] >= 0.99 * max(r["CHARM-1"], r["CHARM-2"],
                                            r["CHARM-3"], r["RSN"]), r
    return {"rows": rows, "summary": summary}


def main():
    res = run()
    for r in res["rows"]:
        print(f"fig9,{r['workload']},{r['gflop']:.2f}GF,"
              f"div={r['diversity']:.2f},"
              + ",".join(f"{s}={r[s]:.1f}" for s in SYSTEMS))
    s = res["summary"]
    print(f"fig9_summary,gain_large={s['gain_large_low_div']:.2f}x,"
          f"gain_small={s['gain_small_diverse']:.2f}x,")
    return res


if __name__ == "__main__":
    main()
