"""Heterogeneous workload subsystem: SSM serving numerics (chunked-scan
prefill == step-by-step decode state; streams invariant across TP degree and
live recomposition), encoder embedding invariance, enc-dec decode through
the fabric (cross-attention source-cache correctness vs a monolithic Model
forward; streams invariant across live recomposition), class-aware policy
costing, and the mixed-fleet end-to-end acceptance (one fabric, four
workload classes, outputs bit-identical across a live move between classes).

Device-touching scenarios run in an 8-host-device subprocess (device count
is fixed at first jax init), mirroring tests/test_fabric.py."""
import dataclasses
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import build_model, ssm as S
from repro.distribution import strip
from repro.serve.fabric import AnalyticalPolicy, TenantObservation
from repro.workloads import (DECODE, ENCDEC, ENCODER, SSM, DecodeEngine,
                             EncDecEngine, EncoderEngine, Engine,
                             ExecutableCache, SSMEngine, ServeConfig,
                             length_buckets, pick_bucket, workload_class_of)


def _fm_cfg():
    return dataclasses.replace(get_reduced("falcon-mamba-7b"),
                               dtype="float32")


def _s2t_cfg():
    return dataclasses.replace(get_reduced("seamless-m4t-medium"),
                               dtype="float32")


@pytest.fixture(scope="module")
def mamba():
    cfg = _fm_cfg()
    model = build_model(cfg)
    params = strip(model.init(jax.random.key(0)))
    return cfg, model, params


# ---------------------------------------------------------------------------
# SSM numerics: the chunked-scan prefill must land the exact state the
# step-by-step recurrence would (admission via mamba_prefill is only sound
# if subsequent mamba_step decodes continue from an equivalent state)
# ---------------------------------------------------------------------------

def test_mamba_prefill_state_matches_stepwise():
    cfg = _fm_cfg()
    block = S.mamba_init(jax.random.key(0), cfg)
    block = strip(block)
    B, Sq = 2, 11                      # odd length: exercises scan padding
    x = np.asarray(jax.random.normal(jax.random.key(1),
                                     (B, Sq, cfg.d_model)), np.float32)
    cache0 = strip(S.mamba_cache_init(cfg, B, np.float32))

    out_p, cache_p = S.mamba_prefill(block, cfg, x, cache0, chunk=4)

    cache_s = cache0
    outs = []
    for t in range(Sq):
        y, cache_s = S.mamba_step(block, cfg, x[:, t:t + 1], cache_s)
        outs.append(y)
    out_s = np.concatenate([np.asarray(o) for o in outs], axis=1)

    np.testing.assert_allclose(np.asarray(cache_p["h"]),
                               np.asarray(cache_s["h"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache_p["conv"]),
                               np.asarray(cache_s["conv"]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_p), out_s,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# constant-size state pool: admission is slot-bound, never length-bound
# ---------------------------------------------------------------------------

def test_ssm_engine_admits_beyond_max_len(mamba):
    """An SSM request whose prompt + budget exceeds max_len still serves:
    the recurrent state is O(1) per slot.  The same request on a transformer
    DecodeEngine is rejected (KV would overflow the slot)."""
    cfg, model, params = mamba
    sc = ServeConfig(max_slots=2, max_len=16, eos_id=-1)
    prompt = np.arange(1, 40) % cfg.vocab_size       # 39 tokens >> max_len

    eng = SSMEngine(model, params, sc)
    rid = eng.submit(prompt, max_new_tokens=5)
    out = eng.run_to_completion(100)
    assert len(out[rid]) == 5

    dec = DecodeEngine(model, params, sc)
    rid2 = dec.submit(prompt, max_new_tokens=5)
    out2 = dec.run_to_completion(100)
    assert out2[rid2] == []            # rejected-but-recorded


def test_ssm_engine_arena_is_slot_bound(mamba):
    """Arena capacity reflects slots x constant state, independent of
    max_len; a full slot pool backpressures, a free one admits."""
    cfg, model, params = mamba
    a = SSMEngine(model, params, ServeConfig(max_slots=2, max_len=16,
                                             eos_id=-1))
    b = SSMEngine(model, params, ServeConfig(max_slots=2, max_len=4096,
                                             eos_id=-1))
    assert a.arena.capacity == b.arena.capacity
    assert a.arena.capacity == 2 * S.state_elems(cfg) * cfg.num_layers


def test_ssm_engine_rejects_kv_archs(mamba):
    cfg, model, params = mamba
    qcfg = get_reduced("qwen2.5-32b")
    qmodel = build_model(qcfg)
    qparams = strip(qmodel.init(jax.random.key(0)))
    with pytest.raises(ValueError):
        SSMEngine(qmodel, qparams, ServeConfig())


def test_workload_class_derivation():
    assert workload_class_of(_fm_cfg()) == SSM
    assert workload_class_of(get_reduced("qwen2.5-32b")) == DECODE
    assert workload_class_of(get_reduced("hymba-1.5b")) == DECODE  # hybrid: KV
    assert workload_class_of(_s2t_cfg()) == ENCDEC  # enc-dec: full jobs


def test_length_bucket_ladder():
    assert length_buckets((), 128) == (128,)
    assert length_buckets((512, 128, 999), 512) == (128, 512)
    ladder = length_buckets((8, 16), 32)
    assert ladder == (8, 16, 32)
    assert pick_bucket(ladder, 5) == 8
    assert pick_bucket(ladder, 8) == 8
    assert pick_bucket(ladder, 9) == 16
    assert pick_bucket(ladder, 30) == 32


def test_engines_satisfy_protocol(mamba):
    cfg, model, params = mamba
    eng = SSMEngine(model, params, ServeConfig(max_slots=1, eos_id=-1))
    enc = EncoderEngine(model, params, ServeConfig(max_slots=1, max_len=16))
    assert isinstance(eng, Engine) and isinstance(enc, Engine)


# ---------------------------------------------------------------------------
# enc-dec decode through the fabric: cross-attention source cache
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def seamless():
    cfg = _s2t_cfg()
    model = build_model(cfg)
    params = strip(model.init(jax.random.key(0)))
    return cfg, model, params


def test_encdec_engine_satisfies_protocol(seamless):
    cfg, model, params = seamless
    eng = EncDecEngine(model, params,
                       ServeConfig(max_slots=1, max_len=16, eos_id=-1,
                                   max_src_len=8))
    assert isinstance(eng, Engine)
    assert eng.workload_class == ENCDEC


def test_encdec_rejects_decoder_only_archs():
    qcfg = get_reduced("qwen2.5-32b")
    qmodel = build_model(qcfg)
    qparams = strip(qmodel.init(jax.random.key(0)))
    with pytest.raises(ValueError):
        EncDecEngine(qmodel, qparams, ServeConfig())


def test_encdec_stream_matches_monolithic_forward(seamless):
    """Cross-attention cache correctness: the engine's pooled-slot decode —
    bucketed batched encode (key padding masked per row), per-slot cross
    K/V write, masked per-row src_len — must emit the exact token stream of
    a monolithic Model prefill + decode_step loop over the EXACT-LENGTH
    inputs: the padding mask makes bucketed encodes bit-identical to
    unpadded ones, so the reference needs no bucket knowledge at all."""
    cfg, model, params = seamless
    sc = ServeConfig(max_slots=1, max_len=16, eos_id=-1, max_src_len=12,
                     len_buckets=(8,))
    eng = EncDecEngine(model, params, sc)
    rng = np.random.default_rng(0)
    srcs = [rng.integers(1, cfg.vocab_size, size=L) for L in (5, 7, 11)]
    rids = [eng.submit(s, max_new_tokens=6) for s in srcs]
    out = eng.run_to_completion(200)
    # two sources share the 8-bucket, the 11-frame one runs at capacity
    assert eng.stats()["bucket_hits"] == {"8": 2, "12": 1}

    for s, rid in zip(srcs, rids):
        enc = model.encode(params, {"tokens": jnp.asarray(s[None])})
        cache = strip(model.init_cache(1, sc.max_len, src_len=len(s)))
        logits, cache = model.prefill(
            params, {"tokens": jnp.full((1, 1), sc.bos_id, jnp.int32)},
            cache, enc_out=enc, src_len=len(s))
        stream = [int(jnp.argmax(logits[0]))]
        for _ in range(5):
            logits, cache = model.decode_step(
                params, cache, jnp.asarray([[stream[-1]]], jnp.int32))
            stream.append(int(jnp.argmax(logits[0])))
        assert out[rid] == stream, \
            f"engine decode diverged from monolithic forward for rid {rid}"


def test_encdec_admission_backpressure_on_source_cache(seamless):
    """Admission is arena-bound across BOTH caches: when live source caches
    + decode budgets exhaust the arena, later jobs stay queued (never lost)
    and admit as slots free.  The arena is shrunk to one job's footprint so
    the source-cache rows are what blocks the second admission."""
    from repro.core.arena import FlexArena
    cfg, model, params = seamless
    sc = ServeConfig(max_slots=2, max_len=16, eos_id=-1, max_src_len=8)
    eng = EncDecEngine(model, params, sc)
    src, new = 8, 7
    rows = src + 1 + new                       # source + BOS + budget
    eng.arena = FlexArena(rows * eng._per_token_elems)
    rng = np.random.default_rng(0)
    r1 = eng.submit(rng.integers(1, cfg.vocab_size, size=src),
                    max_new_tokens=new)
    r2 = eng.submit(rng.integers(1, cfg.vocab_size, size=src),
                    max_new_tokens=new)
    eng.step()
    assert eng.active_count == 1 and eng.queue_depth == 1, \
        "second job should backpressure on the exhausted arena"
    out = eng.run_to_completion(200)
    assert len(out[r1]) == new and len(out[r2]) == new

    # oversized sources are rejected-but-recorded, like every other class
    r3 = eng.submit(rng.integers(1, cfg.vocab_size, size=9),  # > max_src_len
                    max_new_tokens=2)
    out = eng.run_to_completion(50)
    assert out[r3] == []


def test_encoder_embeddings_bucket_invariant(seamless):
    """ROADMAP-flagged bugfix: the bidirectional encoder masks each row's
    own bucket padding, so the same job's embedding is BIT-identical across
    different bucket ladders (before the fix, the padded program shape
    leaked into the numerics)."""
    cfg, model, params = seamless
    job = np.arange(1, 6) % cfg.vocab_size

    def run(buckets):
        eng = EncoderEngine(model, params,
                            ServeConfig(max_slots=2, max_len=32,
                                        len_buckets=buckets))
        rid = eng.submit(job)
        eng.run_to_completion(10)
        return eng.results()[rid]

    a, b, full = run((8,)), run((16,)), run(())
    assert a == b == full, \
        "bucket ladder changed a bidirectional embedding bit-for-bit"


def test_encdec_forced_decode_matches_monolithic(seamless):
    """Forced decoding: a target prefix threads through submit and the
    fused slot-prefill program — the stream must equal a monolithic Model
    prefill over [bos]+prefix (exact lengths) + greedy decode_step loop."""
    cfg, model, params = seamless
    sc = ServeConfig(max_slots=2, max_len=24, eos_id=-1, max_src_len=12,
                     len_buckets=(8,))
    eng = EncDecEngine(model, params, sc)
    rng = np.random.default_rng(0)
    src = rng.integers(1, cfg.vocab_size, size=7)
    prefix = rng.integers(1, cfg.vocab_size, size=4)
    rid = eng.submit(src, max_new_tokens=6, prefix=prefix)
    plain = eng.submit(src, max_new_tokens=6)        # BOS-only co-resident
    out = eng.run_to_completion(200)

    dec = np.concatenate([[sc.bos_id], prefix]).astype(np.int32)
    enc = model.encode(params, {"tokens": jnp.asarray(src[None])})
    cache = strip(model.init_cache(1, sc.max_len, src_len=len(src)))
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(dec[None])},
                                  cache, enc_out=enc, src_len=len(src))
    stream = [int(jnp.argmax(logits[0]))]
    for _ in range(5):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[stream[-1]]], jnp.int32))
        stream.append(int(jnp.argmax(logits[0])))
    assert out[rid] == stream, "forced decode diverged from monolithic"
    assert out[plain] != out[rid], \
        "prefix had no effect on the decoder stream"
    # arena accounting covers the prefix rows: src + (1+prefix) + budget
    from repro.workloads.decode import Request
    req = Request(0, src, 6, prefix=np.asarray(prefix, np.int32))
    assert eng._slot_rows(req) == len(src) + 1 + len(prefix) + 6
    # a prefix that overflows the decoder slot is a hard reject
    assert eng._oversized(Request(1, src, sc.max_len,
                                  prefix=np.asarray(prefix, np.int32)))


def test_encdec_accepts_precomputed_frames(seamless):
    """A real frontend's precomputed (S, d_model) frame embeddings enter
    submit directly — no token re-embedding — and (the STUB embedding
    being jnp.take on the embed table) produce the token path's exact
    stream; the embedded rows pay the same arena rows as token sources."""
    cfg, model, params = seamless
    # slot-granular arena: the reservation is the exact worst case below
    # (a paged table would cover live rows only, growing with decode)
    sc = ServeConfig(max_slots=2, max_len=24, eos_id=-1, max_src_len=12,
                     len_buckets=(8,), paged_kv=False)
    eng = EncDecEngine(model, params, sc)
    rng = np.random.default_rng(0)
    src = rng.integers(1, cfg.vocab_size, size=7)
    frames = np.asarray(params["embed"])[src]         # the STUB's embedding
    r_tok = eng.submit(src, max_new_tokens=6)
    r_frm = eng.submit(frames, max_new_tokens=6)
    # both jobs admitted: the frame job's arena view covers its frame rows
    eng.step()
    assert eng.active_count == 2
    views = {req.rid: req.view for req in eng._active.values()}
    assert views[r_frm].rows == views[r_tok].rows == 7 + 1 + 6
    # under paging both source kinds still pay identical (live) rows
    engp = EncDecEngine(model, params,
                        dataclasses.replace(sc, paged_kv=True))
    rp_tok = engp.submit(src, max_new_tokens=6)
    rp_frm = engp.submit(frames, max_new_tokens=6)
    engp.step()
    vp = {req.rid: req.view for req in engp._active.values()}
    assert vp[rp_frm].rows == vp[rp_tok].rows
    out = eng.run_to_completion(200)
    assert out[r_frm] == out[r_tok], \
        "precomputed frames diverged from the token-embedding path"
    # oversized frame sources reject-but-record like token sources
    r_big = eng.submit(np.zeros((13, cfg.d_model), np.float32),
                       max_new_tokens=2)
    out = eng.run_to_completion(50)
    assert out[r_big] == []


# ---------------------------------------------------------------------------
# shared executable cache: same-config engines reuse programs
# ---------------------------------------------------------------------------

def test_same_config_engines_share_executables(mamba):
    cfg, model, params = mamba
    shared = ExecutableCache(capacity=32)
    sc = ServeConfig(max_slots=2, max_len=32, eos_id=-1)
    a = SSMEngine(model, params, sc, exec_cache=shared)
    b = SSMEngine(model, params, sc, exec_cache=shared)
    prompt = np.arange(1, 9)
    a.submit(prompt, max_new_tokens=3)
    a.run_to_completion(50)
    assert a.compile_builds > 0
    b.submit(prompt, max_new_tokens=3)
    b.run_to_completion(50)
    assert b.compile_builds == 0, \
        "same-config tenant should hit the shared fabric cache"
    # different serve dims -> different program: no false sharing
    c = SSMEngine(model, params, ServeConfig(max_slots=3, max_len=32,
                                             eos_id=-1), exec_cache=shared)
    c.submit(prompt, max_new_tokens=3)
    c.run_to_completion(50)
    assert c.compile_builds > 0
    # different sharding rules -> different program: a replicated and a TP
    # engine of the same config must never share a compiled executable
    from repro.serve import serve_engine_rules
    ann = model.init(jax.random.key(0))     # annotated params (rules need them)
    d = SSMEngine(model, ann, sc, rules=serve_engine_rules(),
                  exec_cache=shared)
    d.submit(prompt, max_new_tokens=3)
    d.run_to_completion(50)
    assert d.compile_builds > 0


def test_encoder_bucketed_programs_match_full_capacity(mamba):
    """Bucketed sequence-length encode: every job runs in its OWN smallest
    fitting program (recorded in stats) and — causal stacks being
    padding-proof — emits exactly the embeddings of the full-capacity
    program."""
    cfg, model, params = mamba
    jobs = [np.arange(1, 1 + L) % cfg.vocab_size for L in (4, 6, 20, 3)]

    def run(buckets):
        eng = EncoderEngine(model, params,
                            ServeConfig(max_slots=2, max_len=32,
                                        len_buckets=buckets))
        for j in jobs:
            eng.submit(j)
        while eng.has_work:
            eng.step()
        return eng

    full = run(())
    bucketed = run((8, 16))
    assert full.stats()["bucket_hits"] == {"32": 4}
    # step 1 batches lens (4, 6) -> both 8-bucket; step 2 batches (20, 3)
    # -> split per job into the capacity program and the 8-bucket one
    assert bucketed.stats()["bucket_hits"] == {"8": 3, "16": 0, "32": 1}
    assert bucketed.results() == full.results(), \
        "bucketed encode changed a causal stack's embeddings"


def test_encoder_bucket_is_per_job_not_per_batch(seamless):
    """A job's bucket — hence the row padding a BIDIRECTIONAL stack sees —
    must be a function of the job alone: co-batching a short job with a
    long one must not change its embedding (arrival timing would otherwise
    alter results)."""
    cfg, model, params = seamless
    sc = ServeConfig(max_slots=2, max_len=32, len_buckets=(8,))
    short = np.arange(1, 5) % cfg.vocab_size
    long = np.arange(1, 21) % cfg.vocab_size

    alone = EncoderEngine(model, params, sc)
    r_alone = alone.submit(short)
    alone.run_to_completion(10)

    both = EncoderEngine(model, params, sc)
    r_both = both.submit(short)
    both.submit(long)                       # co-batched in the same step
    both.run_to_completion(10)

    assert both.results()[r_both] == alone.results()[r_alone], \
        "co-batching changed a bidirectional job's embedding"


def test_encoder_rejections_not_counted_as_throughput(mamba):
    """Oversized embedding jobs are rejected-but-recorded, and — like the
    decode engine's rejects — never emitted: emitted entries feed the
    fabric's per-class throughput accounting."""
    cfg, model, params = mamba
    enc = EncoderEngine(model, params, ServeConfig(max_slots=2, max_len=8))
    ok = enc.submit(np.arange(1, 6))
    bad = enc.submit(np.arange(1, 30))          # 29 tokens > max_len
    emitted = []
    while enc.has_work:
        emitted.extend(enc.step())
    assert [r for r, _ in emitted] == [ok]
    assert enc.results()[bad] == []             # recorded, empty
    assert len(enc.results()[ok]) == cfg.d_model
    assert enc.stats()["seqs_done"] == 1


# ---------------------------------------------------------------------------
# class-aware policy costing
# ---------------------------------------------------------------------------

def test_step_cost_cache_key_includes_workload_class():
    """Satellite regression: an SSM/encoder/encdec tenant sharing a cfg.name
    with a transformer tenant must not read a stale decode-GEMM price."""
    pol = AnalyticalPolicy()
    cfg = _fm_cfg()
    dec = pol.step_cost(cfg, 2, 4)                   # caches under DECODE
    ssm = pol.step_cost(cfg, 2, 4, SSM)
    enc = pol.step_cost(cfg, 2, 4, ENCODER)
    ed = pol.step_cost(cfg, 2, 4, ENCDEC, src_len=64)
    assert len({dec, ssm, enc, ed}) == 4
    # and the decode price is unchanged by the later class-keyed entries
    assert pol.step_cost(cfg, 2, 4) == dec


def test_step_cost_scales_down_with_cus_per_class():
    pol = AnalyticalPolicy()
    cfg = _fm_cfg()
    qcfg = get_reduced("qwen2.5-32b")
    scfg = _s2t_cfg()
    for c, wc in ((cfg, SSM), (qcfg, ENCODER), (qcfg, DECODE),
                  (scfg, ENCDEC)):
        assert pol.step_cost(c, 2, 4, wc) < pol.step_cost(c, 2, 1, wc)


def test_step_cost_encdec_prices_cross_attention_by_src_len():
    """The encdec step price (seconds per decode step) must grow with the
    source length — each step reads the whole per-slot cross-attention
    source cache — and the price must be keyed by src_len so two enc-dec
    tenants with different source capacities never share a stale entry."""
    pol = AnalyticalPolicy()
    cfg = _s2t_cfg()
    short = pol.step_cost(cfg, 2, 2, ENCDEC, src_len=64)
    long = pol.step_cost(cfg, 2, 2, ENCDEC, src_len=64 * 1024)
    assert long > short
    # cached entries survive interleaved queries at the other src_len
    assert pol.step_cost(cfg, 2, 2, ENCDEC, src_len=64) == short
    # an encdec step also prices the extra cross-projection GEMVs: it must
    # cost at least a plain decode step of the same dims
    assert pol.step_cost(cfg, 2, 2, ENCDEC, src_len=64) > \
        pol.step_cost(cfg, 2, 2, DECODE)


def _load(pending, active=1, util=0.0, wclass=None):
    return TenantObservation(pending_tokens=pending, queue_depth=0,
                             active=active, arena_utilization=util,
                             wclass=wclass)


def _cus(points):
    return {t: p.cus for t, p in points.items() if p.cus > 0}


def test_mixed_fleet_split_shifts_toward_owed_class():
    """The split search allocates CUs toward the class with owed work,
    under each class's own cost model."""
    cfgs = {"dec": get_reduced("minitron-4b"), "ssm": _fm_cfg(),
            "enc": get_reduced("qwen2.5-32b")}
    classes = {"dec": DECODE, "ssm": SSM, "enc": ENCODER}
    pol = AnalyticalPolicy()
    # the encoder tenant owes a large prefill backlog; others trickle
    points, reason = pol.decide(
        {t: _load(p, wclass=classes[t])
         for t, p in (("dec", 5), ("ssm", 5), ("enc", 5000))},
        cfgs, {"dec": 3, "ssm": 3, "enc": 2}, 8)
    sizes = _cus(points)
    assert reason in ("rebalance", "admit")
    assert sizes["enc"] > 2, f"expected encoder to gain CUs, got {sizes}"
    assert sizes["enc"] > sizes["dec"] and sizes["enc"] > sizes["ssm"]
    # now the SSM tenant owes the work
    points2, reason2 = pol.decide(
        {t: _load(p, wclass=classes[t])
         for t, p in (("dec", 5), ("ssm", 5000), ("enc", 5))},
        cfgs, {"dec": 3, "ssm": 3, "enc": 2}, 8)
    sizes2 = _cus(points2)
    assert sizes2["ssm"] >= sizes2["dec"] and sizes2["ssm"] >= sizes2["enc"]
    assert sizes2["ssm"] > 3 or reason2 == "hysteresis"


def test_policy_exposes_runner_up():
    cfgs = {"a": get_reduced("minitron-4b"), "b": get_reduced("minitron-4b")}
    pol = AnalyticalPolicy()
    points, reason = pol.decide({"a": _load(50), "b": _load(50)},
                                cfgs, {"a": 4, "b": 4}, 8)
    assert reason == "hysteresis"
    # staying put: the runner-up is the best alternative design, the one
    # the fabric speculatively prewarms during idle decide intervals
    assert pol.runner_up is not None
    assert sum(_cus(pol.runner_up).values()) == 8
    pol.decide({"a": _load(0), "b": _load(0)}, cfgs, {"a": 4, "b": 4}, 8)
    assert pol.runner_up is None       # idle fabric: nothing worth warming


# ---------------------------------------------------------------------------
# device scenarios (8 fake host devices, subprocess)
# ---------------------------------------------------------------------------

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import json
import jax
import numpy as np
"""


def _run(body: str, timeout=900):
    out = subprocess.run([sys.executable, "-c",
                          _PRELUDE + textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_ssm_tp_and_recomposition_stream_invariance():
    """SSM serving mirrors the transformer pins: token streams across 1-way
    (replicated) and 2-way TP sub-meshes are identical, including across a
    mid-stream recomposition that changes the TP degree."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.models import build_model
    from repro.serve import serve_engine_rules
    from repro.workloads import SSMEngine, ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    cfg = dataclasses.replace(get_reduced("falcon-mamba-7b"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=L)
               for L in (5, 9, 7)]              # few distinct exact lengths

    def run(tp, rules, script=None):
        eng = SSMEngine(model, params, sc,
                        mesh=comp.submesh(range(tp), f"tp{tp}"),
                        rules=rules)
        for p in prompts:
            eng.submit(p, max_new_tokens=10)
        step = 0
        while eng.has_work:
            if script and step in script:
                eng.reshard_to(comp.submesh(range(script[step]), "re"))
            eng.step()
            step += 1
            assert step < 200
        return {str(r): t for r, t in eng.results().items()}

    rules = serve_engine_rules()
    ref = run(1, None)                          # replicated baseline
    tp2 = run(2, rules)
    dyn = run(2, rules, {3: 1, 7: 4, 11: 2})    # shrink -> grow -> back
    print(json.dumps({"n": len(ref), "tp2": tp2 == ref, "dyn": dyn == ref}))
    """)
    assert res["n"] == 3
    assert res["tp2"], "TP SSM decode diverged from replicated"
    assert res["dyn"], "mid-stream recomposition altered the SSM stream"


def test_encoder_embeddings_invariant_across_moves():
    """Embedding outputs are bit-identical when the engine migrates between
    sub-accelerators (replicated), and equal across 1-way vs 2-way TP."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.models import build_model
    from repro.serve import serve_engine_rules
    from repro.workloads import EncoderEngine, ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    cfg = dataclasses.replace(get_reduced("qwen2.5-32b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sc = ServeConfig(max_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    jobs = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 20)))
            for _ in range(5)]

    def run(ids, rules, move=None):
        eng = EncoderEngine(model, params, sc,
                            mesh=comp.submesh(ids, "enc"), rules=rules)
        out = {}
        for i, j in enumerate(jobs):
            eng.submit(j)
            if move is not None and i == 2:
                eng.reshard_to(comp.submesh(move, "moved"))
            eng.step()
        return eng.results()

    ref = run(range(2), None)
    moved = run(range(2), None, move=[4, 5])     # same size, other devices
    tp2 = run(range(2), serve_engine_rules())
    exact = all(ref[r] == moved[r] for r in ref)
    close = all(np.allclose(ref[r], tp2[r], rtol=1e-5, atol=1e-6)
                for r in ref)
    print(json.dumps({"n": len(ref), "exact_across_move": exact,
                      "tp_close": close}))
    """)
    assert res["n"] == 5
    assert res["exact_across_move"], \
        "moving the encoder between same-size compositions changed outputs"
    assert res["tp_close"], "TP encoder diverged from replicated"


def test_encdec_streams_invariant_across_recomposition():
    """Acceptance pin: enc-dec decode streams are bit-identical across a
    mid-stream live recomposition (1->2 CU grow, then back) vs a never-moved
    reference run, and 2-way TP (with and without mid-stream degree changes)
    emits the replicated streams."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.models import build_model
    from repro.serve import serve_engine_rules
    from repro.workloads import EncDecEngine, ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    cfg = dataclasses.replace(get_reduced("seamless-m4t-medium"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sc = ServeConfig(max_slots=2, max_len=24, eos_id=-1, max_src_len=16,
                     len_buckets=(8,))
    rng = np.random.default_rng(0)
    srcs = [rng.integers(1, cfg.vocab_size, size=L) for L in (5, 9, 7, 13)]

    def run(tp, rules, script=None):
        eng = EncDecEngine(model, params, sc,
                           mesh=comp.submesh(range(tp), f"tp{tp}"),
                           rules=rules)
        for s in srcs:
            eng.submit(s, max_new_tokens=8)
        step = 0
        while eng.has_work:
            if script and step in script:
                eng.reshard_to(comp.submesh(range(script[step]), "re"))
            eng.step()
            step += 1
            assert step < 200
        return {str(r): t for r, t in eng.results().items()}

    rules = serve_engine_rules()
    ref = run(1, None)                          # never-moved baseline
    moved = run(1, None, {3: 2, 7: 1})          # the 1->2 CU move (and back)
    tp2 = run(2, rules)
    dyn = run(2, rules, {3: 1, 7: 4})
    print(json.dumps({"n": len(ref),
                      "lens_ok": all(len(t) == 8 for t in ref.values()),
                      "moved": moved == ref, "tp2": tp2 == ref,
                      "dyn": dyn == ref}))
    """)
    assert res["n"] == 4 and res["lens_ok"]
    assert res["moved"], "1->2 CU live recomposition altered enc-dec streams"
    assert res["tp2"], "TP enc-dec decode diverged from replicated"
    assert res["dyn"], "mid-stream TP degree change altered enc-dec streams"


def test_live_reconfigure_stream_invariance():
    """Serving-DSE acceptance pin: a mid-stream ``Engine.apply`` — a
    slot-count change AND a TP-degree change on a FIXED CU grant — leaves
    pinned decode streams bit-identical vs a never-retuned run, for
    both the transformer decode and the SSM engine (live slots are
    migrated into the resized pool; the TP move is a sharded device_put)."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.core.dse import DesignPoint
    from repro.models import build_model
    from repro.serve import serve_engine_rules
    from repro.workloads import DecodeEngine, SSMEngine, ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    rules = serve_engine_rules()
    out = {}
    for arch, cls in (("minitron-4b", DecodeEngine),
                      ("falcon-mamba-7b", SSMEngine)):
        cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        sc = ServeConfig(max_slots=2, max_len=48, eos_id=-1)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab_size, size=L)
                   for L in (5, 9, 7)]
        grant = comp.submesh(range(4), "fixed")      # the grant never moves

        def run(script=None):
            eng = cls(model, params, sc, mesh=grant, rules=rules)
            for p in prompts:
                eng.submit(p, max_new_tokens=10)
            step = 0
            while eng.has_work:
                if script and step in script:
                    eng.apply(None, DesignPoint(cus=0, **script[step]))
                eng.step()
                step += 1
                assert step < 300
            return eng, {str(r): t for r, t in eng.results().items()}

        _, ref = run()
        eng, dyn = run({2: {"slots": 4}, 5: {"tp": 2},
                        8: {"slots": 2, "tp": 4}})
        out[arch] = {"match": dyn == ref,
                     "design": {k: (list(v) if isinstance(v, tuple) else v)
                                for k, v in eng.design().items()}}
    print(json.dumps(out))
    """)
    for arch, r in res.items():
        assert r["match"], \
            f"mid-stream reconfigure altered {arch} decode streams"
        assert r["design"]["tp"] == 4 and r["design"]["slots"] >= 2


def test_mixed_fleet_end_to_end_with_live_class_moves():
    """Acceptance: a mixed fleet (transformer decode + mamba + encoder +
    seamless enc-dec) runs end-to-end through ComposedServer with >=1 live
    recomposition between classes, and SSM token streams / encoder
    embeddings / enc-dec decode streams are bit-identical to a
    never-recomposed run of the same fleet."""
    res = _run("""
    from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                    TenantSpec)
    from repro.workloads import ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=48, eos_id=-1)
    s2t_sc = ServeConfig(max_slots=2, max_len=16, eos_id=-1, max_src_len=16,
                         len_buckets=(8,))
    tenants = [
        TenantSpec("llm", "minitron-4b", serve=sc),
        TenantSpec("mamba", "falcon-mamba-7b", seed=1, serve=sc),
        TenantSpec("embed", "qwen2.5-32b", seed=2, serve=sc,
                   workload="encoder"),
        TenantSpec("s2t", "seamless-m4t-medium", seed=3, serve=s2t_sc),
    ]

    def run(policy):
        srv = ComposedServer(mesh, tenants, policy=policy, decide_every=3,
                             tp=False)       # replicated: bit-exact moves
        rng = np.random.default_rng(0)
        def traffic(name, n, new):
            vocab = srv.cfgs[name].vocab_size
            for _ in range(n):
                srv.submit(name, rng.integers(1, vocab, size=8),
                           max_new_tokens=new)
        traffic("llm", 2, 8)
        traffic("embed", 3, 0)
        traffic("s2t", 2, 8)
        for _ in range(8):
            srv.step()
        traffic("mamba", 3, 10)              # burst: forces a class move
        out = srv.drain(max_steps=300)
        return srv, out

    srv, out = run(AnalyticalPolicy())
    ref_srv, ref = run(None)                  # static composition baseline
    moved_classes = {srv.classes[t] for e in srv.events for t in e.moved}
    print(json.dumps({
        "recomps": len(srv.events),
        "classes": srv.classes,
        "moved_classes": sorted(moved_classes),
        "ssm_match": out["mamba"] == ref["mamba"],
        "enc_match": out["embed"] == ref["embed"],
        "encdec_match": out["s2t"] == ref["s2t"],
        "llm_match": out["llm"] == ref["llm"],
        "done": {t: len(d) for t, d in out.items()},
    }))
    """)
    assert res["recomps"] >= 1, "expected a live recomposition"
    assert len(res["moved_classes"]) >= 2, \
        f"expected moves across classes, got {res['moved_classes']}"
    assert res["classes"]["s2t"] == "encdec"   # derived from the arch
    assert res["ssm_match"], "SSM streams changed across the live move"
    assert res["enc_match"], "encoder embeddings changed across the live move"
    assert res["encdec_match"], \
        "enc-dec decode streams changed across the live move"
    assert res["llm_match"]
    assert res["done"] == {"llm": 2, "mamba": 3, "embed": 3, "s2t": 2}


def test_speculative_runner_up_prewarm():
    """Idle decide intervals warm the policy's runner-up split in the
    background: the fabric records speculative prewarms and the runner-up
    composition's executables are already cached when it later commits."""
    res = _run("""
    import time
    from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                    TenantSpec)
    from repro.workloads import ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=32, eos_id=-1)
    # min_gain pinned sky-high: every decide is a hysteresis tick, so the
    # test exercises exactly the idle-interval speculative path (at the
    # default gain the two-stage policy would commit a rebalance first)
    srv = ComposedServer(mesh, [
        TenantSpec("a", "minitron-4b", serve=sc),
        TenantSpec("b", "minitron-4b", seed=1, serve=sc),
    ], policy=AnalyticalPolicy(min_gain=100.0), decide_every=2,
       prewarm_async=True)
    rng = np.random.default_rng(0)
    vocab = srv.cfgs["a"].vocab_size
    # balanced load: the policy stays put (hysteresis) but exposes a
    # runner-up design, which the idle ticks compile in the background
    for t in ("a", "b"):
        srv.submit(t, rng.integers(1, vocab, size=8), max_new_tokens=20)
    steps = 0
    while srv.speculative_prewarms == 0 and steps < 100:
        srv.step()
        steps += 1
    for f in srv._spec_futures:
        f.result()                     # block: surface background errors
    print(json.dumps({"speculative": srv.speculative_prewarms,
                      "events": len(srv.events)}))
    """)
    assert res["speculative"] >= 1, \
        "balanced fleet never speculatively prewarmed its runner-up split"
