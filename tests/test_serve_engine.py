"""Serving-engine invariants that don't need devices: the pooled-cache slot
write is positional (a 1-slot pool behaves like an N-slot one), admission
backpressure is distinguished from real allocator bugs, and pipelined decode
dispatch is an observably pure reordering of host synchronization."""
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.arena import AllocationError
from repro.distribution import strip
from repro.models import build_model
from repro.serve import ExecutableCache, ServeConfig, ServeEngine

import jax


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    params = strip(model.init(jax.random.key(0)))
    return cfg, model, params


def _serve(model, params, **kw):
    defaults = dict(max_slots=3, max_len=48, eos_id=-1, prefill_bucket=8)
    defaults.update(kw)
    return ServeEngine(model, params, ServeConfig(**defaults))


def _submit_all(eng, cfg, n=4, seed=0, new=6):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        plen = int(rng.integers(4, 14))
        eng.submit(rng.integers(1, cfg.vocab_size, size=plen),
                   max_new_tokens=new)


# ---------------------------------------------------------------------------
# satellite: _write_slot must not drop the prefill when max_slots == 1
# ---------------------------------------------------------------------------

def test_single_slot_pool_receives_prefill(qwen):
    """With a (1, ...) pool and a (1, ...) single cache, shape-mismatch
    inference can't tell them apart; the explicit slot-axis write must
    still land — streams match a multi-slot engine's exactly."""
    cfg, model, params = qwen
    prompt = np.arange(1, 9) % cfg.vocab_size

    def run(slots):
        eng = _serve(model, params, max_slots=slots)
        eng.submit(prompt, max_new_tokens=5)
        return eng.run_to_completion(100)

    one, four = run(1), run(4)
    assert one == four
    # a dropped prefill decodes from an all-zeros cache: the first decode
    # token would disagree with the offline prefill's argmax
    import jax.numpy as jnp
    cache = strip(model.init_cache(1, 48))
    logits, _ = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              cache)
    assert one[0][0] == int(jnp.argmax(logits[0]))


# ---------------------------------------------------------------------------
# satellite: admission backpressure vs real allocator bugs
# ---------------------------------------------------------------------------

def test_admit_arena_full_is_backpressure(qwen):
    cfg, model, params = qwen
    eng = _serve(model, params, max_slots=2)

    def full_alloc(*a, **kw):
        raise AllocationError("arena full: need 1, free 0")

    eng.arena.alloc = full_alloc
    eng.submit(np.arange(1, 6), max_new_tokens=4)
    eng.step()                      # no crash: request just stays queued
    assert eng.queue_depth == 1 and eng.active_count == 0


def test_admit_propagates_non_allocation_bugs(qwen):
    """A TypeError (bad sizes, dtype bugs) in FlexArena.alloc must surface,
    not masquerade as admission backpressure."""
    cfg, model, params = qwen
    eng = _serve(model, params, max_slots=2)

    def broken_alloc(*a, **kw):
        raise TypeError("rows must be int")

    eng.arena.alloc = broken_alloc
    eng.submit(np.arange(1, 6), max_new_tokens=4)
    with pytest.raises(TypeError):
        eng.step()


# ---------------------------------------------------------------------------
# pipelined decode dispatch
# ---------------------------------------------------------------------------

def test_pipelined_decode_matches_sync(qwen):
    cfg, model, params = qwen

    def run(pipeline):
        eng = _serve(model, params, pipeline_decode=pipeline)
        _submit_all(eng, cfg, n=5)
        return eng.run_to_completion(200)

    assert run(True) == run(False)


def test_pipelined_survives_midstream_snapshots(qwen):
    """snapshot()/results() force an early harvest of the in-flight step;
    the engine must re-inject the harvested tokens, not feed zeros."""
    cfg, model, params = qwen
    ref = _serve(model, params, pipeline_decode=False)
    _submit_all(ref, cfg, n=4)
    want = ref.run_to_completion(200)

    eng = _serve(model, params, pipeline_decode=True)
    _submit_all(eng, cfg, n=4)
    steps = 0
    while eng.has_work:
        eng.step()
        eng.snapshot()              # harvests the in-flight dispatch
        steps += 1
        assert steps < 200
    assert eng.snapshot() == want


def test_eos_keeps_synchronous_path(qwen):
    """eos termination needs the token value before the next dispatch, so
    pipelining must auto-disable; streams stop at (or before) eos."""
    cfg, model, params = qwen
    eng = _serve(model, params, eos_id=3, pipeline_decode=True)
    _submit_all(eng, cfg, n=3, new=8)
    out = eng.run_to_completion(200)
    for toks in out.values():
        assert len(toks) <= 8
        if 3 in toks:
            assert toks.index(3) == len(toks) - 1   # nothing emitted past eos


# ---------------------------------------------------------------------------
# executable cache
# ---------------------------------------------------------------------------

def test_executable_cache_lru_and_counters():
    cache = ExecutableCache(capacity=2)
    assert cache.get_or_build("a", lambda: "A") == "A"
    assert cache.get_or_build("a", lambda: "A2") == "A"     # hit, no rebuild
    assert cache.builds == 1 and cache.hits == 1
    assert cache.ensure("a", lambda: "A3") == 0             # warm no-op
    cache.get_or_build("b", lambda: "B")
    cache.get_or_build("c", lambda: "C")                    # evicts oldest
    assert not cache.contains("a") and cache.contains("b")
    assert cache.builds == 3


def test_engine_reuses_decode_executable(qwen):
    """One decode program per (mesh, shapes): repeated steps never rebuild."""
    cfg, model, params = qwen
    eng = _serve(model, params)
    _submit_all(eng, cfg, n=3)
    for _ in range(3):
        eng.step()
    builds = eng.compile_builds
    eng.run_to_completion(200)
    assert eng.compile_builds == builds
