"""Rule registry: rule id -> ``check(index, config) -> List[Finding]``.

Adding a rule is one module exposing ``RULE`` (its id) and ``check``; list
it here and document it in docs/static-analysis.md.
"""
from __future__ import annotations

from tools.fabriclint.rules import (cache_key, deprecation, hot_sync,
                                    protocol, thread_safety)

ALL_RULES = {
    hot_sync.RULE: hot_sync.check,
    cache_key.RULE: cache_key.check,
    thread_safety.RULE: thread_safety.check,
    deprecation.RULE: deprecation.check,
    protocol.RULE: protocol.check,
}
