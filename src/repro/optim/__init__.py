from repro.optim.base import (
    AdafactorConfig,
    AdamWConfig,
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    make_optimizer,
)
from repro.optim.compression import (
    ErrorFeedback,
    compressed_psum,
    dequantize_int8,
    quantize_int8,
)

__all__ = [
    "AdafactorConfig", "AdamWConfig", "Optimizer", "adafactor", "adamw",
    "clip_by_global_norm", "cosine_schedule", "global_norm", "make_optimizer",
    "ErrorFeedback", "compressed_psum", "dequantize_int8", "quantize_int8",
]
