"""System integration: DSE -> codegen (Table-1 streams) -> functional
data-plane simulator == numpy reference numerics."""
import numpy as np
import pytest

from repro.configs.paper_workloads import MLP_S, POINTNET_S, bert, mlp
from repro.core.analytical import filco_vck190
from repro.core.codegen import generate, plan_ddr_layout
from repro.core.dse import run_dse
from repro.core.ga import GAConfig
from repro.core.instructions import decode_stream, encode_stream
from repro.core.simulator import DataPlaneSim


def _run_workload(wl, *, use_kernel=False, seed=0, solver="ga"):
    accel = filco_vck190()
    res = run_dse(wl, accel, solver=solver, max_modes=4,
                  ga_config=GAConfig(population=16, generations=12, seed=seed))
    prog = generate(wl, res.plan)
    layout = prog.layout
    sim = DataPlaneSim(layout.total_elems, accel.num_fmus,
                       accel.fmu_capacity * 8, accel.num_cus,
                       use_kernel=use_kernel)
    rng = np.random.default_rng(seed)
    first = wl.layers[0]
    x0 = rng.normal(size=(first.m, first.k)).astype(np.float32)
    sim.ddr[layout.input_addr:layout.input_addr + x0.size] = x0.reshape(-1)
    weights = {}
    for i, l in enumerate(wl.layers):
        w = (rng.normal(size=(l.k, l.n)) / np.sqrt(l.k)).astype(np.float32)
        weights[i] = w
        a = layout.weight_addr[i]
        sim.ddr[a:a + w.size] = w.reshape(-1)
    ddr0 = sim.ddr.copy()           # pre-run DDR image (for fallback reads)
    sim.run(prog)
    # numpy reference over the DAG (same operand-provenance rule as codegen:
    # first shape-matching dep, else an (m,k) read at the input region)
    outs = {}
    for i, l in enumerate(wl.layers):
        src = None
        for d in l.deps:
            dep = wl.layers[d]
            if (dep.m, dep.n) == (l.m, l.k):
                src = outs[d]
                break
        if src is None:
            src = ddr0[layout.input_addr:
                       layout.input_addr + l.m * l.k].reshape(l.m, l.k)
        outs[i] = src @ weights[i]
    return sim, layout, outs, prog


@pytest.mark.parametrize("wl", [MLP_S, POINTNET_S], ids=lambda w: w.name)
def test_simulator_matches_reference(wl):
    sim, layout, outs, _ = _run_workload(wl)
    for i in outs:
        a = layout.result_addr[i]
        got = sim.ddr[a:a + outs[i].size].reshape(outs[i].shape)
        err = np.abs(got - outs[i]).max() / (np.abs(outs[i]).max() + 1e-9)
        assert err < 1e-4, (wl.name, i, err)


def test_simulator_through_flex_mm_kernel():
    """The CU path through the interpret-mode Pallas kernel agrees too —
    ISA + arena + kernel validated together."""
    wl = mlp(24, 40, 3, "tiny")
    sim, layout, outs, _ = _run_workload(wl, use_kernel=True)
    last = max(outs)
    a = layout.result_addr[last]
    got = sim.ddr[a:a + outs[last].size].reshape(outs[last].shape)
    np.testing.assert_allclose(got, outs[last], rtol=1e-4, atol=1e-4)


def test_instruction_streams_roundtrip_binary():
    wl = MLP_S
    _, _, _, prog = _run_workload(wl)
    data = encode_stream(prog.iom_load)
    assert decode_stream("iom_load", data) == prog.iom_load
    for u, s in prog.fmu.items():
        assert decode_stream("fmu", encode_stream(s)) == s
    for u, s in prog.cu.items():
        assert decode_stream("cu", encode_stream(s)) == s
    assert prog.total_bytes() > 0
    # streams end with is_last (paper §2.5 header contract)
    assert prog.iom_load[-1].is_last and prog.iom_store[-1].is_last


def test_multi_cu_row_split():
    """A layer scheduled on >1 CU splits rows and still reproduces A@B."""
    wl = mlp(64, 48, 1, "one")
    sim, layout, outs, prog = _run_workload(wl, seed=3)
    got = sim.ddr[layout.result_addr[0]:
                  layout.result_addr[0] + outs[0].size].reshape(outs[0].shape)
    np.testing.assert_allclose(got, outs[0], rtol=1e-4, atol=1e-4)


def test_concurrent_groups_disjoint_cus():
    from repro.core.composer import concurrent_groups
    wl = bert(32, layers=1)
    res = run_dse(wl, filco_vck190(), solver="ga", max_modes=4,
                  ga_config=GAConfig(population=16, generations=15, seed=1))
    for group in concurrent_groups(res.plan):
        used = []
        for pl in group:
            used.extend(pl.cu_ids)
        assert len(used) == len(set(used)), "overlapping CU sets in a slot"
