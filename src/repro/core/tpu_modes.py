"""Bridge: assigned-architecture configs -> FILCO MM workloads -> two-stage
DSE on the TPU profile.

This closes the loop between the paper's framework and the pod-scale
deployment: a transformer layer of any assigned arch is exactly the kind of
diverse MM DAG FILCO schedules.  ``arch_workload()`` lowers one layer (or a
whole block stack) to an :class:`MMWorkload`; ``dse_for_arch()`` runs the
two-stage DSE against the TPU v5e profile, where a "CU" is a mesh sub-slice
and the FMU capacity is a chip's VMEM — yielding per-layer tile choices and
a composed schedule the same way the paper does on the VCK190.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.common.platform import TPU_V5E, PlatformProfile
from repro.configs.base import ModelConfig, ShapeCell
from repro.configs.paper_workloads import MMLayer, MMWorkload
from repro.core.analytical import AccelConfig
from repro.core.dse import DSEResult, run_dse
from repro.core.ga import GAConfig


def tpu_accel(num_cus: int = 8, vmem_frac: float = 0.75) -> AccelConfig:
    """A TPU chip as a FILCO design point: CUs = schedulable mesh sub-slices
    (grid partitions of the MXU work), FMUs = VMEM views."""
    elems = int(TPU_V5E.onchip_bytes * vmem_frac) // 4
    return AccelConfig(
        name="FILCO-TPUv5e", num_cus=num_cus,
        aies_per_cu=TPU_V5E.num_compute_units, num_fmus=16,
        onchip_elems=elems, fp=True, fmv=True, fmf=True)


def arch_workload(cfg: ModelConfig, cell: ShapeCell, *, layers: int = 1,
                  tokens_per_device: Optional[int] = None) -> MMWorkload:
    """Lower `layers` transformer layers of an arch to an MM DAG.

    Shapes are per-device: tokens_per_device defaults to the cell's global
    tokens / 256 chips (the single-pod mesh).
    """
    d, hq, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    hkv = cfg.num_kv_heads
    if tokens_per_device is None:
        if cell.kind == "decode":
            tokens_per_device = max(cell.global_batch // 256, 1)
        else:
            tokens_per_device = max(cell.global_batch * cell.seq_len // 256, 8)
    t = tokens_per_device
    nodes: List[MMLayer] = []
    prev: Tuple[int, ...] = ()
    for li in range(layers):
        base = len(nodes)
        if cfg.mla is not None:
            m = cfg.mla
            nodes.append(MMLayer(f"l{li}.q", t, d,
                                 hq * (m.qk_nope_head_dim + m.qk_rope_head_dim),
                                 prev))
            nodes.append(MMLayer(f"l{li}.dkv", t, d,
                                 m.kv_lora_rank + m.qk_rope_head_dim, prev))
            nodes.append(MMLayer(f"l{li}.ukv", t, m.kv_lora_rank,
                                 hq * (m.qk_nope_head_dim + m.v_head_dim),
                                 (base + 1,)))
            o_dep = (base + 2,)
        elif cfg.attention_free:
            o_dep = prev
        else:
            nodes.append(MMLayer(f"l{li}.qkv", t, d, (hq + 2 * hkv) * hd, prev))
            kv = min(cell.seq_len, 4096)    # per-device attended kv window
            nodes.append(MMLayer(f"l{li}.qk", hq * t, hd, kv, (base,)))
            nodes.append(MMLayer(f"l{li}.av", hq * t, kv, hd, (base + 1,)))
            nodes.append(MMLayer(f"l{li}.o", t, hq * hd, d, (base + 2,)))
            o_dep = (base + 3,)
        if cfg.ssm is not None:
            d_in = cfg.ssm.d_inner or cfg.ssm.expand * d
            nodes.append(MMLayer(f"l{li}.ssm_in", t, d, 2 * d_in, prev))
            nodes.append(MMLayer(f"l{li}.ssm_out", t, d_in, d,
                                 (len(nodes) - 1,)))
            o_dep = (len(nodes) - 1,)
        # FFN / MoE (routed experts appear as per-expert token slabs)
        if cfg.moe is not None:
            mo = cfg.moe
            per_e = max(t * mo.top_k // mo.num_experts, 1)
            # a representative subset of expert MMs keeps the DAG tractable
            for e in range(min(mo.num_experts, 8)):
                nodes.append(MMLayer(f"l{li}.e{e}.up", per_e, d,
                                     mo.expert_d_ff, o_dep))
                nodes.append(MMLayer(f"l{li}.e{e}.down", per_e,
                                     mo.expert_d_ff, d, (len(nodes) - 1,)))
            if mo.dense_residual:
                nodes.append(MMLayer(f"l{li}.dense_up", t, d,
                                     mo.dense_residual_d_ff or cfg.d_ff, o_dep))
                nodes.append(MMLayer(f"l{li}.dense_down", t,
                                     mo.dense_residual_d_ff or cfg.d_ff, d,
                                     (len(nodes) - 1,)))
            prev = (len(nodes) - 1,)
        elif cfg.d_ff:
            nodes.append(MMLayer(f"l{li}.ffn_up", t, d, cfg.d_ff, o_dep))
            nodes.append(MMLayer(f"l{li}.ffn_down", t, cfg.d_ff, d,
                                 (len(nodes) - 1,)))
            prev = (len(nodes) - 1,)
        else:
            prev = o_dep
    return MMWorkload(f"{cfg.name}/{cell.name}/L{layers}", tuple(nodes))


def dse_for_arch(cfg: ModelConfig, cell: ShapeCell, *,
                 platform: PlatformProfile = TPU_V5E,
                 seed: int = 0) -> DSEResult:
    wl = arch_workload(cfg, cell)
    return run_dse(wl, tpu_accel(), platform, solver="ga", max_modes=5,
                   ga_config=GAConfig(population=16, generations=20,
                                      seed=seed, patience=8))
