"""Scheduling problem structures shared by the MILP-equivalent exact solver
and the GA heuristic (paper §3.2–3.3).

A problem is a layer DAG with per-layer execution-mode candidates
(f_ik FMUs, c_ik CUs, e_ik latency — the Stage-1 table) plus platform
resource bounds (F_max, C_max).  A schedule picks one mode per layer
(Eq. 1), start/end times respecting dependencies (Eq. 2), and explicit
FMU/CU unit assignments such that no unit runs two overlapping layers
(Eq. 3–4) and counts match the chosen mode (Eq. 5); the objective is
makespan (Eq. 6).

``validate()`` checks a schedule against exactly that constraint set;
``list_schedule()`` is the serial schedule-generation scheme used by the GA
decoder and the exact solver's branching.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Mode:
    fmus: int                 # f_ik
    cus: int                  # c_ik
    latency: float            # e_ik
    meta: tuple = ()          # runtime parameters (tiles, views) — opaque here


@dataclasses.dataclass(frozen=True)
class ScheduleProblem:
    deps: Tuple[Tuple[int, ...], ...]      # deps[i] = predecessor layer ids
    modes: Tuple[Tuple[Mode, ...], ...]    # modes[i] = candidate modes
    f_max: int
    c_max: int

    @property
    def num_layers(self) -> int:
        return len(self.deps)

    def topo_order(self) -> List[int]:
        n = self.num_layers
        indeg = [len(d) for d in self.deps]
        succ: List[List[int]] = [[] for _ in range(n)]
        for i, ds in enumerate(self.deps):
            for d in ds:
                succ[d].append(i)
        ready = [i for i in range(n) if indeg[i] == 0]
        out = []
        while ready:
            i = ready.pop()
            out.append(i)
            for j in succ[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.append(j)
        assert len(out) == n, "dependency cycle"
        return out

    def successors(self) -> List[List[int]]:
        succ: List[List[int]] = [[] for _ in range(self.num_layers)]
        for i, ds in enumerate(self.deps):
            for d in ds:
                succ[d].append(i)
        return succ

    def critical_path_lb(self) -> float:
        """Longest dependency chain using each layer's fastest mode."""
        best = [min(m.latency for m in ms) for ms in self.modes]
        dist = [0.0] * self.num_layers
        for i in self.topo_order():
            base = max((dist[d] for d in self.deps[i]), default=0.0)
            dist[i] = base + best[i]
        return max(dist, default=0.0)

    def area_lb(self) -> float:
        """Resource-area bound: total CU-time / C_max (and FMU analogue)."""
        cu_area = sum(min(m.cus * m.latency for m in ms) for ms in self.modes)
        fmu_area = sum(min(m.fmus * m.latency for m in ms) for ms in self.modes)
        return max(cu_area / self.c_max, fmu_area / self.f_max)

    def lower_bound(self) -> float:
        return max(self.critical_path_lb(), self.area_lb())


@dataclasses.dataclass(frozen=True)
class Placement:
    layer: int
    mode_idx: int
    start: float
    end: float
    fmu_ids: Tuple[int, ...]
    cu_ids: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Schedule:
    placements: Tuple[Placement, ...]

    @property
    def makespan(self) -> float:
        return max((p.end for p in self.placements), default=0.0)


class InvalidSchedule(ValueError):
    pass


def validate(problem: ScheduleProblem, schedule: Schedule) -> None:
    """Raise InvalidSchedule unless every MILP constraint (Eq. 1–6) holds."""
    n = problem.num_layers
    by_layer: Dict[int, Placement] = {}
    for p in schedule.placements:
        if p.layer in by_layer:
            raise InvalidSchedule(f"layer {p.layer} scheduled twice (Eq. 1)")
        by_layer[p.layer] = p
    if len(by_layer) != n:
        raise InvalidSchedule("not all layers scheduled (Eq. 1)")
    for p in schedule.placements:
        mode = problem.modes[p.layer][p.mode_idx]
        if abs((p.end - p.start) - mode.latency) > EPS:
            raise InvalidSchedule(f"layer {p.layer}: E != S + e (Eq. 2)")
        if len(p.fmu_ids) != mode.fmus or len(p.cu_ids) != mode.cus:
            raise InvalidSchedule(f"layer {p.layer}: unit counts (Eq. 5)")
        if len(set(p.fmu_ids)) != len(p.fmu_ids) or \
           len(set(p.cu_ids)) != len(p.cu_ids):
            raise InvalidSchedule(f"layer {p.layer}: duplicate unit ids")
        if any(u >= problem.f_max for u in p.fmu_ids) or \
           any(u >= problem.c_max for u in p.cu_ids):
            raise InvalidSchedule(f"layer {p.layer}: unit id out of range")
        for d in problem.deps[p.layer]:
            if by_layer[d].end > p.start + EPS:
                raise InvalidSchedule(
                    f"dep {d}->{p.layer}: S_j < E_i (Eq. 2)")
    # Eq. 3–4: unit exclusivity among overlapping layers
    for a_i in range(len(schedule.placements)):
        for b_i in range(a_i + 1, len(schedule.placements)):
            a, b = schedule.placements[a_i], schedule.placements[b_i]
            overlap = a.start < b.end - EPS and b.start < a.end - EPS
            if not overlap:
                continue
            if set(a.fmu_ids) & set(b.fmu_ids):
                raise InvalidSchedule(
                    f"layers {a.layer},{b.layer} share an FMU while "
                    f"overlapping (Eq. 4)")
            if set(a.cu_ids) & set(b.cu_ids):
                raise InvalidSchedule(
                    f"layers {a.layer},{b.layer} share a CU while "
                    f"overlapping (Eq. 4)")


# ---------------------------------------------------------------------------
# serial schedule-generation scheme (list scheduling)
# ---------------------------------------------------------------------------

class _UnitPool:
    """Tracks per-unit busy intervals; greedy left-to-right assignment.

    Because tasks hold units for contiguous intervals and aggregate demand
    never exceeds capacity (checked by the caller's timeline), interval-graph
    perfection guarantees the greedy specific-unit assignment succeeds."""

    def __init__(self, count: int):
        self.count = count
        self.busy_until = [0.0] * count
        self.intervals: List[List[Tuple[float, float]]] = [[] for _ in range(count)]

    def free_at(self, t: float, dur: float) -> List[int]:
        out = []
        for u in range(self.count):
            if all(not (s < t + dur - EPS and t < e - EPS)
                   for s, e in self.intervals[u]):
                out.append(u)
        return out

    def take(self, units: Sequence[int], t: float, dur: float) -> None:
        for u in units:
            self.intervals[u].append((t, t + dur))


def fast_makespan(problem: ScheduleProblem, order: Sequence[int],
                  mode_choice: Sequence[int]) -> float:
    """Count-based serial SGS makespan — no unit-id assignment.

    By interval-graph perfection, aggregate-capacity feasibility equals
    specific-unit feasibility for contiguous holds, so this returns exactly
    ``list_schedule(...).makespan`` at a fraction of the cost (the GA fitness
    loop calls this thousands of times).
    """
    import numpy as np

    n = problem.num_layers
    end_time = [0.0] * n
    # events: arrays of (time, fmu_delta, cu_delta), kept time-sorted
    ev_t = [0.0]
    ev_f = [0]
    ev_c = [0]
    makespan = 0.0
    for li in order:
        mode = problem.modes[li][mode_choice[li] % len(problem.modes[li])]
        ready = max((end_time[d] for d in problem.deps[li]), default=0.0)
        dur, f, c = mode.latency, mode.fmus, mode.cus
        t_arr = np.asarray(ev_t)
        f_cum = np.cumsum(np.asarray(ev_f))
        c_cum = np.cumsum(np.asarray(ev_c))
        start = None
        # candidate starts: ready, then event times > ready
        cands = [ready] + [t for t in ev_t if t > ready + EPS]
        for t in sorted(set(cands)):
            # usage during [t, t+dur): max over events in window
            lo = np.searchsorted(t_arr, t + EPS) - 1
            hi = np.searchsorted(t_arr, t + dur - EPS, side="right")
            fmax = f_cum[lo:hi].max() if hi > lo else f_cum[lo]
            cmax = c_cum[lo:hi].max() if hi > lo else c_cum[lo]
            if fmax + f <= problem.f_max and cmax + c <= problem.c_max:
                start = t
                break
        assert start is not None
        end = start + dur
        # insert +usage at start, -usage at end
        i0 = int(np.searchsorted(t_arr, start, side="right"))
        ev_t.insert(i0, start)
        ev_f.insert(i0, f)
        ev_c.insert(i0, c)
        t_arr2 = np.asarray(ev_t)
        i1 = int(np.searchsorted(t_arr2, end, side="right"))
        ev_t.insert(i1, end)
        ev_f.insert(i1, -f)
        ev_c.insert(i1, -c)
        end_time[li] = end
        makespan = max(makespan, end)
    return makespan


def list_schedule(problem: ScheduleProblem, order: Sequence[int],
                  mode_choice: Sequence[int]) -> Schedule:
    """Schedule layers in `order` (must be dependency-compatible), each with
    its chosen mode, at the earliest resource-feasible start time."""
    n = problem.num_layers
    fmu_pool = _UnitPool(problem.f_max)
    cu_pool = _UnitPool(problem.c_max)
    end_time = [0.0] * n
    placed: List[Placement] = []
    # event times where resource availability changes
    events: List[float] = [0.0]
    for li in order:
        mode = problem.modes[li][mode_choice[li] % len(problem.modes[li])]
        ready = max((end_time[d] for d in problem.deps[li]), default=0.0)
        cands = sorted({ready} | {t for t in events if t > ready - EPS})
        start = None
        for t in cands:
            f_free = fmu_pool.free_at(t, mode.latency)
            c_free = cu_pool.free_at(t, mode.latency)
            if len(f_free) >= mode.fmus and len(c_free) >= mode.cus:
                start = t
                fmu_ids = tuple(f_free[: mode.fmus])
                cu_ids = tuple(c_free[: mode.cus])
                break
        assert start is not None, "no feasible slot found (should not happen)"
        fmu_pool.take(fmu_ids, start, mode.latency)
        cu_pool.take(cu_ids, start, mode.latency)
        end = start + mode.latency
        end_time[li] = end
        events.append(end)
        placed.append(Placement(li, mode_choice[li] % len(problem.modes[li]),
                                start, end, fmu_ids, cu_ids))
    return Schedule(tuple(placed))
