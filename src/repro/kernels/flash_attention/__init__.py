from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention", "mha", "attention_ref"]
