"""Batched serving engine with continuous batching and a FlexArena-backed
slot allocator.

The FILCO connection: serving-time KV/workspace memory is exactly the
diverse-workload storage problem the FMU solves — requests of wildly
different prompt lengths share one flat arena through runtime views instead
of per-request padded buffers.  The engine tracks per-request views in a
host-side FlexArena whose capacity mirrors the device cache pool, so
admission control (can this prompt fit?) is the paper's Fig. 5(b) check.

Decode state on device is a fixed pool of batch slots (functional pytree,
jit-friendly); prefill fills a slot, decode steps advance all live slots in
lock-step (continuous batching: slots join/leave between steps).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.core.arena import FlexArena, ROLE_ACT
from repro.distribution import partitioning as part
from repro.models.model import Model

PyTree = Any


def _mesh_of(sub) -> Optional[Mesh]:
    """Accept a Mesh, a composer SubAccelerator, or None."""
    if sub is None or isinstance(sub, Mesh):
        return sub
    return sub.mesh


def _replicate(tree: PyTree, mesh: Optional[Mesh]) -> PyTree:
    """Commit a pytree to a (sub-)mesh, replicated on every device.  The
    engine is mesh-agnostic: which devices run it is entirely decided by
    where its params/cache live, so moving an engine between compositions
    is one device_put of its state."""
    if mesh is None:
        return tree
    return jax.device_put(tree, NamedSharding(mesh, PartitionSpec()))


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # prompt
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    view: Any = None                    # arena view (admission accounting)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4                 # concurrent decode slots
    max_len: int = 128                 # per-slot cache capacity
    eos_id: int = 0
    greedy: bool = True
    prefill_bucket: int = 32           # prompts padded up to this length


class ServeEngine:
    def __init__(self, model: Model, params: PyTree, cfg: ServeConfig,
                 mesh=None, rules: Optional[part.ShardingRules] = None):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.reshard_count = 0
        mc = model.cfg
        # per-layer per-token KV elements (admission accounting)
        if mc.mla is not None:
            per_tok = mc.mla.kv_lora_rank + mc.mla.qk_rope_head_dim
        elif mc.attention_free:
            per_tok = 0
        else:
            per_tok = 2 * mc.num_kv_heads * mc.resolved_head_dim
        self._per_token_elems = max(per_tok, 1) * mc.num_layers
        self.arena = FlexArena(
            cfg.max_slots * cfg.max_len * self._per_token_elems)
        self._queue: List[Request] = []
        self._active: Dict[int, Request] = {}
        # finished rid -> emitted tokens; bounded so a long-running engine
        # doesn't grow host memory with every request ever served
        self._finished: Dict[int, List[int]] = {}
        self.finished_cap = 10_000
        self._next_rid = 0
        self._free_slots = list(range(cfg.max_slots))
        # one pooled cache for all slots
        self.cache = part.strip(model.init_cache(cfg.max_slots, cfg.max_len))
        self._prefill_jit = jax.jit(self._prefill_one, static_argnums=(3,))
        self._decode_jit = jax.jit(self._decode_all)
        self._pos = np.zeros(cfg.max_slots, np.int32)   # per-slot next index
        self.reshard_to(mesh)          # commit params+cache to the sub-mesh
        self.reshard_count = 0         # construction placement isn't a move


    # ------------------------------------------------------------------
    def reshard_to(self, sub) -> None:
        """Migrate this engine — params AND live decode state — onto a new
        sub-accelerator (FILCO real-time recomposition, §1/§2.1).

        The engine is purely functional on device: everything it owns is the
        params pytree and the pooled cache pytree, so growing, shrinking or
        moving its composition is a replicated device_put of both.  Host-side
        state (queues, slots, arena views) is untouched, and decode numerics
        are bit-identical because replication does not change the math.
        """
        mesh = _mesh_of(sub)
        self.mesh = mesh
        self.params = _replicate(self.params, mesh)
        self.cache = _replicate(self.cache, mesh)
        self.reshard_count += 1

    # ------------------------------------------------------------------
    # load metrics consumed by the recomposition policy
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def active_count(self) -> int:
        return len(self._active)

    def pending_tokens(self) -> int:
        """Decode steps of work still owed: remaining tokens of active
        requests plus full budgets of queued ones."""
        owed = sum(req.max_new_tokens - len(req.out_tokens)
                   for req in self._active.values())
        owed += sum(req.max_new_tokens + len(req.tokens)
                    for req in self._queue)
        return max(owed, 0)

    # ------------------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(tokens, np.int32),
                                   max_new_tokens))
        return rid

    # ------------------------------------------------------------------
    def _prefill_one(self, params, cache, tokens, true_len: int):
        """Prefill a single-slot cache with a (1, bucket) padded prompt."""
        batch = {"tokens": tokens}
        logits, cache = self.model.prefill(params, batch, cache,
                                           true_len=true_len)
        return logits, cache

    def _decode_all(self, params, cache, tokens, live_mask):
        logits, cache = self.model.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(live_mask, nxt, 0)
        return nxt, cache

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        while self._queue and self._free_slots:
            req = self._queue[0]
            need = (len(req.tokens) + req.max_new_tokens)
            if need > self.cfg.max_len:
                # rejected (would never fit a slot): still recorded, with
                # whatever was emitted (nothing) — requests never vanish
                req.done = True
                self._queue.pop(0)
                self._record_finished(req)
                continue
            try:
                view = self.arena.alloc(need, self._per_token_elems, ROLE_ACT)
            except Exception:
                break  # arena full: stay queued (admission control)
            self._queue.pop(0)
            req.view = view
            req.slot = self._free_slots.pop(0)
            self._active[req.slot] = req
            self._prefill_into_slot(req)

    def _prefill_into_slot(self, req: Request) -> None:
        """Prefill one request into its slot.

        Attention archs: pad to the bucket and pass true_len (garbage KV
        beyond true_len is masked by per-row cache_len and overwritten by
        subsequent decodes).  SSM/hybrid archs carry recurrent state that
        padding would corrupt, so they prefill at the exact prompt length
        (bounded recompiles: one per distinct length)."""
        L = len(req.tokens)
        padded_ok = self.model.cfg.ssm is None
        if padded_ok:
            bucket = max(self.cfg.prefill_bucket, 8)
            nb = -(-L // bucket) * bucket
        else:
            nb = L
        toks = np.zeros((1, nb), np.int32)
        toks[0, :L] = req.tokens
        single = part.strip(self.model.init_cache(1, self.cfg.max_len))
        logits, single = self._prefill_jit(self.params, single,
                                           jnp.asarray(toks), L)
        self.cache = _write_slot(self.cache, single, req.slot)
        self._pos[req.slot] = L
        first = int(jax.device_get(jnp.argmax(logits[0])))
        req.out_tokens.append(first)

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration: admit -> decode all live slots.
        Returns [(rid, token)] emitted this step."""
        self._admit()
        if not self._active:
            return []
        B = self.cfg.max_slots
        toks = np.zeros((B, 1), np.int32)
        live = np.zeros((B,), bool)
        for slot, req in self._active.items():
            toks[slot, 0] = req.out_tokens[-1]
            live[slot] = True
        nxt, self.cache = self._decode_jit(self.params, self.cache,
                                           jnp.asarray(toks),
                                           jnp.asarray(live))
        nxt = np.asarray(jax.device_get(nxt))
        emitted = []
        for slot in list(self._active):
            req = self._active[slot]
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            emitted.append((req.rid, tok))
            if tok == self.cfg.eos_id or \
               len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.arena.free_view(req.view)
                self._free_slots.append(slot)
                self._record_finished(req)
                del self._active[slot]
        return emitted

    def _record_finished(self, req: Request) -> None:
        self._finished[req.rid] = list(req.out_tokens)
        while len(self._finished) > self.finished_cap:
            self._finished.pop(next(iter(self._finished)))  # oldest first

    def run_to_completion(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self._queue and not self._active:
                break
            self.step()
        return self.snapshot()

    def results(self) -> Dict[int, List[int]]:
        """Completed (or rejected) requests' emitted tokens."""
        return {rid: list(toks) for rid, toks in self._finished.items()}

    def snapshot(self) -> Dict[int, List[int]]:
        """Every request seen so far -> tokens emitted (in-flight, queued
        and finished)."""
        out = {req.rid: list(req.out_tokens)
               for req in list(self._active.values()) + self._queue}
        out.update(self.results())
        return out


def _write_slot(pool_cache: PyTree, single_cache: PyTree, slot: int) -> PyTree:
    """Copy a 1-batch cache into slot `slot` of the pooled cache."""
    def write(pool, one):
        if not hasattr(pool, "ndim") or pool.ndim == 0:
            return pool
        # leaves have either (slots, ...) batch-leading or (L, slots, ...)
        if pool.ndim == one.ndim and pool.shape[0] != one.shape[0]:
            return jax.lax.dynamic_update_slice(
                pool, one.astype(pool.dtype),
                (slot,) + (0,) * (pool.ndim - 1))
        if pool.ndim >= 2 and one.ndim == pool.ndim and \
           pool.shape[1] != one.shape[1]:
            return jax.lax.dynamic_update_slice(
                pool, one.astype(pool.dtype),
                (0, slot) + (0,) * (pool.ndim - 2))
        return pool

    return jax.tree.map(write, pool_cache, single_cache)
