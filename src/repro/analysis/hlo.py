"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, but our
stacks scan over layers (and attention/xent scan inside), so its totals
undercount by the trip counts.  This module parses optimized HLO text and
walks the call graph, multiplying through ``while`` loops using the
``backend_config={"known_trip_count":{"n":...}}`` attribute that XLA attaches
to counted loops (verified present for lax.scan lowerings).

Per-op accounting:
  flops     — dot ops: 2 x numel(result) x prod(contracting dims); dots
              inside fusions are walked (fusion bodies contribute flops).
  bytes     — top-level ops: sum(operand bytes) + result bytes.  Fusion
              internals do NOT touch HBM, so only the fusion call's own
              operands/results count (the fusion-boundary memory model).
  collective— on-wire payload with ring-model factors by replica-group size
              (see wire_bytes()).

Validated against cost_analysis() on scan-free programs and against
hand-computed totals on scanned programs (tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s4": 1, "u4": 1, "f4e2m1fn": 1, "f8e8m0fnu": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3fnuz": 1,
}

_SHAPE_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\s/*]+?))\s+"
    r"([\w\-]+)\(")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s.*\{\s*$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_WHILE_ATTR = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\s*\{"n":"(\d+)"\}')
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_numel_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_ATOM.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Tuple[List[int], str]:
    m = _SHAPE_ATOM.search(shape_str)
    if not m:
        return [], ""
    dt, dims = m.group(1), m.group(2)
    return [int(d) for d in dims.split(",") if d], dt


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0.0) + v
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + v
        return self

    def scaled(self, factor: float) -> "Cost":
        return Cost(self.flops * factor, self.bytes * factor,
                    self.collective_bytes * factor,
                    {k: v * factor for k, v in self.collective_by_kind.items()},
                    {k: int(v * factor) for k, v in
                     self.collective_count.items()})


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def wire_bytes(kind: str, result_bytes: int, group: int) -> float:
    """Ring-model on-wire payload per device."""
    g = max(group, 1)
    frac = (g - 1) / g if g > 1 else 0.0
    if kind == "all-gather":
        return result_bytes * frac
    if kind == "all-reduce":
        return 2.0 * result_bytes * frac
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)        # operand = result * g
    if kind == "all-to-all":
        return result_bytes * frac
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^=]*?)\}\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        first = m.group(1).split("}")[0]
        return len([x for x in first.split(",") if x.strip() != ""])
    return 1


class HloAnalyzer:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cache: Dict[Tuple[str, bool], Cost] = {}
        self._sliced_cache: Dict[str, Dict[int, float]] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        buf: List[str] = []
        depth = 0
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HEADER.match(line)
                if m and line.endswith("{"):
                    cur = m.group(1)
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur
                    buf = []
                    depth = 1
                continue
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                self.computations[cur] = buf
                cur = None
                continue
            buf.append(line)
        if cur is not None:
            self.computations[cur] = buf

    # ------------------------------------------------------------------
    def _symbols(self, comp: str) -> Dict[str, str]:
        table: Dict[str, str] = {}
        for line in self.computations.get(comp, []):
            m = _OP_LINE.match(line)
            if m:
                table[m.group(1)] = m.group(2).strip()
        return table

    def _dot_flops(self, line: str, symbols: Dict[str, str],
                   result_shape: str) -> float:
        dims, _ = _shape_dims(result_shape)
        numel = 1
        for d in dims:
            numel *= d
        # contracting dims of lhs
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        ops = _OPERAND.findall(line.split("dot(", 1)[1])
        contract = 1
        if mc and ops:
            lhs_shape = symbols.get(ops[0], "")
            ldims, _ = _shape_dims(lhs_shape)
            for idx in mc.group(1).split(","):
                if idx and int(idx) < len(ldims):
                    contract *= ldims[int(idx)]
        return 2.0 * numel * contract

    # ------------------------------------------------------------------
    def cost(self, comp: Optional[str] = None, *,
             inside_fusion: bool = False) -> Cost:
        comp = comp or self.entry
        key = (comp, inside_fusion)
        if key in self._cache:
            return self._cache[key]
        total = Cost()
        symbols = self._symbols(comp)
        for line in self.computations.get(comp, []):
            m = _OP_LINE.match(line)
            if not m:
                continue
            name, result_shape, op = m.group(1), m.group(2).strip(), m.group(3)
            rbytes = _shape_numel_bytes(result_shape)
            if op == "while":
                mw = _WHILE_ATTR.search(line)
                trip = 1
                mt = _TRIP.search(line)
                if mt:
                    trip = int(mt.group(1))
                if mw:
                    body = self.cost(mw.group(2))
                    cond = self.cost(mw.group(1))
                    total += body.scaled(trip)
                    total += cond.scaled(trip)
                total.bytes += rbytes  # loop carries
                continue
            if op == "fusion":
                mcall = _CALL_ATTR.search(line)
                body = mcall.group(1) if mcall else None
                if body:
                    inner = self.cost(body, inside_fusion=True)
                    # fusion internals: flops + collectives count; bytes don't
                    total.flops += inner.flops
                    total.collective_bytes += inner.collective_bytes
                    for k, v in inner.collective_by_kind.items():
                        total.collective_by_kind[k] = \
                            total.collective_by_kind.get(k, 0.0) + v
                if not inside_fusion:
                    arg_str = line.split("fusion(", 1)[1] if "fusion(" in line \
                        else line.split("(", 1)[1]
                    opnds = _OPERAND.findall(arg_str.split("), ")[0] + ")")
                    dus_window = self._dus_window(body) if body else None
                    if dus_window is not None:
                        # in-place update fusion: the aliased buffers are
                        # not traffic; count read-modify-write of the
                        # windows + inputs smaller than the largest aliased
                        # element (multi-output scatter fusions included)
                        elem_sizes = [_shape_numel_bytes(f"{dt}[{dims}]")
                                      for dt, dims in
                                      _SHAPE_ATOM.findall(result_shape)]
                        max_elem = max(elem_sizes) if elem_sizes else rbytes
                        obytes = 0.0
                        for o in opnds:
                            sz = _shape_numel_bytes(symbols.get(o, ""))
                            if sz < max_elem:
                                obytes += sz
                        total.bytes += 2.0 * dus_window + obytes
                        continue
                    obytes = 0.0
                    sliced = self._fusion_sliced_params(body) if body else {}
                    for i, o in enumerate(opnds):
                        full = _shape_numel_bytes(symbols.get(o, ""))
                        # operands the body only reads through (dynamic-)
                        # slice windows touch the window, not the buffer
                        # (stacked scanned weights read one layer per step)
                        obytes += min(full, sliced.get(i, full))
                    total.bytes += rbytes + obytes
                continue
            if op in ("call", "conditional", "sort", "reduce",
                      "reduce-window", "scatter", "select-and-scatter",
                      "map", "all-reduce", "reduce-scatter"):
                for cname in _CALL_ATTR.findall(line):
                    if cname in self.computations and cname != comp:
                        total += self.cost(cname, inside_fusion=inside_fusion)
                mb = _BRANCHES.search(line)
                if mb:
                    branch_costs = []
                    for cname in _OPERAND.findall(mb.group(1)):
                        if cname in self.computations:
                            branch_costs.append(self.cost(cname))
                    if branch_costs:
                        worst = max(branch_costs, key=lambda c: c.flops + c.bytes)
                        total += worst
            if op in COLLECTIVES:
                g = _group_size(line)
                payload_bytes = rbytes
                if op == "reduce-scatter":
                    pass  # wire_bytes handles the operand scaling
                w = wire_bytes(op, payload_bytes, g)
                total.collective_bytes += w
                total.collective_by_kind[op] = \
                    total.collective_by_kind.get(op, 0.0) + w
                total.collective_count[op] = \
                    total.collective_count.get(op, 0) + 1
            if op == "dot":
                total.flops += self._dot_flops(line, symbols, result_shape)
            if op == "convolution":
                # unused by this model zoo; count result numel as 1 MAC/elem
                dims, _ = _shape_dims(result_shape)
                n = 1
                for d in dims:
                    n *= d
                total.flops += 2.0 * n
            if not inside_fusion and op not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "copy"):
                # `copy` excluded: XLA:CPU materializes loop-carry copies the
                # TPU pipeliner elides — counting them inflates HBM traffic
                # by the full carry per scan iteration.
                if op in ("slice", "dynamic-slice", "gather"):
                    total.bytes += 2.0 * rbytes       # window read + write
                elif op == "dynamic-update-slice":
                    ops_ = _OPERAND.findall(line.split("(", 1)[1])
                    upd = _shape_numel_bytes(symbols.get(ops_[1], "")) \
                        if len(ops_) > 1 else rbytes
                    total.bytes += 2.0 * upd          # update read + write
                else:
                    opnds = _OPERAND.findall(
                        line.split("(", 1)[1]) if "(" in line else []
                    obytes = sum(_shape_numel_bytes(symbols.get(o, ""))
                                 for o in opnds)
                    total.bytes += rbytes + obytes
        self._cache[key] = total
        return total

    # ------------------------------------------------------------------
    def _resolve(self, body_lines, name):
        for line in body_lines:
            mm = _OP_LINE.match(line)
            if mm and mm.group(1) == name:
                return mm, line
        return None, None

    def _dus_window_of(self, body_lines, symbols, name) -> Optional[float]:
        """Window bytes if `name` resolves (through bitcast/copy hops) to a
        dynamic-update-slice, else None."""
        m, line = self._resolve(body_lines, name)
        for _ in range(3):
            if m is None:
                return None
            if m.group(3) in ("bitcast", "copy"):
                ops_ = _OPERAND.findall(line.split("(", 1)[1])
                if not ops_:
                    return None
                m, line = self._resolve(body_lines, ops_[0])
                continue
            break
        if m is None or m.group(3) != "dynamic-update-slice":
            return None
        ops_ = _OPERAND.findall(line.split("(", 1)[1])
        if len(ops_) < 2:
            return None
        return float(_shape_numel_bytes(symbols.get(ops_[1], "")))

    def _dus_window(self, body: str) -> Optional[float]:
        """If the fusion body's root is a dynamic-update-slice — directly,
        through bitcast/copy hops, or a TUPLE of such (multi-output scatter
        fusions, e.g. scan writing several grad buffers per step) — return
        the total update-window bytes, else None.  In-place updates touch
        the window, never the whole aliased buffer."""
        lines = self.computations.get(body, [])
        symbols = self._symbols(body)
        root_line = None
        for line in lines:
            if re.match(r"^\s*ROOT\s", line):
                root_line = line
                break
        if root_line is None:
            return None
        m = _OP_LINE.match(root_line)
        if not m:
            return None
        if m.group(3) == "tuple":
            ops_ = _OPERAND.findall(root_line.split("(", 1)[1])
            total = 0.0
            any_dus = False
            for o in ops_:
                w = self._dus_window_of(lines, symbols, o)
                if w is None:
                    mm, _ = self._resolve(lines, o)
                    if mm is None:
                        return None
                    total += _shape_numel_bytes(mm.group(2))
                else:
                    any_dus = True
                    total += w
            return total if any_dus else None
        return self._dus_window_of(lines, symbols, m.group(1))

    # ------------------------------------------------------------------
    def _fusion_sliced_params(self, body: str) -> Dict[int, float]:
        """Parameter index -> touched bytes, for fusion params consumed ONLY
        by (dynamic-)slice/gather ops inside the body."""
        if body in self._sliced_cache:
            return self._sliced_cache[body]
        lines = self.computations.get(body, [])
        param_name_by_idx: Dict[int, str] = {}
        for line in lines:
            m = _OP_LINE.match(line)
            if m and m.group(3) == "parameter":
                mi = re.search(r"parameter\((\d+)\)", line)
                if mi:
                    param_name_by_idx[int(mi.group(1))] = m.group(1)
        out: Dict[int, float] = {}
        for idx, pname in param_name_by_idx.items():
            touched = 0.0
            only_sliced = True
            pat = "%" + pname
            for line in lines:
                m = _OP_LINE.match(line)
                if not m or m.group(1) == pname:
                    continue
                args = line.split("(", 1)[1] if "(" in line else ""
                if pat + "," in args or pat + ")" in args or \
                   pat + " " in args:
                    if m.group(3) in ("slice", "dynamic-slice", "gather"):
                        touched += _shape_numel_bytes(m.group(2))
                    else:
                        only_sliced = False
                        break
            if only_sliced and touched > 0:
                out[idx] = touched
        self._sliced_cache[body] = out
        return out


def analyze_hlo(text: str) -> Cost:
    return HloAnalyzer(text).cost()
