import jax

# Sharding-invariant RNG (newer jax defaults to this; the pinned jaxlib does
# not): param init must produce identical values on one device, a production
# mesh, or any recomposed sub-mesh — elastic checkpoint restarts and the
# serving fabric's live recomposition both rely on it.
jax.config.update("jax_threefry_partitionable", True)
