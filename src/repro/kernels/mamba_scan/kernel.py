"""mamba_scan — fused selective-scan Pallas kernel (Mamba-1, arXiv:2312.00752).

Fuses discretization (dt, A -> deltaA), the recurrence
``h_t = deltaA_t * h_{t-1} + dt_t * B_t * x_t`` and the output projection
``y_t = C_t . h_t + D * x_t`` in VMEM, so the (S, D, N) state expansion never
touches HBM — the TPU re-derivation of Mamba's hardware-aware scan and the
kind of bandwidth-bound hot spot FILCO assigns to a dedicated CU.

Grid: (B, D/bd, S/bs) with the last (sequence) dimension sequential; the
(bd, N) hidden state lives in VMEM scratch across sequence steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref, *,
                 bs):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)        # (bs, bd)
    dt = dt_ref[...].astype(jnp.float32)      # (bs, bd)
    bmat = b_ref[...].astype(jnp.float32)     # (bs, N)
    cmat = c_ref[...].astype(jnp.float32)     # (bs, N)
    a = a_ref[...].astype(jnp.float32)        # (bd, N)
    dvec = d_ref[...].astype(jnp.float32)     # (1, bd)

    def step(t, carry):
        h, y = carry                          # h: (bd, N); y: (bs, bd)
        dt_t = dt[t][:, None]                 # (bd, 1)
        da = jnp.exp(dt_t * a)                # (bd, N)
        dbx = (dt_t * x[t][:, None]) * bmat[t][None, :]
        h = da * h + dbx
        y_t = jnp.sum(h * cmat[t][None, :], axis=1) + dvec[0] * x[t]
        y = jax.lax.dynamic_update_index_in_dim(y, y_t, t, 0)
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros(x.shape, jnp.float32)
    h_last, y = jax.lax.fori_loop(0, bs, step, (h0, y0))
    h_ref[...] = h_last
    y_ref[...] = y.astype(y_ref.dtype)


def _step_kernel(live_ref, x_ref, conv_ref, h_ref, inproj_ref, convw_ref,
                 convb_ref, xproj_ref, dtproj_ref, dtbias_ref, alog_ref,
                 dvec_ref, outproj_ref, o_ref, nconv_ref, nh_ref, *,
                 dt_rank, state_dim):
    b = pl.program_id(0)
    live = live_ref[b] != 0
    f32 = jnp.float32

    @pl.when(live)
    def _step():
        x = x_ref[...]                                       # (1, d_model)
        dtype = x.dtype
        xz = jax.lax.dot_general(
            x, inproj_ref[...].astype(dtype), (((1,), (0,)), ((), ())))
        d_in = xz.shape[1] // 2
        xp, z = xz[:, :d_in], xz[:, d_in:]                   # (1, d_in)
        window = jnp.concatenate(
            [conv_ref[...].astype(dtype), xp], axis=0)       # (w, d_in)
        xc = jnp.sum(window.astype(f32) * convw_ref[...].astype(f32),
                     axis=0, keepdims=True) + convb_ref[...].astype(f32)
        x_conv = jax.nn.silu(xc).astype(dtype)               # (1, d_in)
        dbc = jax.lax.dot_general(
            x_conv, xproj_ref[...].astype(dtype), (((1,), (0,)), ((), ())))
        dt_raw = dbc[:, :dt_rank]
        b_ssm = dbc[:, dt_rank:dt_rank + state_dim].astype(f32)
        c_ssm = dbc[:, dt_rank + state_dim:].astype(f32)     # (1, N)
        dt = jax.nn.softplus(
            jax.lax.dot_general(dt_raw, dtproj_ref[...].astype(dtype),
                                (((1,), (0,)), ((), ()))).astype(f32)
            + dtbias_ref[...].astype(f32))                   # (1, d_in)
        a = -jnp.exp(alog_ref[...].astype(f32))              # (d_in, N)
        dt_col = jnp.reshape(dt, (d_in, 1))
        da = jnp.exp(dt_col * a)
        xcol = jnp.reshape(x_conv.astype(f32), (d_in, 1))
        h_new = da * h_ref[...] + (dt_col * xcol) * b_ssm    # (d_in, N)
        y = jax.lax.dot_general(h_new, c_ssm, (((1,), (1,)), ((), ())))
        y = jnp.reshape(y, (1, d_in)) \
            + dvec_ref[...].astype(f32) * x_conv.astype(f32)
        y = (y * jax.nn.silu(z.astype(f32))).astype(dtype)
        o_ref[...] = jax.lax.dot_general(
            y, outproj_ref[...].astype(dtype), (((1,), (0,)), ((), ())))
        nconv_ref[...] = window[1:].astype(nconv_ref.dtype)
        nh_ref[...] = h_new

    @pl.when(jnp.logical_not(live))
    def _dead():
        # empty slot: no SSM work, output zeros, state carried unchanged
        o_ref[...] = jnp.zeros_like(o_ref)
        nconv_ref[...] = conv_ref[...]
        nh_ref[...] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba_step_kernel(x1, conv, h, live, in_proj, conv_w, conv_b, x_proj,
                      dt_proj, dt_bias, a_log, d, out_proj, *,
                      interpret: bool = False):
    """Fused single-token Mamba step: in_proj + conv shift + selective-scan
    update + gate + out_proj in one kernel, one row per grid step.

    x1: (B, 1, d_model); conv: (B, w-1, d_in); h: (B, d_in, N) fp32;
    live: (B,) int32 row mask -> (out (B, 1, d_model), new_conv, new_h).

    Every weight rides VMEM whole, so the op is bound by
    ``d_model * d_in``-scale weights fitting VMEM — fine for serving-sized
    blocks, not a training kernel.  Rows with ``live == 0`` skip all work
    and carry their state through unchanged (output rows are zero).
    """
    B = x1.shape[0]
    w1, d_in = conv.shape[1], conv.shape[2]
    n = h.shape[2]
    dt_rank = dt_proj.shape[0]
    full = lambda b, *_: (0, 0)
    row3 = lambda b, *_: (b, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, 1, x1.shape[2]), row3),       # x1
            pl.BlockSpec((None, w1, d_in), row3),             # conv window
            pl.BlockSpec((None, d_in, n), row3),              # h
            pl.BlockSpec(in_proj.shape, full),
            pl.BlockSpec(conv_w.shape, full),
            pl.BlockSpec((1, d_in), full),                    # conv_b
            pl.BlockSpec(x_proj.shape, full),
            pl.BlockSpec(dt_proj.shape, full),
            pl.BlockSpec((1, d_in), full),                    # dt_bias
            pl.BlockSpec(a_log.shape, full),
            pl.BlockSpec((1, d_in), full),                    # D
            pl.BlockSpec(out_proj.shape, full),
        ],
        out_specs=[
            pl.BlockSpec((None, 1, x1.shape[2]), row3),
            pl.BlockSpec((None, w1, d_in), row3),
            pl.BlockSpec((None, d_in, n), row3),
        ],
    )
    kernel = functools.partial(_step_kernel, dt_rank=dt_rank, state_dim=n)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(x1.shape, x1.dtype),
            jax.ShapeDtypeStruct(conv.shape, conv.dtype),
            jax.ShapeDtypeStruct(h.shape, jnp.float32),
        ],
        interpret=interpret,
    )(live, x1, conv, h, in_proj, conv_w, conv_b.reshape(1, d_in), x_proj,
      dt_proj, dt_bias.reshape(1, d_in), a_log, d.reshape(1, d_in), out_proj)


@functools.partial(jax.jit, static_argnames=("bd", "bs", "interpret"))
def mamba_scan(x, dt, b, c, a_log, d, *, bd: int = 512, bs: int = 128,
               interpret: bool = False):
    """Fused selective scan.

    x, dt: (B, S, D); b, c: (B, S, N); a_log: (D, N); d: (D,) -> y: (B, S, D).
    dt must already be softplus'd (positive step sizes).
    """
    B, S, D = x.shape
    N = b.shape[-1]
    bd = min(bd, D)
    bs = min(bs, S)
    assert D % bd == 0 and S % bs == 0, (D, bd, S, bs)
    grid = (B, D // bd, S // bs)
    a = -jnp.exp(a_log.astype(jnp.float32))
    d2 = d.reshape(1, D)
    kernel = functools.partial(_scan_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bs, bd), lambda bi, di, si: (bi, si, di)),  # x
            pl.BlockSpec((None, bs, bd), lambda bi, di, si: (bi, si, di)),  # dt
            pl.BlockSpec((None, bs, N), lambda bi, di, si: (bi, si, 0)),    # B
            pl.BlockSpec((None, bs, N), lambda bi, di, si: (bi, si, 0)),    # C
            pl.BlockSpec((bd, N), lambda bi, di, si: (di, 0)),              # A
            pl.BlockSpec((1, bd), lambda bi, di, si: (0, di)),              # D
        ],
        out_specs=pl.BlockSpec((None, bs, bd), lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, d2)
