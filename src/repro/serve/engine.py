"""Compatibility shim — NOT the engine's home.  The batched transformer
serving engine lives in ``repro.workloads.decode`` (it moved there when the
workload-class subsystem landed and is now one engine class among
decode/ssm/encoder/encdec — see ``repro.workloads`` and docs/workloads.md).

``ServeEngine`` remains a public alias for the transformer decode engine;
new code should import :class:`~repro.workloads.decode.DecodeEngine` (or
its siblings :class:`~repro.workloads.ssm.SSMEngine`,
:class:`~repro.workloads.encoder.EncoderEngine`,
:class:`~repro.workloads.encdec.EncDecEngine`) from ``repro.workloads``.
"""
from repro.workloads.decode import (DecodeEngine, Request, ServeConfig,
                                    _mesh_of, _write_slot)

ServeEngine = DecodeEngine

__all__ = ["DecodeEngine", "Request", "ServeConfig", "ServeEngine",
           "_mesh_of", "_write_slot"]
