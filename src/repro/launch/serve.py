"""Serving launcher.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
      --requests 8

Builds the model, initializes (or restores) params, and drives the
continuous-batching engine over a synthetic request stream.  On real pods the
engine runs under serve_rules() on the production mesh; optionally composed
into multiple independent sub-accelerators for multi-tenant serving
(examples/multi_tenant_serve.py).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.distribution import partitioning as part
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = part.strip(model.init(jax.random.key(args.seed)))
    mesh = None
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    engine = ServeEngine(model, params,
                         ServeConfig(max_slots=args.max_slots,
                                     max_len=args.max_len, eos_id=-1),
                         mesh=mesh)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    rids = []
    for _ in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, size=plen)
        rids.append(engine.submit(prompt, max_new_tokens=args.max_new_tokens))
    steps = 0
    emitted = 0
    while engine._queue or engine._active:
        emitted += len(engine.step())
        steps += 1
        if steps > 10_000:
            break
    dt = time.monotonic() - t0
    print(json.dumps({
        "requests": args.requests, "decode_steps": steps,
        "tokens_emitted": emitted, "wall_s": round(dt, 2),
        "tokens_per_s": round(emitted / dt, 1),
        "arena_utilization": engine.arena.utilization(),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
