"""fabriclint: static analysis that pins the fabric's invariants.

The fabric's hardest bugs were *invariant* bugs invisible to pytest until
they bit: a value read inside a program builder but missing from the
executable-cache key (PR-5 shape poisoning), state mutated from both the
prewarm thread and the serving loop without a lock, a hot-path scalar
coercion that silently syncs the pipelined dispatch.  fabriclint turns those
postmortems into machine-checked rules over the AST (stdlib ``ast`` only —
zero new dependencies):

* ``hot-sync``      — device→host syncs reachable from ``step()``
* ``cache-key``     — ServeConfig reads in program builders missing from
                      ``_config_key``
* ``thread-safety`` — attributes mutated from both the prewarm thread and
                      the serving loop outside a lock
* ``deprecation``   — ``DeprecationWarning`` shims past the one-release
                      grace window (``# fabriclint: deprecated-since=PRn``)
* ``protocol``      — the five engines match the ``Engine`` protocol
                      signature-exactly

Run as ``python -m tools.fabriclint src/``.  Deliberate violations live in
``tools/fabriclint/baseline.json`` with a reason string, or inline as
``# fabriclint: disable=<rule> -- <reason>`` on (or directly above) the
flagged line.  See docs/static-analysis.md for the rule catalog.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Finding:
    """One rule violation.  ``code`` is a short normalized snippet of the
    flagged construct — (rule, path, symbol, code) is the line-number-free
    fingerprint the baseline matches on, so findings survive unrelated
    edits to the file."""

    rule: str
    path: str          # repo-relative
    line: int
    symbol: str        # enclosing Class.method / function
    code: str
    message: str

    def fingerprint(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.code)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"{self.symbol}: {self.message}")


def run_lint(paths: Sequence[str], *, rules: Optional[Sequence[str]] = None,
             current_pr: Optional[int] = None,
             repo_root: Optional[Path] = None,
             baseline_path: Optional[Path] = None):
    """Lint ``paths`` (files or directories) and return
    ``(findings, baselined, stale_baseline_entries)``.

    ``findings`` are the violations left after inline suppressions and the
    baseline; ``baselined`` the (finding, reason) pairs the baseline
    absorbed; ``stale`` the baseline entries that matched nothing (candidates
    for deletion).  ``current_pr`` defaults to the highest PR number in
    CHANGES.md (the deprecation rule's clock).
    """
    from tools.fabriclint import baseline as baseline_mod
    from tools.fabriclint.rules import ALL_RULES
    from tools.fabriclint.walker import Index, current_pr_from_changes

    root = Path(repo_root) if repo_root is not None else Path.cwd()
    index = Index(repo_root=root)
    for p in paths:
        index.add_path(Path(p))
    if current_pr is None:
        current_pr = current_pr_from_changes(root / "CHANGES.md")
    config = {"current_pr": current_pr, "repo_root": root}

    selected = list(rules) if rules else list(ALL_RULES)
    unknown = [r for r in selected if r not in ALL_RULES]
    if unknown:
        raise KeyError(f"unknown rule(s) {unknown}; known: {list(ALL_RULES)}")

    raw: List[Finding] = []
    for name in selected:
        raw.extend(ALL_RULES[name](index, config))
    raw.sort(key=lambda f: (f.path, f.line, f.rule))

    kept = [f for f in raw if not index.suppressed(f)]
    entries = (baseline_mod.load(baseline_path)
               if baseline_path is not None else [])
    return baseline_mod.apply(kept, entries)
