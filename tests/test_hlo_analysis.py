"""Trip-count-aware HLO analyzer: validated against XLA cost_analysis on
scan-free programs and hand counts on scanned/nested programs; collective
wire bytes on a multi-device subprocess."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import analyze_hlo, wire_bytes


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(comp):
    """jaxlib >= 0.4.36 returns a one-element list from cost_analysis()."""
    ca = comp.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matches_xla_on_scan_free():
    def f(x, w):
        return jnp.tanh(x @ w) @ w

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = _compiled(f, x, w)
    mine = analyze_hlo(comp.as_text())
    assert mine.flops == _xla_cost(comp)["flops"]


def test_scan_trip_count_multiplication():
    def g(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = _compiled(g, x, w)
    mine = analyze_hlo(comp.as_text())
    assert mine.flops == 2 * 128 ** 3 * 10
    # XLA counts the body once — the whole reason this module exists
    assert _xla_cost(comp)["flops"] < mine.flops


def test_nested_scan():
    def h(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    mine = analyze_hlo(_compiled(h, x, w).as_text())
    assert mine.flops == 2 * 128 ** 3 * 20


def test_bytes_reasonable_for_simple_matmul():
    def f(x, w):
        return x @ w

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    mine = analyze_hlo(_compiled(f, x, w).as_text())
    expect = 3 * 256 * 256 * 4
    assert expect <= mine.bytes <= 3 * expect


def test_wire_bytes_model():
    assert wire_bytes("all-gather", 1000, 8) == pytest.approx(875.0)
    assert wire_bytes("all-reduce", 1000, 8) == pytest.approx(1750.0)
    assert wire_bytes("reduce-scatter", 1000, 8) == pytest.approx(7000.0)
    assert wire_bytes("collective-permute", 1000, 1) == 1000.0
    assert wire_bytes("all-gather", 1000, 1) == 0.0


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.hlo import analyze_hlo

    mesh = jax.make_mesh((8,), ("d",))
    x = jax.ShapeDtypeStruct((1024, 512), jnp.float32,
                             sharding=NamedSharding(mesh, P("d", None)))
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "d")))

    def f(x, w):
        return jnp.sum(jnp.square(x @ w))

    comp = jax.jit(f, out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
    c = analyze_hlo(comp.as_text())
    print(json.dumps({"flops": c.flops, "coll": c.collective_bytes,
                      "kinds": c.collective_by_kind}))
""")


def test_collective_bytes_multi_device():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # per-device flops: total / 8
    assert res["flops"] == pytest.approx(2 * 1024 * 512 * 512 / 8, rel=0.01)
    # the w all-gather dominates: 512*512*4 * 7/8
    assert res["coll"] == pytest.approx(512 * 512 * 4 * 7 / 8, rel=0.05)
