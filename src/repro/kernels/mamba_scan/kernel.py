"""mamba_scan — fused selective-scan Pallas kernel (Mamba-1, arXiv:2312.00752).

Fuses discretization (dt, A -> deltaA), the recurrence
``h_t = deltaA_t * h_{t-1} + dt_t * B_t * x_t`` and the output projection
``y_t = C_t . h_t + D * x_t`` in VMEM, so the (S, D, N) state expansion never
touches HBM — the TPU re-derivation of Mamba's hardware-aware scan and the
kind of bandwidth-bound hot spot FILCO assigns to a dedicated CU.

Grid: (B, D/bd, S/bs) with the last (sequence) dimension sequential; the
(bd, N) hidden state lives in VMEM scratch across sequence steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref, *,
                 bs):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)        # (bs, bd)
    dt = dt_ref[...].astype(jnp.float32)      # (bs, bd)
    bmat = b_ref[...].astype(jnp.float32)     # (bs, N)
    cmat = c_ref[...].astype(jnp.float32)     # (bs, N)
    a = a_ref[...].astype(jnp.float32)        # (bd, N)
    dvec = d_ref[...].astype(jnp.float32)     # (1, bd)

    def step(t, carry):
        h, y = carry                          # h: (bd, N); y: (bs, bd)
        dt_t = dt[t][:, None]                 # (bd, 1)
        da = jnp.exp(dt_t * a)                # (bd, N)
        dbx = (dt_t * x[t][:, None]) * bmat[t][None, :]
        h = da * h + dbx
        y_t = jnp.sum(h * cmat[t][None, :], axis=1) + dvec[0] * x[t]
        y = jax.lax.dynamic_update_index_in_dim(y, y_t, t, 0)
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros(x.shape, jnp.float32)
    h_last, y = jax.lax.fori_loop(0, bs, step, (h0, y0))
    h_ref[...] = h_last
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "bs", "interpret"))
def mamba_scan(x, dt, b, c, a_log, d, *, bd: int = 512, bs: int = 128,
               interpret: bool = False):
    """Fused selective scan.

    x, dt: (B, S, D); b, c: (B, S, N); a_log: (D, N); d: (D,) -> y: (B, S, D).
    dt must already be softplus'd (positive step sizes).
    """
    B, S, D = x.shape
    N = b.shape[-1]
    bd = min(bd, D)
    bs = min(bs, S)
    assert D % bd == 0 and S % bs == 0, (D, bd, S, bs)
    grid = (B, D // bd, S // bs)
    a = -jnp.exp(a_log.astype(jnp.float32))
    d2 = d.reshape(1, D)
    kernel = functools.partial(_scan_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bs, bd), lambda bi, di, si: (bi, si, di)),  # x
            pl.BlockSpec((None, bs, bd), lambda bi, di, si: (bi, si, di)),  # dt
            pl.BlockSpec((None, bs, N), lambda bi, di, si: (bi, si, 0)),    # B
            pl.BlockSpec((None, bs, N), lambda bi, di, si: (bi, si, 0)),    # C
            pl.BlockSpec((bd, N), lambda bi, di, si: (di, 0)),              # A
            pl.BlockSpec((1, bd), lambda bi, di, si: (0, di)),              # D
        ],
        out_specs=pl.BlockSpec((None, bs, bd), lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, d2)
