from repro.core.dse import DesignPoint
from repro.serve.compile_cache import ExecutableCache
from repro.serve.dse import Stage1Optimizer, TenantDesignSpace
from repro.serve.engine import DecodeEngine, Request, ServeConfig, ServeEngine
from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                RecompositionEvent, TenantLoad, TenantSpec,
                                serve_engine_rules)
from repro.workloads import EncDecEngine, EncoderEngine, SSMEngine

__all__ = [
    "ExecutableCache",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "DecodeEngine",
    "SSMEngine",
    "EncoderEngine",
    "EncDecEngine",
    "AnalyticalPolicy",
    "ComposedServer",
    "DesignPoint",
    "RecompositionEvent",
    "Stage1Optimizer",
    "TenantDesignSpace",
    "TenantLoad",
    "TenantSpec",
    "serve_engine_rules",
]
