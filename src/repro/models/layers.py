"""Core neural-net layers: norms, RoPE, dense, activations, blockwise attention.

All layers are functional: ``*_init(rng, ...) -> Annotated param pytree`` and
``*_apply(params, x, ...) -> y``.  Parameters carry logical sharding
annotations (repro.distribution.partitioning.Annotated) consumed by the
launcher when placing them on a mesh.

The attention here is the *portable* (pure-jnp) path: a lax.scan over KV
blocks with running logsumexp — the flash-attention algorithm — so that
``prefill_32k`` never materializes an S x S score matrix and
``memory_analysis()`` stays honest.  The Pallas kernel in
``repro.kernels.flash_attention`` implements the same contract for TPU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.partitioning import Annotated

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(rng, shape, std, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) * jnp.asarray(std, dtype)


def dense_init(rng, in_dim: int, out_dim, logical: Tuple, *, std: Optional[float] = None,
               dtype=jnp.float32) -> Annotated:
    """Weight of shape (in_dim, *out_dims) with fan-in scaled init."""
    out_dims = out_dim if isinstance(out_dim, tuple) else (out_dim,)
    std = std if std is not None else 1.0 / math.sqrt(in_dim)
    return Annotated(_normal(rng, (in_dim, *out_dims), std, dtype), logical)


def bias_init(out_dim, logical: Tuple, dtype=jnp.float32) -> Annotated:
    out_dims = out_dim if isinstance(out_dim, tuple) else (out_dim,)
    return Annotated(jnp.zeros(out_dims, dtype), logical)


def scale_init(dim: int, logical: Tuple, value: float = 1.0, dtype=jnp.float32) -> Annotated:
    return Annotated(jnp.full((dim,), value, dtype), logical)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_init(kind: str, dim: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": scale_init(dim, (None,), 1.0, dtype)}
    return {"scale": scale_init(dim, (None,), 1.0, dtype),
            "bias": bias_init(dim, (None,), dtype)}


def apply_norm(kind: str, params, x, eps: float):
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"], eps)
    return layer_norm(x, params["scale"], params["bias"], eps)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# rotary embedding — computed on the fly from positions (no 500k-entry table).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) (D even); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                   # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv         # (..., S, D/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash) attention — pure jnp, scan over KV blocks, with a
# custom_vjp backward that saves only (q, k, v, out, lse) and recomputes
# block scores (the flash-attention backward).  Without the custom backward,
# autodiff through the forward scan saves the fp32 (B,Sq,Hq,D) accumulator
# carry at EVERY block step — tens of GiB per layer at 4k+ sequence lengths.
#
# q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D), Hq % Hkv == 0.
# Supports causal masking, sliding window and explicit kv-length masking.
# Double differentiation through attention is unsupported (first-order vjp).
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_expand(k, groups: int):
    # (B, S, Hkv, D) -> (B, S, Hkv*groups, D) by repeat; done blockwise so the
    # expansion is only ever (block) wide.
    return jnp.repeat(k, groups, axis=2)


def _block_mask(qpos, kpos, valid_len, *, causal, window, is_global):
    """(B|1, Sq, blk) mask shared by the fwd and bwd passes.

    valid_len may be a scalar (one kv length for the whole batch — decode
    with a uniform cache, or Skv itself) or a per-row (B,) vector (serving's
    right-padded batches: each row masks its own key padding, so a job's
    attention never reads another bucket's pad region and encodes are
    bucket-invariant)."""
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = vl[None]                                     # (1,)
    mask = kpos[None, None, :] < vl[:, None, None]        # (B|1, 1, blk)
    if causal:
        mask = mask & (kpos[None, None, :] <= qpos[None, :, None])
    if window:
        w_ok = kpos[None, None, :] > (qpos[None, :, None] - window)
        if is_global is not None:
            w_ok = w_ok | is_global
        mask = mask & w_ok
    return mask


def _flash_fwd_pass(causal, window, block_size, logit_cap, q, k, v, q_offset,
                    valid_len, is_global):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    groups = Hq // Hkv
    nblk = Skv // block_size
    kb = k.reshape(B, nblk, block_size, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_size, Hkv, D).transpose(1, 0, 2, 3, 4)
    # operands stay in the input dtype (bf16 on TPU: half the HBM/ICI bytes);
    # the MXU accumulates in f32 via preferred_element_type — upcasting the
    # operands instead gets the convert hoisted above the SP all-gathers and
    # doubles wire traffic (EXPERIMENTS.md §Perf).
    scale = 1.0 / math.sqrt(D)
    qpos = jnp.arange(Sq) + q_offset

    def body(carry, blk):
        acc, m, l = carry
        kblk, vblk, bidx = blk
        kpos = bidx * block_size + jnp.arange(block_size)
        kexp = _gqa_expand(kblk, groups)
        s = jnp.einsum("bqhd,bkhd->bqhk", q, kexp,
                       preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        mask = _block_mask(qpos, kpos, valid_len, causal=causal,
                           window=window, is_global=is_global)
        s = jnp.where(mask[:, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        resc = jnp.exp(m - m_new)
        vexp = _gqa_expand(vblk, groups)
        acc = acc * resc[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p.astype(v.dtype), vexp,
            preferred_element_type=jnp.float32)
        l = l * resc + jnp.sum(p, axis=-1)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Sq, Hq, D), jnp.float32)
    m0 = jnp.full((B, Sq, Hq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (kb, vb, jnp.arange(nblk)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))               # (B, Sq, Hq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash(causal, window, block_size, logit_cap, q, k, v, q_offset,
           valid_len, is_global):
    out, _ = _flash_fwd_pass(causal, window, block_size, logit_cap, q, k, v,
                             q_offset, valid_len, is_global)
    return out


def _flash_fwd(causal, window, block_size, logit_cap, q, k, v, q_offset,
               valid_len, is_global):
    out, lse = _flash_fwd_pass(causal, window, block_size, logit_cap, q, k, v,
                               q_offset, valid_len, is_global)
    return out, (q, k, v, out, lse, q_offset, valid_len, is_global)


def _flash_bwd(causal, window, block_size, logit_cap, res, dout):
    q, k, v, out, lse, q_offset, valid_len, is_global = res
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    groups = Hq // Hkv
    nblk = Skv // block_size
    scale = 1.0 / math.sqrt(D)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                    # (B,Sq,Hq)
    qpos = jnp.arange(Sq) + q_offset
    kb = k.reshape(B, nblk, block_size, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_size, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(dq, blk):
        kblk, vblk, bidx = blk
        kpos = bidx * block_size + jnp.arange(block_size)
        kexp = _gqa_expand(kblk, groups)
        vexp = _gqa_expand(vblk, groups)
        s_raw = jnp.einsum("bqhd,bkhd->bqhk", q, kexp,
                           preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = logit_cap * jnp.tanh(s_raw / logit_cap)
        else:
            s = s_raw
        mask = _block_mask(qpos, kpos, valid_len, causal=causal,
                           window=window, is_global=is_global)
        s = jnp.where(mask[:, :, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                       # (B,Sq,Hq,blk)
        pc = p.astype(v.dtype)
        dv_h = jnp.einsum("bqhk,bqhd->bkhd", pc, dout,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhd,bkhd->bqhk", dout, vexp,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        if logit_cap > 0.0:
            t = jnp.tanh(s_raw / logit_cap)
            ds = ds * (1.0 - jnp.square(t))
        ds = jnp.where(mask[:, :, None, :], ds, 0.0)
        dsc = ds.astype(k.dtype)
        dq = dq + jnp.einsum("bqhk,bkhd->bqhd", dsc, kexp,
                             preferred_element_type=jnp.float32) * scale
        dk_h = jnp.einsum("bqhk,bqhd->bkhd", dsc, q,
                          preferred_element_type=jnp.float32) * scale
        # fold GQA: sum q-head groups back to kv heads
        dk = dk_h.reshape(B, block_size, Hkv, groups, D).sum(3)
        dv = dv_h.reshape(B, block_size, Hkv, groups, D).sum(3)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Hq, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nblk)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, D)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(
    q, k, v, *,
    causal: bool,
    q_offset=0,
    window: int = 0,
    kv_len=None,
    block_size: int = 512,
    logit_cap: float = 0.0,
    is_global=None,
):
    """Flash-attention algorithm in jnp (memory-efficient fwd AND bwd).

    q_offset: position of q[0] within the kv timeline (prefill: 0; decode:
      cache length).  window: sliding-window size (0 = unlimited).  kv_len:
      optional dynamic valid kv length — a scalar (decode with a
      preallocated cache) or a per-row (B,) vector (right-padded serving
      batches: each row masks its own key padding).
    is_global: optional scalar bool — when True, ignore ``window`` (hybrid
      models with a few global layers inside a scanned stack).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    block_size = min(block_size, Skv)
    nblk = -(-Skv // block_size)
    pad = nblk * block_size - Skv
    valid_len = jnp.asarray(Skv if kv_len is None else kv_len)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q_offset = jnp.asarray(q_offset)
    is_global_arr = None if is_global is None else jnp.asarray(is_global)
    return _flash(causal, window, block_size, logit_cap, q, k, v, q_offset,
                  valid_len, is_global_arr)


def triangular_attention(
    q, k, v, *,
    q_offset=0,
    window: int = 0,
    block_size: int = 512,
    logit_cap: float = 0.0,
    is_global=None,
):
    """Causal blockwise attention over the *triangular pair list* — computes
    only (i, j<=i) blocks, eliminating the ~2x masked-FLOP waste of the
    rectangular scan.  Beyond-paper optimization (EXPERIMENTS.md §Perf).

    FORWARD/PREFILL ONLY: differentiating through the pair scan would save
    the full fp32 accumulator per pair step; training uses
    ``blockwise_attention`` (custom_vjp flash backward) instead.
    Requires Sq == Skv (prefill/train) and Sq % block_size == 0.
    """
    B, S, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert S == Skv and S % block_size == 0, (S, Skv, block_size)
    groups = Hq // Hkv
    nb = S // block_size
    # static (i, j) pair list, j <= i, ordered by i then j so the running
    # softmax state for q-block i is finalized before i+1 begins.
    pairs = [(i, j) for i in range(nb) for j in range(i + 1)]
    if window:
        wblk = -(-window // block_size)
        if is_global is None:
            pairs = [(i, j) for (i, j) in pairs if i - j <= wblk]
    pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pj = jnp.asarray([p[1] for p in pairs], jnp.int32)

    qb = q.reshape(B, nb, block_size, Hq, D)
    kb = k.reshape(B, nb, block_size, Hkv, D)
    vb = v.reshape(B, nb, block_size, Hkv, D)
    scale = 1.0 / math.sqrt(D)

    def body(carry, idx):
        acc, m, l = carry                       # (B, nb, blk, Hq, D/·)
        i, j = idx
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        qpos = i * block_size + jnp.arange(block_size) + q_offset
        kpos = j * block_size + jnp.arange(block_size) + q_offset
        s = jnp.einsum("bqhd,bkhd->bqhk", qi, _gqa_expand(kj, groups),
                       preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            w_ok = kpos[None, :] > (qpos[:, None] - window)
            if is_global is not None:
                w_ok = w_ok | is_global
            mask = mask & w_ok
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        resc = jnp.exp(mi - m_new)
        ai = ai * resc[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p.astype(v.dtype), _gqa_expand(vj, groups),
            preferred_element_type=jnp.float32)
        li = li * resc + jnp.sum(p, axis=-1)
        acc = jax.lax.dynamic_update_index_in_dim(acc, ai, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, li, i, 1)
        return (acc, m, l), None

    acc0 = jnp.zeros((B, nb, block_size, Hq, D), jnp.float32)
    m0 = jnp.full((B, nb, block_size, Hq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nb, block_size, Hq), jnp.float32)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (pi, pj))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     logit_cap: float = 0.0, is_global=None):
    """Single-token attention against a preallocated cache.

    q: (B, 1, Hq, D); caches: (B, T, Hkv, D); cache_len: int32 scalar or (B,)
    vector — number of valid cache entries *including* the current token
    (already written).  Per-row lengths support continuous batching (slots
    at different positions).  Scores are (B, Hq, T): tiny, computed directly.
    Under a kv_seq-sharded cache this lowers to partial softmax + combine
    collectives (split-K decode, DESIGN.md §6.3).
    """
    B, _, Hq, D = q.shape
    _, T, Hkv, _ = k_cache.shape
    groups = Hq // Hkv
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    kexp = jnp.repeat(k_cache, groups, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q[:, 0], kexp,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    pos = jnp.arange(T)
    mask = pos[None, None, :] < cache_len[:, None, None]
    if window:
        w_ok = pos[None, None, :] > (cache_len[:, None, None] - 1 - window)
        if is_global is not None:
            w_ok = w_ok | is_global
        mask = mask & w_ok
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    vexp = jnp.repeat(v_cache, groups, axis=2)
    out = jnp.einsum("bht,bthd->bhd", p.astype(v_cache.dtype), vexp,
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(q.dtype)


def scatter_kv(cache, new, pos):
    """Write `new` (B, 1, ...) into `cache` (B, T, ...) at per-row positions
    `pos` (B,) — the continuous-batching cache update (vmapped DUS lowers to
    an efficient scatter)."""
    def one(c, n, p):
        return jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (p,) + (0,) * (c.ndim - 1))

    return jax.vmap(one)(cache, new, pos)
