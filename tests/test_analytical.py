"""Analytical model: design-point behavior must reproduce the paper's
qualitative claims (Fig. 1, §4.2)."""
import pytest

from repro.common.platform import TPU_V5E, VCK190
from repro.configs.paper_workloads import (DEIT_B, MLP_L, MLP_S, POINTNET,
                                           BERT_32, BERT_512)
from repro.core.analytical import (best_accel_latency, charm_monolithic,
                                   charm_three, charm_two, filco_ablation,
                                   filco_vck190, layer_latency, rsn_overlay)

WORKLOADS = [MLP_L, MLP_S, DEIT_B, POINTNET, BERT_32, BERT_512]


def throughput(accels, wl):
    t = sum(best_accel_latency(accels, VCK190, l.m, l.k, l.n).total_s
            for l in wl.layers)
    return wl.total_flops / t


@pytest.fixture(scope="module")
def table():
    systems = {
        "CHARM-1": charm_monolithic(), "CHARM-2": charm_two(),
        "CHARM-3": charm_three(), "RSN": rsn_overlay(),
        "FILCO": [filco_vck190()],
    }
    return {name: {wl.name: throughput(acc, wl) for wl in WORKLOADS}
            for name, acc in systems.items()}


def test_filco_dominates_everywhere(table):
    for wl in WORKLOADS:
        best_other = max(table[s][wl.name] for s in table if s != "FILCO")
        assert table["FILCO"][wl.name] >= 0.99 * best_other, wl.name


def test_charm1_peaks_on_large_uniform_but_collapses(table):
    c1 = table["CHARM-1"]
    # peak on MLP-L, severe degradation on small/diverse (paper Fig. 1 (1))
    assert c1["MLP-L"] > 10 * c1["MLP-S"]
    assert c1["MLP-L"] > 10 * c1["PointNet-L"]


def test_charm_partitioning_trades_peak_for_robustness(table):
    # CHARM-2/3 beat CHARM-1 on small workloads but lose the MLP-L peak
    assert table["CHARM-2"]["MLP-S"] > table["CHARM-1"]["MLP-S"]
    assert table["CHARM-2"]["MLP-L"] < table["CHARM-1"]["MLP-L"]


def test_rsn_between_charm_and_filco_on_diverse(table):
    for wl in ("DeiT-L", "MLP-S"):
        assert table["RSN"][wl] > table["CHARM-1"][wl]
        assert table["FILCO"][wl] > table["RSN"][wl]


def test_paper_speedup_envelope(table):
    """1.3x–5x+ gains on diverse workloads vs CHARM/RSN (paper abstract)."""
    gains = []
    for wl in ("MLP-S", "PointNet-L", "BERT-32"):
        for s in ("CHARM-1", "RSN"):
            gains.append(table["FILCO"][wl] / table[s][wl])
    assert max(gains) >= 3.0
    assert min(gains) >= 1.2


def test_ablation_ordering():
    """Each FILCO feature adds throughput on a small diverse MM (Fig. 10)."""
    m, k, n = 96, 768, 96
    lat = {}
    for tag, acc in [
        ("fp", filco_ablation(fp=True)),
        ("fp+fmf", filco_ablation(fp=True, fmf=True)),
        ("fp+fmf+fmv", filco_ablation(fp=True, fmf=True, fmv=True)),
    ]:
        lat[tag] = layer_latency(acc, VCK190, m, k, n).total_s
    assert lat["fp+fmf+fmv"] <= lat["fp+fmf"] <= lat["fp"]
    assert lat["fp+fmf+fmv"] < lat["fp"]


def test_flexible_parallelism_efficiency_crossover():
    """FP: small MMs waste no atoms; static pays the full tile (Fig. 8)."""
    flex = filco_vck190()
    static = charm_monolithic()[0]
    small = layer_latency(flex, VCK190, 16, 24, 16)
    small_static = layer_latency(static, VCK190, 16, 24, 16)
    assert small.flops_issued < small_static.flops_issued / 100
    big = layer_latency(flex, VCK190, 2048, 2048, 2048)
    big_static = layer_latency(static, VCK190, 2048, 2048, 2048)
    assert big.flops_issued == pytest.approx(big_static.flops_issued, rel=0.01)


def test_tpu_profile_scales():
    """The same model prices a TPU design point (profile swap, Fig. 6)."""
    acc = filco_vck190()
    v = layer_latency(acc, VCK190, 1024, 1024, 1024)
    t = layer_latency(acc, TPU_V5E, 1024, 1024, 1024)
    assert t.total_s < v.total_s        # v5e is simply faster
    assert t.flops_valid == v.flops_valid
