from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                RecompositionEvent, TenantLoad, TenantSpec)

__all__ = [
    "Request",
    "ServeConfig",
    "ServeEngine",
    "AnalyticalPolicy",
    "ComposedServer",
    "RecompositionEvent",
    "TenantLoad",
    "TenantSpec",
]
