"""Counters, gauges, and log-bucketed histograms for the serving fabric.

Zero-dependency (stdlib-only) metrics primitives.  Everything here is
designed around two constraints from the serving hot path:

* **Always-on cheap.**  ``Histogram.observe`` is an integer log2 + one
  list increment; ``Counter.inc``/``Gauge.set`` are a single attribute
  update.  No locks on the record path (the fabric is single-threaded per
  engine; background compile threads only touch their own spans/counters
  through CPython-atomic ops).
* **Mergeable across replicas.**  All histograms share one fixed bucket
  layout, so merging dp replicas (or a retired replica's registry after a
  drain-and-rebalance) is element-wise addition — quantiles computed from
  a merged histogram are deterministic functions of the union of
  observations, regardless of merge order.

Bucket layout: buckets grow by ``2**(1/8)`` (8 buckets per doubling,
~9.05% relative width) starting at ``HIST_BASE`` seconds.  With 288
buckets the range covers 100 ns .. ~19 hours, wide enough for everything
from a single decode step to a cold compile, while a whole histogram is
just a 288-int list (lazily allocated).
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "HIST_BASE",
    "HIST_BUCKETS_PER_DOUBLING",
    "HIST_NBUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "bucket_bounds",
    "metric_key",
]

HIST_BASE = 1e-7                 # seconds; lower edge of bucket 0
HIST_BUCKETS_PER_DOUBLING = 8    # 2**(1/8) growth => ~9% relative error
HIST_NBUCKETS = 288              # covers HIST_BASE * 2**36 ~= 6.9e3 s

_LOG2_BASE = math.log2(HIST_BASE)


def _bucket_index(value: float) -> int:
    """Index of the log bucket containing ``value`` (clamped to range)."""
    if value <= HIST_BASE:
        return 0
    i = int((math.log2(value) - _LOG2_BASE) * HIST_BUCKETS_PER_DOUBLING)
    if i < 0:
        return 0
    if i >= HIST_NBUCKETS:
        return HIST_NBUCKETS - 1
    return i


def bucket_bounds(index: int) -> Tuple[float, float]:
    """(lower, upper) value edges of bucket ``index``."""
    lo = HIST_BASE * 2.0 ** (index / HIST_BUCKETS_PER_DOUBLING)
    hi = HIST_BASE * 2.0 ** ((index + 1) / HIST_BUCKETS_PER_DOUBLING)
    return lo, hi


class Histogram:
    """Fixed-layout log-bucketed histogram with exact count/sum/min/max.

    Quantiles are deterministic: a cumulative scan over the fixed buckets
    with linear interpolation inside the target bucket, so two histograms
    holding the same multiset of observations report identical quantiles
    (and so does their merge).
    """

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self) -> None:
        self.counts: List[int] = [0] * HIST_NBUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[_bucket_index(v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def merge(self, other: "Histogram") -> "Histogram":
        counts = self.counts
        for i, c in enumerate(other.counts):
            if c:
                counts[i] += c
        self.count += other.count
        self.sum += other.sum
        if other.min < self.min:
            self.min = other.min
        if other.max > self.max:
            self.max = other.max
        return self

    def quantile(self, q: float) -> float:
        """Deterministic quantile estimate (seconds); nan when empty."""
        if self.count == 0:
            return math.nan
        q = min(max(q, 0.0), 1.0)
        # Rank in [1, count]; ceil keeps q=0.5 of {a,b} inside a's bucket.
        rank = min(max(int(math.ceil(q * self.count)), 1), self.count)
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo, hi = bucket_bounds(i)
                frac = (rank - cum) / c
                est = lo + frac * (hi - lo)
                # Exact extremes beat bucket edges when they are tighter.
                return min(max(est, self.min), self.max)
            cum += c
        return self.max  # unreachable unless counts drifted

    def fraction_below(self, threshold: float) -> float:
        """Fraction of observations <= ``threshold`` — the SLO-attainment
        primitive (e.g. ``fraction_below(ttft_target)`` is the tenant's
        TTFT attainment).  Deterministic like :meth:`quantile`: whole
        buckets count exactly, the straddling bucket interpolates
        linearly, and the exact min/max tighten the edges so a histogram
        whose max is under the target reports exactly 1.0.  ``nan`` when
        empty."""
        if self.count == 0:
            return math.nan
        x = float(threshold)
        if x >= self.max:
            return 1.0
        if x < self.min:
            return 0.0
        below = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo, hi = bucket_bounds(i)
            if hi <= x:
                below += c
            elif lo <= x:
                below += c * (x - lo) / (hi - lo)
        return min(max(below / self.count, 0.0), 1.0)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def snapshot(self) -> Dict[str, object]:
        """JSON-friendly view: exact stats + sparse non-zero buckets."""
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.mean,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "buckets": {str(i): c for i, c in enumerate(self.counts) if c},
        }


class Counter:
    """Monotonic counter (int or float increments)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Point-in-time level.  Merging registries keeps the max (the
    hottest replica) — use counters/histograms for additive quantities."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


LabelSet = Tuple[Tuple[str, str], ...]


def _labelset(labels: Mapping[str, str]) -> LabelSet:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def metric_key(name: str, labels: LabelSet) -> str:
    """Render ``name{k=v,...}`` — the snapshot/export key format."""
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class MetricsRegistry:
    """Named, labelled metrics with merge + JSON snapshot.

    Keys are ``(name, sorted-label-tuple)``.  ``merge`` folds another
    registry in: counters add, histograms bucket-add, gauges keep max.
    A ``ReplicaGroup`` merges its per-replica registries (plus the
    registries of replicas retired by a dp shrink) into one view.
    """

    def __init__(self) -> None:
        self._counters: Dict[Tuple[str, LabelSet], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelSet], Gauge] = {}
        self._hists: Dict[Tuple[str, LabelSet], Histogram] = {}

    # -- get-or-create handles -------------------------------------------
    def counter(self, name: str, **labels: str) -> Counter:
        key = (name, _labelset(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels: str) -> Gauge:
        key = (name, _labelset(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, **labels: str) -> Histogram:
        key = (name, _labelset(labels))
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram()
        return h

    # -- label-tuple fast path (used by Telemetry, avoids kwargs dicts) --
    def counter_at(self, name: str, labels: LabelSet) -> Counter:
        key = (name, labels)
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge_at(self, name: str, labels: LabelSet) -> Gauge:
        key = (name, labels)
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram_at(self, name: str, labels: LabelSet) -> Histogram:
        key = (name, labels)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram()
        return h

    # -- aggregation ------------------------------------------------------
    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        for key, c in other._counters.items():
            self._counters.setdefault(key, Counter()).inc(c.value)
        for key, g in other._gauges.items():
            mine = self._gauges.setdefault(key, Gauge())
            if g.value > mine.value:
                mine.value = g.value
        for key, h in other._hists.items():
            self._hists.setdefault(key, Histogram()).merge(h)
        return self

    @staticmethod
    def merged(registries: Iterable["MetricsRegistry"]) -> "MetricsRegistry":
        out = MetricsRegistry()
        for r in registries:
            out.merge(r)
        return out

    def merged_histogram(self, name: str,
                         **match: str) -> Histogram:
        """Merge all histograms named ``name`` whose labels include
        ``match`` (e.g. all replicas/classes of one tenant)."""
        want = set(_labelset(match))
        out = Histogram()
        for (n, labels), h in self._hists.items():
            if n == name and want.issubset(labels):
                out.merge(h)
        return out

    def find_histograms(self, name: str) -> Dict[str, Histogram]:
        return {metric_key(n, ls): h
                for (n, ls), h in self._hists.items() if n == name}

    # -- export -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, object]]:
        return {
            "counters": {metric_key(n, ls): c.value
                         for (n, ls), c in sorted(self._counters.items())},
            "gauges": {metric_key(n, ls): g.value
                       for (n, ls), g in sorted(self._gauges.items())},
            "histograms": {metric_key(n, ls): h.snapshot()
                           for (n, ls), h in sorted(self._hists.items())},
        }
