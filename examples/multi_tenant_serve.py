"""Real-time recomposition (FILCO §1/§2.1): one device mesh serving multiple
tenants, with the fabric LIVE-recomposed as traffic shifts.

The scenario (8 fake host devices, 8 CUs on the 'model' axis):

  phase 1 — tenants A and B each hold 4 CUs and serve concurrently
            (composed: "multiple independent accelerators");
  phase 2 — A takes a traffic burst while B idles: the analytical policy
            grows A by stealing B's CUs mid-stream (decode state moves, B's
            untouched requests keep their devices until B is parked);
  phase 3 — a single large job arrives for A: the fabric unifies into the
            monolithic accelerator (paper's CHARM-1 operating point is one
            composition of the same fabric).

Run (fakes 8 devices; ONLY examples/dry-run may do this):
  PYTHONPATH=src python examples/multi_tenant_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.serve import (AnalyticalPolicy, ComposedServer,  # noqa: E402
                         ServeConfig, TenantSpec)


def run_phase(server, title, steps):
    for _ in range(steps):
        server.step()
    sizes = server.sizes()
    print(f"{title}: composition={sizes} "
          f"pending={ {t: ld.pending_tokens for t, ld in server.loads().items()} }")


def main():
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    serve = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    server = ComposedServer(
        mesh,
        [TenantSpec("tenant-A", "minitron-4b", serve=serve),
         TenantSpec("tenant-B", "qwen2.5-32b", seed=1, serve=serve)],
        policy=AnalyticalPolicy(),
        decide_every=4)
    print(f"fabric: {mesh.devices.size} devices, "
          f"{server.composer.num_cus} CUs on axis 'model'")
    print(f"initial composition: {server.sizes()}")

    rng = np.random.default_rng(0)

    def traffic(tenant, n, plen, new):
        vocab = server.cfgs[tenant].vocab_size
        for _ in range(n):
            server.submit(tenant, rng.integers(1, vocab, size=plen),
                          max_new_tokens=new)

    # phase 1: both tenants comparably loaded -> stay near the 4/4 split
    traffic("tenant-A", 2, 8, 8)
    traffic("tenant-B", 2, 8, 24)
    run_phase(server, "phase 1 (balanced)", 4)

    # phase 2: A bursts while B winds down -> policy shifts B's CUs to A
    # (a live grow/shrink: B keeps serving, smaller)
    traffic("tenant-A", 6, 10, 16)
    run_phase(server, "phase 2 (A bursts)", 20)

    # phase 3: one large job for A -> the fabric unifies
    if server.sizes().get("tenant-A", 0) < server.composer.num_cus:
        server.unify("tenant-A")
    traffic("tenant-A", 1, 24, 24)
    run_phase(server, "phase 3 (unified)", 30)

    server.drain()
    print("\nrecomposition events:")
    for e in server.events:
        print(f"  step {e.step:3d} [{e.reason}] {e.sizes_before} -> "
              f"{e.sizes_after} moved={list(e.moved)} "
              f"({e.seconds * 1e3:.1f} ms)")
    assert server.events, "expected at least one live recomposition"
    assert any(max(e.sizes_after.values()) == server.composer.num_cus
               for e in server.events), "expected a unify step"
    print(f"\nstats: {server.stats()}")
    print("multi-tenant recomposition OK")


if __name__ == "__main__":
    main()
