"""Real-time recomposition (FILCO §1/§2.1): one device mesh serving multiple
tenants, with the fabric LIVE-recomposed as traffic shifts.

The scenario (8 fake host devices, 8 CUs on the 'model' axis):

  phase 1 — tenants A and B each hold 4 CUs and serve concurrently
            (composed: "multiple independent accelerators");
  phase 2 — A takes a traffic burst while B idles: the analytical policy
            grows A by stealing B's CUs mid-stream (decode state moves, B's
            untouched requests keep their devices until B is parked);
  phase 3 — a single large job arrives for A: the fabric unifies into the
            monolithic accelerator (paper's CHARM-1 operating point is one
            composition of the same fabric);
  phase 4 — a heterogeneous fleet: transformer decode + mamba SSM +
            encoder embedding + seamless enc-dec tenants share the fabric
            under class-aware costing (each workload priced by its bound
            resource).

Run (fakes 8 devices; ONLY examples/dry-run may do this):
  PYTHONPATH=src python examples/multi_tenant_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.serve import (AnalyticalPolicy, ComposedServer,  # noqa: E402
                         ServeConfig, TenantSpec)


def run_phase(server, title, steps):
    for _ in range(steps):
        server.step()
    sizes = server.sizes()
    print(f"{title}: composition={sizes} "
          f"pending={ {t: ld.pending_tokens for t, ld in server.loads().items()} }")


def heterogeneous_fleet():
    """One fabric, four workload classes (FILCO's diverse-workload claim):
    a transformer decode tenant, a mamba SSM tenant (constant-size recurrent
    state), an encoder tenant (prefill-only embeddings) and a seamless
    enc-dec tenant (batched bucketed encode + cross-attention decode) share
    8 CUs under the class-aware analytical policy — each priced by its bound
    resource (weight bandwidth / state bandwidth / compute / decode GEMV +
    per-step cross-attention source reads)."""
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    serve = ServeConfig(max_slots=2, max_len=48, eos_id=-1)
    s2t_serve = ServeConfig(max_slots=2, max_len=24, eos_id=-1,
                            max_src_len=32, len_buckets=(16,))
    server = ComposedServer(
        mesh,
        [TenantSpec("llm", "minitron-4b", serve=serve),
         TenantSpec("mamba", "falcon-mamba-7b", seed=1, serve=serve),
         TenantSpec("embed", "qwen2.5-32b", seed=2, serve=serve,
                    workload="encoder"),
         # workload="auto" derives "encdec" from the enc-dec architecture
         TenantSpec("s2t", "seamless-m4t-medium", seed=3, serve=s2t_serve)],
        policy=AnalyticalPolicy(),
        decide_every=3)
    print(f"\nheterogeneous fleet: classes={server.classes} "
          f"composition={server.sizes()}")
    assert server.classes["s2t"] == "encdec"
    rng = np.random.default_rng(1)

    def traffic(name, n, new):
        vocab = server.cfgs[name].vocab_size
        for _ in range(n):
            server.submit(name, rng.integers(1, vocab, size=8),
                          max_new_tokens=new)

    # wave 1: decode + embedding + enc-dec traffic — the idle mamba tenant
    # is parked and its CUs go to the busy classes
    traffic("llm", 2, 10)
    traffic("embed", 4, 0)
    traffic("s2t", 2, 8)
    for _ in range(8):
        server.step()
    # wave 2: a mamba burst — the policy admits it back, stealing CUs from
    # the winding-down classes (a live recomposition between classes)
    traffic("mamba", 3, 12)
    out = server.drain(max_steps=200)
    done = {t: len(d) for t, d in out.items()}
    print(f"completed per tenant: {done}")
    for e in server.events:
        print(f"  step {e.step:3d} [{e.reason}] {e.sizes_before} -> "
              f"{e.sizes_after}")
    assert done == {"llm": 2, "mamba": 3, "embed": 4, "s2t": 2}
    assert server.events, "expected the policy to recompose between classes"
    # embeddings are real vectors, not token streams
    emb = next(iter(server.engines["embed"].results().values()))
    assert len(emb) == server.cfgs["embed"].d_model
    # enc-dec jobs produce full decode streams through the fabric
    s2t_streams = server.engines["s2t"].results()
    assert all(len(toks) == 8 for toks in s2t_streams.values())
    print(f"s2t encode-bucket hits: "
          f"{server.engines['s2t'].stats()['bucket_hits']}")
    print("heterogeneous fleet OK")


def main():
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    serve = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    server = ComposedServer(
        mesh,
        [TenantSpec("tenant-A", "minitron-4b", serve=serve),
         TenantSpec("tenant-B", "qwen2.5-32b", seed=1, serve=serve)],
        policy=AnalyticalPolicy(),
        decide_every=4)
    print(f"fabric: {mesh.devices.size} devices, "
          f"{server.composer.num_cus} CUs on axis 'model'")
    print(f"initial composition: {server.sizes()}")

    rng = np.random.default_rng(0)

    def traffic(tenant, n, plen, new):
        vocab = server.cfgs[tenant].vocab_size
        for _ in range(n):
            server.submit(tenant, rng.integers(1, vocab, size=plen),
                          max_new_tokens=new)

    # phase 1: both tenants comparably loaded -> stay near the 4/4 split
    traffic("tenant-A", 2, 8, 8)
    traffic("tenant-B", 2, 8, 24)
    run_phase(server, "phase 1 (balanced)", 4)

    # phase 2: A bursts while B winds down -> policy shifts B's CUs to A
    # (a live grow/shrink: B keeps serving, smaller)
    traffic("tenant-A", 6, 10, 16)
    run_phase(server, "phase 2 (A bursts)", 20)

    # phase 3: one large job for A -> the fabric unifies
    if server.sizes().get("tenant-A", 0) < server.composer.num_cus:
        server.unify("tenant-A")
    traffic("tenant-A", 1, 24, 24)
    run_phase(server, "phase 3 (unified)", 30)

    server.drain()
    print("\nrecomposition events:")
    for e in server.events:
        print(f"  step {e.step:3d} [{e.reason}] {e.sizes_before} -> "
              f"{e.sizes_after} moved={list(e.moved)} "
              f"({e.seconds * 1e3:.1f} ms)")
    assert server.events, "expected at least one live recomposition"
    assert any(max(e.sizes_after.values()) == server.composer.num_cus
               for e in server.events), "expected a unify step"
    print(f"\nstats: {server.stats()}")
    print("multi-tenant recomposition OK")
    heterogeneous_fleet()


if __name__ == "__main__":
    main()
