"""Functional optimizers with sharding-aware state pytrees.

Optimizer state mirrors the parameter tree, so state leaves inherit the
parameter's logical sharding (ZeRO-3: fully sharded optimizer state for
free).  ``make_optimizer(cfg)`` picks AdamW (default) or factored Adafactor
(>=100B archs: arctic-480b, qwen1.5-110b — DESIGN.md §6.4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]   # (grads, state, params, lr)


# ---------------------------------------------------------------------------
# gradient utilities
# ---------------------------------------------------------------------------

def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"       # bf16 states = distributed-memory trick


def adamw(cfg: AdamWConfig = AdamWConfig()) -> Optimizer:
    sdt = jnp.dtype(cfg.state_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, sdt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
            v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
            mh = m32 / b1c
            vh = v32 / b2c
            step = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:      # decoupled weight decay on matrices only
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * step
            return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        newp = jax.tree.map(lambda t: t[0], flat,
                            is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t: t[1], flat,
                            is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree.map(lambda t: t[2], flat,
                            is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": newm, "v": newv, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, beta1=0) — O(n+m) state for (n,m) params
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.8          # t^-decay running-average schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


def adafactor(cfg: AdafactorConfig = AdafactorConfig()) -> Optimizer:
    def init(params):
        def make(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree.map(make, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** (-cfg.decay)

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + cfg.eps
            if p.ndim >= 2:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + cfg.eps)
                cfac = jax.lax.rsqrt(vc + cfg.eps)
                step = g32 * rfac[..., None] * cfac[..., None, :]
                newv = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                step = g32 * jax.lax.rsqrt(vv + cfg.eps)
                newv = {"v": vv}
            # update clipping (rms of step <= threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-30)
            step = step / jnp.maximum(1.0, rms / cfg.clip_threshold)
            if cfg.weight_decay and p.ndim >= 2:
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * step
            return newp.astype(p.dtype), newv

        flat = jax.tree.map(upd, grads, state["v"], params)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        newp = jax.tree.map(lambda t: t[0], flat, is_leaf=is_pair)
        newv = jax.tree.map(lambda t: t[1], flat, is_leaf=is_pair)
        return newp, {"v": newv, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(warmup, 1))
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


def make_optimizer(name: str, **kwargs) -> Optimizer:
    if name == "adamw":
        return adamw(AdamWConfig(**kwargs))
    if name == "adafactor":
        return adafactor(AdafactorConfig(**kwargs))
    raise ValueError(name)
