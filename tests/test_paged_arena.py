"""PagedArena property suite + the slot-pool release audit.

The invariants the paged-KV PR stands on:

* random alloc / grow / free sequences never overlap pages, never leak a
  page, and a drained arena always re-packs to FULL capacity in one table
  (equal-size pages cannot fragment — the property that makes pages the
  FMU's natural admission currency);
* every serving-engine exit path — sync finish, pipelined finish,
  preemption (+ resume), evacuate — releases the slot and its arena
  reservation *together* (``DecodeEngine._release_slot``), so arena bytes
  return to zero after every request drains; ``_evict_finished`` only ever
  touches finished records, never reservations;
* preempt / resume is invisible in the token streams (exact device-state
  save + host re-injection), and an oversubscribed arena
  (``kv_arena_frac`` < 1) preempts instead of wedging.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.core import arena as ar
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


# ---------------------------------------------------------------------------
# allocator properties (host-only, no jax compute)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_paged_random_ops_never_overlap_never_leak(seed):
    """Model-checked churn: after every op the arena's structural
    invariants hold (disjoint pages, substrate accounting exact, page
    counts match logical rows), and freeing everything returns every
    page."""
    rng = np.random.default_rng(seed)
    pa = ar.PagedArena(num_pages=24, page_rows=8, cols=16)
    live = []
    for _ in range(120):
        op = int(rng.integers(0, 3))
        if op == 0 or not live:
            rows = int(rng.integers(1, 100))
            try:
                live.append(pa.alloc(rows, 16))
            except ar.AllocationError:
                assert pa.free_pages < pa.pages_for(rows)
        elif op == 1:
            t = live[int(rng.integers(0, len(live)))]
            before = (t.rows, len(t.pages))
            want = t.rows + int(rng.integers(0, 24))
            try:
                pa.grow(t, want)
                assert t.rows >= before[0]
            except ar.AllocationError:
                # failed growth must leave the table untouched
                assert (t.rows, len(t.pages)) == before
        else:
            t = live.pop(int(rng.integers(0, len(live))))
            pa.free_view(t)
            pa.free_view(t)                      # idempotent
        pa.check()
    for t in live:
        pa.free_view(t)
    pa.check()
    assert pa.used == 0 and pa.free_pages == pa.num_pages


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_paged_drain_repacks_to_full_capacity(seed):
    """After arbitrary churn and a full drain, ONE table must cover every
    page — equal-size pages defragment by construction (a FlexArena under
    the same churn can end up unable to place its largest view)."""
    rng = np.random.default_rng(seed)
    pa = ar.PagedArena(num_pages=16, page_rows=4, cols=8)
    live = []
    for _ in range(60):
        if int(rng.integers(0, 2)) == 0 or not live:
            try:
                live.append(pa.alloc(int(rng.integers(1, 40)), 8))
            except ar.AllocationError:
                pass
        else:
            pa.free_view(live.pop(int(rng.integers(0, len(live)))))
    for t in live:
        pa.free_view(t)
    full = pa.alloc(pa.num_pages * pa.page_rows, 8)
    assert len(full.pages) == pa.num_pages and pa.free_pages == 0
    pa.check()


def test_paged_api_contract():
    pa = ar.PagedArena(num_pages=4, page_rows=8, cols=16)
    assert pa.pages_for(0) == 0 and pa.pages_for(1) == 1
    assert pa.pages_for(8) == 1 and pa.pages_for(9) == 2
    with pytest.raises(ar.AllocationError):
        pa.alloc(8, 32)                          # cols must match
    with pytest.raises(ar.AllocationError):
        pa.alloc(0, 16)
    t = pa.alloc(10, 16)                         # 2 pages
    assert pa.used_pages == 2 and t.size == 2 * pa.page_elems
    pa.grow(t, 16)                               # same 2 pages
    assert len(t.pages) == 2
    pa.grow(t, 17)                               # crosses a boundary
    assert len(t.pages) == 3
    with pytest.raises(ar.AllocationError):
        pa.grow(t, 100)                          # needs 13 pages, has 4
    assert len(t.pages) == 3 and t.rows == 17    # unchanged by the failure
    pa.free_view(t)
    with pytest.raises(ar.AllocationError):
        pa.grow(t, 20)                           # grow on a freed table
    assert pa.used == 0
    with pytest.raises(ValueError):
        ar.PagedArena(num_pages=0, page_rows=8, cols=16)
    assert pa.fits([(9, 16), (8, 16)]) and not pa.fits([(33, 16)])


# ---------------------------------------------------------------------------
# the engine release audit: slots + reservations always exit together
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def _engine(model, params, **kw):
    defaults = dict(max_slots=3, max_len=32, eos_id=-1)
    defaults.update(kw)
    return ServeEngine(model, params, ServeConfig(**defaults))


def _submit(eng, cfg, n, seed=0, new=6):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        eng.submit(rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(3, 12))),
                   max_new_tokens=new)


@pytest.mark.parametrize("paged", [True, False])
@pytest.mark.parametrize("pipeline", [True, False])
def test_arena_drains_to_zero_on_every_exit_path(qwen, paged, pipeline):
    """The satellite-4 pin: whatever the finish path (sync harvest,
    pipelined dispatch-time finish, preempt + resume), arena bytes return
    to exactly zero once every request drains — on the paged arena AND the
    slot-granular FlexArena."""
    cfg, model, params = qwen
    eng = _engine(model, params, paged_kv=paged, kv_page_rows=8,
                  pipeline_decode=pipeline)
    _submit(eng, cfg, 5)
    steps = 0
    while eng.has_work:
        if steps == 3:
            assert eng.preempt_one() is not None
        eng.step()
        steps += 1
        assert steps < 400
    assert eng.arena.used == 0
    assert eng.preempt_count == 1
    assert len(eng.results()) == 5
    assert all(len(t) == 6 for t in eng.results().values())


def test_preempt_resume_streams_bitexact(qwen):
    """Seeded preempt points anywhere in the run never change one token:
    preemption exports the exact cache block and re-injects the last
    emitted token on resume, and greedy decode rows are batch-
    independent."""
    cfg, model, params = qwen

    def run(preempt_at=()):
        eng = _engine(model, params, paged_kv=True, kv_page_rows=8)
        _submit(eng, cfg, 4, new=8)
        steps = 0
        while eng.has_work:
            if steps in preempt_at:
                eng.preempt_one()
            eng.step()
            steps += 1
            assert steps < 400
        assert eng.arena.used == 0
        return eng.results()

    ref = run()
    assert run(preempt_at=(2, 5, 9)) == ref
    assert run(preempt_at=(1, 2, 3)) == ref


def test_oversubscribed_arena_preempts_and_completes(qwen):
    """kv_arena_frac < 1 oversubscribes pages: growth pressure must
    preempt (never deadlock, never drop work) and every stream still
    completes its full budget with the arena drained."""
    cfg, model, params = qwen
    eng = _engine(model, params, paged_kv=True, kv_page_rows=4,
                  kv_arena_frac=0.5)
    _submit(eng, cfg, 6, new=16)
    steps = 0
    while eng.has_work:
        eng.step()
        steps += 1
        assert steps < 1000
    assert eng.preempt_count >= 1
    assert eng.arena.used == 0
    assert all(len(t) == 16 for t in eng.results().values())


def test_evacuate_releases_everything_including_parked(qwen):
    """A dp retune's evacuate must strip parked (preempted) requests along
    with live slots — they ride along as exact cache-block exports — and
    leave the arena empty."""
    cfg, model, params = qwen
    eng = _engine(model, params, paged_kv=True)
    _submit(eng, cfg, 4)
    eng.step()
    eng.step()
    assert eng.preempt_one() is not None
    live, queued = eng.evacuate()
    assert eng.arena.used == 0 and eng.active_count == 0
    assert len(live) == 3 and len(queued) == 1
    assert eng.preempted_depth == 0
