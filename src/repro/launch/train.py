"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --reduced \
      --steps 50 --seq-len 64 --global-batch 8

On real hardware ``--arch <id>`` (full config) trains on the production mesh
with train_rules(); on this CPU container use ``--reduced`` for the smoke
configs or ``--mesh-shape`` under a host-device-count override.  The launcher
wires pipeline -> Trainer (checkpoint/restart, preemption guard, straggler
watchdog) and implements the restart policy loop.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import make_pipeline
from repro.distribution import partitioning as part
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.train import TrainConfig, Trainer
from repro.train import fault


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--production-mesh", action="store_true",
                    help="build the 16x16 production mesh (real pods)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    pipe = make_pipeline(cfg, args.seq_len, args.global_batch,
                         host_id=jax.process_index(),
                         num_hosts=jax.process_count())
    mesh = rules = None
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = part.train_rules()
    tc = TrainConfig(steps=args.steps, lr=args.lr,
                     microbatches=args.microbatches,
                     ckpt_dir=args.ckpt_dir)

    policy = fault.RestartPolicy(max_restarts=args.max_restarts,
                                 base_backoff_s=0.0)
    while True:
        trainer = Trainer(model, tc, mesh=mesh, rules=rules, pipeline=pipe)
        out = trainer.fit()
        print(json.dumps({"status": out["status"], "step": out["step"],
                          "final": out["metrics"][-1] if out["metrics"] else {}},
                         indent=1))
        if out["status"] == "completed":
            return 0
        backoff = policy.next_backoff()
        if backoff is None:
            print("restart budget exhausted", file=sys.stderr)
            return 1
        print(f"[fault] {out['status']} at step {out['step']}; "
              f"restarting (resume from checkpoint)")


if __name__ == "__main__":
    raise SystemExit(main())
