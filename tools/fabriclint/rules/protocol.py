"""protocol: the five engines implement ``Engine`` signature-exactly.

``Engine`` is a ``runtime_checkable`` Protocol, but ``isinstance`` only
checks member *presence* — a drifted signature (renamed parameter, a
positional param grown where callers pass keywords, a dropped kw-only
marker) passes the runtime check and breaks at the one call site that
exercises it.  This rule compares every protocol member against each
implementation through the in-file-set MRO:

* positional parameter names must match the protocol's, in order;
* extra positionals must carry defaults (callers using the protocol
  signature still work); protocol defaults must remain defaults;
* protocol kw-only names must be accepted kw-only (or via ``**kwargs``);
  extra kw-onlys must carry defaults;
* ``@property`` members must be properties (or satisfied by a class/
  ``__init__`` attribute); plain data members by an attribute anywhere in
  the chain.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from tools.fabriclint import Finding
from tools.fabriclint.walker import ClassInfo, Index

RULE = "protocol"

PROTOCOL_NAME = "Engine"
IMPLEMENTATIONS = ("DecodeEngine", "SSMEngine", "EncoderEngine",
                   "EncDecEngine", "ReplicaGroup")


class _Sig:
    def __init__(self, node: ast.FunctionDef):
        a = node.args
        self.pos = [p.arg for p in list(a.posonlyargs) + list(a.args)]
        if self.pos and self.pos[0] in ("self", "cls"):
            self.pos = self.pos[1:]
        self.n_pos_defaults = len(a.defaults)
        self.kwonly = [p.arg for p in a.kwonlyargs]
        self.kwonly_defaults = {p.arg: d is not None
                                for p, d in zip(a.kwonlyargs, a.kw_defaults)}
        self.vararg = a.vararg is not None
        self.kwarg = a.kwarg is not None

    def pos_has_default(self, i: int) -> bool:
        return i >= len(self.pos) - self.n_pos_defaults


def _mismatch(proto: _Sig, impl: _Sig) -> Optional[str]:
    n = len(proto.pos)
    if impl.pos[:n] != proto.pos:
        if impl.vararg and not impl.pos:
            pass               # *args absorbs the positional surface
        else:
            return (f"positional params {impl.pos[:n] or '()'} != protocol's "
                    f"{proto.pos}")
    for i, name in enumerate(proto.pos):
        if proto.pos_has_default(i) and i < len(impl.pos) \
                and not impl.pos_has_default(i):
            return f"protocol default param `{name}` lost its default"
    for i in range(n, len(impl.pos)):
        if not impl.pos_has_default(i):
            return (f"extra positional param `{impl.pos[i]}` has no default "
                    "— protocol-shaped calls break")
    for name in proto.kwonly:
        if name not in impl.kwonly and not impl.kwarg:
            return f"protocol kw-only param `{name}` not accepted kw-only"
    for name in impl.kwonly:
        if name not in proto.kwonly \
                and not impl.kwonly_defaults.get(name, False):
            return f"extra kw-only param `{name}` has no default"
    return None


def _has_attr(index: Index, chain: List[ClassInfo], name: str) -> bool:
    for c in chain:
        if name in c.class_attrs or name in c.init_attrs \
                or name in c.properties:
            return True
    return False


def check(index: Index, config: Dict) -> List[Finding]:
    protos = [c for c in index.classes.get(PROTOCOL_NAME, [])
              if c.is_protocol]
    if not protos:
        return []
    proto = protos[0]
    findings: List[Finding] = []
    for impl_name in IMPLEMENTATIONS:
        for impl in index.classes.get(impl_name, []):
            chain = index.mro_chain(impl)
            findings.extend(_check_impl(index, proto, impl, chain))
    return findings


def _check_impl(index: Index, proto: ClassInfo, impl: ClassInfo,
                chain: List[ClassInfo]) -> List[Finding]:
    out: List[Finding] = []

    def finding(msg: str, code: str) -> Finding:
        return Finding(rule=RULE, path=impl.path, line=impl.node.lineno,
                       symbol=impl.name, code=code, message=msg)

    for name, member in proto.methods.items():
        impl_fn = index.resolve_method(impl, name)
        if member.is_property:
            if impl_fn is not None and impl_fn.is_property:
                continue
            if _has_attr(index, chain, name):
                continue
            out.append(finding(
                f"protocol property `{name}` is neither a @property nor an "
                "attribute on the class", f"property:{name}"))
            continue
        if impl_fn is None or impl_fn.is_property:
            out.append(finding(
                f"protocol method `{name}` is "
                + ("a property here" if impl_fn else "missing"),
                f"method:{name}"))
            continue
        msg = _mismatch(_Sig(member.node), _Sig(impl_fn.node))
        if msg is not None:
            out.append(Finding(
                rule=RULE, path=impl_fn.path, line=impl_fn.node.lineno,
                symbol=f"{impl.name}.{name}", code=f"signature:{name}",
                message=f"`{name}` drifts from the Engine protocol: {msg}"))

    for attr in sorted(proto.class_attrs):
        if not _has_attr(index, chain, attr):
            out.append(finding(
                f"protocol attribute `{attr}` not set anywhere in the class "
                "chain", f"attr:{attr}"))
    return out
