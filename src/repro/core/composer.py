"""Mesh composer — FILCO's "composed into a unified or multiple independent
accelerators" (paper §1, §2.1) at pod scale.

On the Versal board, CUs behind a fully-connected stream topology are grouped
per layer by the scheduler.  On a TPU pod, the allocatable unit is a slice of
the device mesh: the composer partitions the mesh's model axis (and/or data
axis) into disjoint sub-meshes, one per concurrently-scheduled layer group or
per tenant model, and reunifies them when a large uniform workload wants the
monolithic accelerator (the CHARM-1 operating point is *one* composition of
the same fabric).

Pure device-array math + jax.sharding.Mesh construction; exercised by the
multi-tenant serving example and tested under a host-device-count subprocess.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.core.dse import ExecutionPlan, PlannedLayer


@dataclasses.dataclass(frozen=True)
class SubAccelerator:
    """A composed accelerator: a contiguous slice of mesh CUs."""

    name: str
    cu_ids: Tuple[int, ...]          # columns of the model axis
    mesh: Optional[Mesh]             # None when constructed without devices


def split_axis(devices: np.ndarray, axis: int,
               sizes: Sequence[int]) -> List[np.ndarray]:
    """Split a device array along `axis` into blocks of the given sizes."""
    assert sum(sizes) == devices.shape[axis], (sizes, devices.shape)
    out = []
    start = 0
    for s in sizes:
        idx = [slice(None)] * devices.ndim
        idx[axis] = slice(start, start + s)
        out.append(devices[tuple(idx)])
        start += s
    return out


class MeshComposer:
    """Carves sub-accelerators out of a (data, model) or (pod, data, model)
    mesh.  CU granularity: one CU = one model-axis column (a data-parallel
    group of chips), matching the scheduler's C_max."""

    def __init__(self, mesh: Mesh, *, cu_axis: str = "model"):
        self.mesh = mesh
        self.cu_axis = cu_axis
        self.axis_index = mesh.axis_names.index(cu_axis)
        self.num_cus = mesh.devices.shape[self.axis_index]

    def unified(self) -> SubAccelerator:
        """The monolithic composition: all CUs as one accelerator."""
        return SubAccelerator("unified", tuple(range(self.num_cus)), self.mesh)

    def compose(self, sizes: Sequence[int],
                names: Optional[Sequence[str]] = None) -> List[SubAccelerator]:
        """Partition the CU axis into independent accelerators of the given
        sizes (must sum to the axis size)."""
        blocks = split_axis(self.mesh.devices, self.axis_index, sizes)
        out = []
        start = 0
        for i, (blk, size) in enumerate(zip(blocks, sizes)):
            name = names[i] if names else f"sub{i}"
            sub = Mesh(blk, self.mesh.axis_names)
            out.append(SubAccelerator(name, tuple(range(start, start + size)),
                                      sub))
            start += size
        return out

    def for_plan(self, plan: ExecutionPlan) -> Dict[int, SubAccelerator]:
        """Map every planned layer's CU set to a sub-mesh.  Layers sharing a
        CU set share the sub-accelerator (ping-pong reuse across time)."""
        cache: Dict[Tuple[int, ...], SubAccelerator] = {}
        result: Dict[int, SubAccelerator] = {}
        for pl in plan.layers:
            key = tuple(sorted(pl.cu_ids))
            if key not in cache:
                if max(key) >= self.num_cus:
                    raise ValueError(
                        f"plan uses CU {max(key)} but mesh has {self.num_cus}")
                idx = [slice(None)] * self.mesh.devices.ndim
                idx[self.axis_index] = list(key)
                blk = self.mesh.devices[tuple(idx)]
                cache[key] = SubAccelerator(
                    f"cus{key}", key, Mesh(blk, self.mesh.axis_names))
            result[pl.layer] = cache[key]
        return result


def concurrent_groups(plan: ExecutionPlan) -> List[List[PlannedLayer]]:
    """Maximal sets of layers whose schedule intervals overlap — these run
    simultaneously on disjoint compositions (validation: Eq. 4 guarantees
    disjoint CU sets)."""
    events = sorted({pl.start for pl in plan.layers})
    groups = []
    for t in events:
        live = [pl for pl in plan.layers if pl.start <= t < pl.end]
        if live and live not in groups:
            groups.append(live)
    return groups
