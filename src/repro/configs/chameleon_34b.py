"""chameleon-34b — early-fusion VLM [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  Early fusion: VQ-GAN
image codes live *inside* the text vocabulary, so the backbone consumes one
mixed token stream; the image tokenizer frontend is a STUB per assignment
(``input_specs()`` provides token ids that include image-token spans).
Chameleon stabilizes training with QK-norm — modeled here.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    attn_type="full",
    qk_norm=True,
    act="silu",
    glu=True,
)

REDUCED = ModelConfig(
    name="chameleon-reduced",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    attn_type="full",
    qk_norm=True,
    act="silu",
    glu=True,
)
