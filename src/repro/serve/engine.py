"""Compatibility shim: the batched transformer serving engine moved to
``repro.workloads.decode`` when the workload-class subsystem landed (it is
now one engine class among decode/ssm/encoder — see ``repro.workloads``).

``ServeEngine`` remains the public name for the transformer decode engine;
new code should import :class:`~repro.workloads.decode.DecodeEngine` (or its
siblings) from ``repro.workloads``.
"""
from repro.workloads.decode import (DecodeEngine, Request, ServeConfig,
                                    _mesh_of, _write_slot)

ServeEngine = DecodeEngine

__all__ = ["DecodeEngine", "Request", "ServeConfig", "ServeEngine",
           "_mesh_of", "_write_slot"]
