"""deprecation: the "one release behind a DeprecationWarning" policy,
machine-checked.

Every ``warnings.warn(..., DeprecationWarning)`` shim must carry a
``# fabriclint: deprecated-since=PRn`` annotation between its ``def`` line
and the ``warn`` call (or on the line above the ``def``).  The shim is in
grace for exactly one release: it fails the lint once
``current_pr > n + 1``, at which point the fix is deletion, not a baseline
entry.  ``current_pr`` defaults to the highest PR number in CHANGES.md —
the repo's own changelog is the release clock — and is overridable with
``--current-pr`` (how tests and the red-before-removal workflow pin it).
"""
from __future__ import annotations

import ast
from typing import Dict, List

from tools.fabriclint import Finding
from tools.fabriclint.walker import Index, snippet

RULE = "deprecation"
GRACE_RELEASES = 1


def _is_deprecation_warn(node: ast.Call) -> bool:
    fn = node.func
    named_warn = (isinstance(fn, ast.Name) and fn.id == "warn") or \
        (isinstance(fn, ast.Attribute) and fn.attr == "warn")
    if not named_warn:
        return False
    for arg in list(node.args) + [kw.value for kw in node.keywords]:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Name) and sub.id == "DeprecationWarning":
                return True
    return False


def check(index: Index, config: Dict) -> List[Finding]:
    current_pr = int(config.get("current_pr") or 0)
    findings: List[Finding] = []
    for name in sorted(index.functions):
        for info in index.functions[name]:
            for node in ast.walk(info.node):
                if not (isinstance(node, ast.Call)
                        and _is_deprecation_warn(node)):
                    continue
                since = index.deprecated_since_for(
                    info.path, info.node.lineno - 1, node.lineno)
                if since is None:
                    findings.append(Finding(
                        rule=RULE, path=info.path, line=node.lineno,
                        symbol=info.qualname, code=snippet(node, 60),
                        message=("DeprecationWarning shim without a "
                                 "`# fabriclint: deprecated-since=PRn` "
                                 "annotation — the grace window can't be "
                                 "enforced")))
                elif current_pr > since + GRACE_RELEASES:
                    findings.append(Finding(
                        rule=RULE, path=info.path, line=node.lineno,
                        symbol=info.qualname, code=f"deprecated-since=PR{since}",
                        message=(f"deprecated since PR{since}; the one-release "
                                 f"grace window closed at PR{since + GRACE_RELEASES} "
                                 f"(current: PR{current_pr}) — delete this shim")))
    return findings
