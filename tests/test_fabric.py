"""Real-time recomposition: delta planning is movement-minimal and pure;
resharding a live engine preserves decode numerics bit-exactly; unaffected
tenants keep their device assignments.  Device-touching scenarios run in an
8-host-device subprocess (device count is fixed at first jax init)."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.core.composer import (RecompositionDelta, plan_recomposition,
                                 recomposition_delta)
from repro.serve.fabric import (AnalyticalPolicy, TenantObservation,
                                _candidate_splits, _compositions)

# ---------------------------------------------------------------------------
# pure delta-planning tests (no devices)
# ---------------------------------------------------------------------------


def test_plan_unchanged_tenants_keep_exact_cus():
    cur = {"a": (0, 1, 2, 3), "b": (4, 5, 6, 7)}
    new = plan_recomposition(cur, {"a": 4, "b": 4}, 8)
    assert new == cur
    d = recomposition_delta(cur, new)
    assert d == RecompositionDelta(("a", "b"), (), (), ())


def test_plan_grow_steals_only_from_shrunk_tenant():
    cur = {"a": (0, 1, 2, 3), "b": (4, 5, 6, 7)}
    new = plan_recomposition(cur, {"a": 6, "b": 2}, 8)
    # a keeps its 4 and gains 2; b keeps a subset of its own
    assert set(cur["a"]) <= set(new["a"]) and len(new["a"]) == 6
    assert set(new["b"]) <= set(cur["b"]) and len(new["b"]) == 2
    assert not set(new["a"]) & set(new["b"])
    d = recomposition_delta(cur, new)
    assert set(d.moved) == {"a", "b"} and not d.unchanged


def test_plan_third_tenant_unaffected_by_neighbors():
    cur = {"a": (0, 1), "b": (2, 3, 4), "c": (5, 6, 7)}
    new = plan_recomposition(cur, {"a": 3, "b": 2, "c": 3}, 8)
    assert new["c"] == cur["c"]                  # untouched
    d = recomposition_delta(cur, new)
    assert "c" in d.unchanged and set(d.moved) == {"a", "b"}


def test_plan_park_and_admit():
    cur = {"a": (0, 1, 2, 3), "b": (4, 5, 6, 7)}
    new = plan_recomposition(cur, {"a": 8, "b": 0}, 8)
    assert new == {"a": (0, 1, 2, 3, 4, 5, 6, 7)}
    d = recomposition_delta(cur, new)
    assert d.evicted == ("b",) and d.moved == ("a",)
    back = plan_recomposition(new, {"a": 4, "b": 4}, 8)
    assert len(back["a"]) == len(back["b"]) == 4
    assert recomposition_delta(new, back).admitted == ("b",)


def test_plan_rejects_oversubscription():
    with pytest.raises(ValueError):
        plan_recomposition({}, {"a": 5, "b": 4}, 8)


def test_compositions_enumerates_all_positive_splits():
    splits = list(_compositions(5, 2))
    assert splits == [(1, 4), (2, 3), (3, 2), (4, 1)]
    assert all(sum(s) == 8 for s in _compositions(8, 3))


def test_candidate_splits_proportional_fallback_at_pod_scale():
    # C(63, 7) >> budget: one demand-proportional split instead of a hang
    busy = [f"t{i}" for i in range(8)]
    demand = {t: float(i + 1) for i, t in enumerate(busy)}
    splits = list(_candidate_splits(64, busy, demand))
    assert len(splits) == 1
    (s,) = splits
    assert sum(s) == 64 and all(x >= 1 for x in s)
    assert list(s) == sorted(s)      # heavier demand never gets less


# ---------------------------------------------------------------------------
# policy (pure: analytical model only)
# ---------------------------------------------------------------------------

def _load(pending, active=1, util=0.0):
    return TenantObservation(pending_tokens=pending, queue_depth=0,
                             active=active, arena_utilization=util)


def _cus(points):
    """Design-point dict -> {tenant: CU count} (composed tenants only)."""
    return {t: p.cus for t, p in points.items() if p.cus > 0}


def test_policy_gives_lone_busy_tenant_the_fabric():
    from repro.configs import get_reduced
    cfgs = {"a": get_reduced("minitron-4b"), "b": get_reduced("minitron-4b")}
    pol = AnalyticalPolicy()
    points, reason = pol.decide({"a": _load(100), "b": _load(0)},
                                cfgs, {"a": 4, "b": 4}, 8)
    assert _cus(points) == {"a": 8} and reason == "unify"


def test_policy_hysteresis_keeps_balanced_split():
    from repro.configs import get_reduced
    cfgs = {"a": get_reduced("minitron-4b"), "b": get_reduced("minitron-4b")}
    pol = AnalyticalPolicy()
    points, reason = pol.decide({"a": _load(50), "b": _load(50)},
                                cfgs, {"a": 4, "b": 4}, 8)
    assert _cus(points) == {"a": 4, "b": 4} and reason == "hysteresis"


def test_policy_admits_parked_tenant_with_new_work():
    from repro.configs import get_reduced
    cfgs = {"a": get_reduced("minitron-4b"), "b": get_reduced("minitron-4b")}
    points, reason = AnalyticalPolicy().decide(
        {"a": _load(10), "b": _load(10)}, cfgs, {"a": 8, "b": 0}, 8)
    assert reason == "admit" and _cus(points).get("b", 0) >= 1


def test_decide_legacy_keyword_form_is_gone():
    """The PR-5 calling convention (TenantLoad values + classes=/lengths=
    side channels) rode one release behind a DeprecationWarning and was
    deleted when the grace window closed (the fabriclint deprecation rule
    is the enforcement; see docs/static-analysis.md)."""
    from repro.configs import get_reduced
    cfgs = {"a": get_reduced("minitron-4b"), "b": get_reduced("minitron-4b")}
    obs = {"a": _load(100), "b": _load(0)}
    with pytest.raises(TypeError):
        AnalyticalPolicy().decide(obs, cfgs, {"a": 4, "b": 4}, 8,
                                  classes={"a": "decode"})


# ---------------------------------------------------------------------------
# device scenarios (8 fake host devices, subprocess)
# ---------------------------------------------------------------------------

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import numpy as np
"""


def _run(body: str, timeout=900):
    out = subprocess.run([sys.executable, "-c",
                          _PRELUDE + textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_recomposition_preserves_decode_numerics():
    """Tokens across a mid-stream grow -> shrink -> unify sequence match a
    never-recomposed run bit-exactly (acceptance criterion)."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.distribution import strip
    from repro.models import build_model
    from repro.serve import ServeConfig, ServeEngine

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    cfg = get_reduced("minitron-4b")
    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 12))) for _ in range(3)]

    def run(script):
        model = build_model(cfg)
        params = strip(model.init(jax.random.key(0)))
        eng = ServeEngine(model, params, sc, mesh=comp.submesh(range(4), "t"))
        for p in prompts:
            eng.submit(p, max_new_tokens=10)
        step = 0
        while eng._queue or eng._active:
            if step in script:
                ids, name = script[step]
                eng.reshard_to(comp.submesh(ids, name))
            eng.step()
            step += 1
            assert step < 200
        return {str(r): t for r, t in eng.results().items()}

    ref = run({})
    dyn = run({3: (range(6), "grown"), 7: (range(2), "shrunk"),
               11: (range(8), "unified")})
    print(json.dumps({"match": ref == dyn, "n": len(ref)}))
    """)
    assert res["n"] == 3 and res["match"], "recomposition changed numerics"


def test_composed_server_delta_leaves_unmoved_tenant_devices():
    """ComposedServer.recompose: the unchanged tenant keeps the SAME mesh
    devices; moved tenants' params land on their new sub-mesh."""
    res = _run("""
    from repro.serve.fabric import ComposedServer, TenantSpec
    from repro.serve import ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=32, eos_id=-1)
    srv = ComposedServer(mesh, [
        TenantSpec("a", "minitron-4b", serve=sc),
        TenantSpec("b", "minitron-4b", seed=1, serve=sc),
        TenantSpec("c", "minitron-4b", seed=2, serve=sc),
    ], policy=None)                      # sizes: a=3, b=3, c=2

    def devs(t):
        leaf = jax.tree.leaves(srv.engines[t].params)[0]
        return sorted(d.id for d in leaf.sharding.device_set)

    c_before_sub = srv.subs["c"]
    c_before_devs = devs("c")
    ev = srv.recompose({"a": 4, "b": 2, "c": 2})
    print(json.dumps({
        "c_same_sub": srv.subs["c"] is c_before_sub,
        "c_devs_same": devs("c") == c_before_devs,
        "unchanged": list(ev.unchanged), "moved": sorted(ev.moved),
        "a_ndev": len(devs("a")), "b_ndev": len(devs("b")),
    }))
    """)
    assert res["c_same_sub"] and res["c_devs_same"]
    assert res["unchanged"] == ["c"] and res["moved"] == ["a", "b"]
    assert res["a_ndev"] == 4 and res["b_ndev"] == 2


def test_tp_decode_equivalence_across_degrees():
    """Same prompts through 1-way (replicated), 2-way and 4-way TP
    sub-meshes must emit identical token streams, including across a
    mid-stream reshard_to() that changes the TP degree (satellite +
    tentpole acceptance: sharded decode is an implementation detail, never
    a numerics change a user can observe)."""
    res = _run("""
    import dataclasses
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.models import build_model
    from repro.serve import ServeConfig, ServeEngine, serve_engine_rules

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    # fp32: greedy argmax must be reduction-order-proof across TP degrees
    cfg = dataclasses.replace(get_reduced("minitron-4b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 12))) for _ in range(3)]

    def run(tp, rules, script=None):
        eng = ServeEngine(model, params, sc,
                          mesh=comp.submesh(range(tp), f"tp{tp}"),
                          rules=rules)
        for p in prompts:
            eng.submit(p, max_new_tokens=10)
        step = 0
        while eng.has_work:
            if script and step in script:
                eng.reshard_to(comp.submesh(range(script[step]), "re"))
            eng.step()
            step += 1
            assert step < 200
        return {str(r): t for r, t in eng.results().items()}

    rules = serve_engine_rules()
    ref = run(1, None)                           # replicated baseline
    tp2 = run(2, rules)
    tp4 = run(4, rules)
    dyn = run(4, rules, {3: 2, 7: 8, 11: 4})     # shrink -> unify -> back
    print(json.dumps({"n": len(ref), "tp2": tp2 == ref, "tp4": tp4 == ref,
                      "dyn": dyn == ref}))
    """)
    assert res["n"] == 3
    assert res["tp2"] and res["tp4"], "TP decode diverged from replicated"
    assert res["dyn"], "mid-stream TP-degree change altered the stream"


def test_warm_recompose_skips_post_move_compile():
    """With warming on, the target composition's executables are built
    before the switch commits: the first post-move step performs zero cold
    compiles, and the engine is actually sharded over its new sub-mesh."""
    res = _run("""
    from repro.serve.fabric import ComposedServer, TenantSpec
    from repro.serve import ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=32, eos_id=-1)
    srv = ComposedServer(mesh, [
        TenantSpec("a", "minitron-4b", serve=sc),
        TenantSpec("b", "minitron-4b", seed=1, serve=sc),
    ], policy=None, tp=True, warm=True)          # sizes: a=4, b=4
    rng = np.random.default_rng(0)
    vocab = srv.cfgs["a"].vocab_size
    for t in ("a", "b"):
        srv.submit(t, rng.integers(1, vocab, size=8), max_new_tokens=16)
    for _ in range(3):
        srv.step()                               # executables for 4+4 built

    ev = srv.recompose({"a": 6, "b": 2})
    builds_after_warm = {t: srv.engines[t].compile_builds for t in "ab"}
    srv.step()                                   # first post-move step
    builds_after_step = {t: srv.engines[t].compile_builds for t in "ab"}

    def tp_degree(t):
        leaf = jax.tree.leaves(srv.engines[t].params)[0]
        return len(leaf.sharding.device_set)

    print(json.dumps({
        "warm_builds": ev.warm_builds,
        "warm_seconds_pos": ev.warm_compile_seconds > 0,
        "cold_after_move": {t: builds_after_step[t] - builds_after_warm[t]
                            for t in "ab"},
        "a_ndev": tp_degree("a"), "b_ndev": tp_degree("b"),
        "post_step_recorded": sorted(ev.post_step_seconds),
    }))
    """)
    assert res["warm_builds"] >= 2 and res["warm_seconds_pos"]
    assert res["cold_after_move"] == {"a": 0, "b": 0}, \
        "post-recomposition step recompiled despite warming"
    assert res["a_ndev"] == 6 and res["b_ndev"] == 2
    assert res["post_step_recorded"] == ["a", "b"]


def test_prewarm_async_commits_after_background_compile():
    """prewarm_async: the policy's chosen composition compiles in a
    background thread while the old composition keeps serving; the switch
    commits on a later autoscale tick, marked `overlapped`, and every
    request still completes with its full budget."""
    res = _run("""
    import time
    from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                    TenantSpec)
    from repro.serve import ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    srv = ComposedServer(mesh, [
        TenantSpec("a", "minitron-4b", serve=sc),
        TenantSpec("b", "minitron-4b", seed=1, serve=sc),
    ], policy=AnalyticalPolicy(), decide_every=2, prewarm_async=True)
    rng = np.random.default_rng(0)
    vocab = srv.cfgs["a"].vocab_size
    for _ in range(4):
        srv.submit("a", rng.integers(1, vocab, size=8), max_new_tokens=24)
    steps = 0
    while (not srv.events) and steps < 300:
        srv.step()
        if srv._pending_prewarm is not None:
            time.sleep(0.05)      # let the compile thread make progress
        steps += 1
    out = srv.drain(max_steps=400)
    lens = sorted(len(v) for v in out["a"].values())
    print(json.dumps({
        "events": len(srv.events),
        "overlapped": [e.overlapped for e in srv.events],
        "lens": lens,
    }))
    """)
    assert res["events"] >= 1
    assert res["overlapped"][0] is True, \
        "first recomposition should commit from the background prewarm"
    assert res["lens"] == [24, 24, 24, 24]


def test_replica_group_routing_and_merged_stats():
    """ReplicaGroup under skewed request lengths: least-loaded routing
    keeps owed work balanced across replicas (no replica ends up with all
    the long streams), the group-merged load signals equal the sums over
    ``per_replica`` stats, and every request completes with its full
    budget under its stable group rid."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.core.dse import DesignPoint
    from repro.models import build_model
    from repro.serve import ReplicaGroup, ServeConfig, serve_engine_rules
    from repro.workloads import DECODE

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    cfg = get_reduced("minitron-4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    grp = ReplicaGroup(DECODE, model, params, sc,
                       sub=comp.submesh(range(4), "t"),
                       rules=serve_engine_rules())
    grp.apply(None, DesignPoint(cus=4, tp=1, dp=4))
    rng = np.random.default_rng(0)
    budgets = [32, 2, 32, 2, 32, 2, 32, 2]        # skewed lengths
    rids = [grp.submit(rng.integers(1, cfg.vocab_size, size=6),
                       max_new_tokens=b) for b in budgets]
    owed = [r.pending_tokens() for r in grp.replicas]
    queued = [r.queue_depth + r.active_count for r in grp.replicas]
    st = grp.stats()
    merged_ok = (
        st["dp"] == 4 and len(st["per_replica"]) == 4
        and st["pending_tokens"] == sum(owed) == grp.pending_tokens()
        and st["queue_depth"] == sum(r.queue_depth for r in grp.replicas)
        and st["active"] == sum(r.active_count for r in grp.replicas)
        and abs(st["arena_utilization"]
                - sum(r.arena_utilization() for r in grp.replicas) / 4)
            < 1e-6)
    out = grp.run_to_completion(400)
    print(json.dumps({
        "owed": owed, "queued": queued, "merged_ok": merged_ok,
        "rids": rids,
        "lens": {str(r): len(out[r]) for r in rids},
    }))
    """)
    assert res["merged_ok"], "group stats disagree with per-replica sums"
    assert res["rids"] == list(range(8))            # stable group rids
    # every replica took work, and the owed spread stays below one long
    # request (least-loaded routing: nobody hoards the 32-token streams)
    assert min(res["queued"]) >= 1, res
    assert max(res["owed"]) - min(res["owed"]) < 32, res
    assert res["lens"] == {str(i): b for i, b in
                           enumerate([32, 2, 32, 2, 32, 2, 32, 2])}


def test_dp_replica_streams_bit_identical():
    """Acceptance: which replica serves a request never changes its tokens.
    dp=2 streams match the dp=1 baseline bit-exactly, and so does a run
    whose replica count is retuned mid-stream (1 -> 2 -> 4 -> 1) while
    requests are live — adoption copies cache rows exactly, never
    re-prefills."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.core.dse import DesignPoint
    from repro.models import build_model
    from repro.serve import ReplicaGroup, ServeConfig, serve_engine_rules
    from repro.workloads import DECODE

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    cfg = get_reduced("minitron-4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sc = ServeConfig(max_slots=4, max_len=64, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 12))) for _ in range(4)]

    def run(dp0, script):
        grp = ReplicaGroup(DECODE, model, params, sc,
                           sub=comp.submesh(range(4), "t"),
                           rules=serve_engine_rules())
        # tp pinned at 1: the dp axis must be the ONLY thing that varies
        grp.apply(None, DesignPoint(cus=4, tp=1, dp=dp0))
        for p in prompts:
            grp.submit(p, max_new_tokens=10)
        step = 0
        while grp.has_work:
            if step in script:
                grp.apply(None, DesignPoint(cus=4, dp=script[step]))
            grp.step()
            step += 1
            assert step < 200
        return {str(r): t for r, t in grp.results().items()}

    ref = run(1, {})
    dp2 = run(2, {})
    dyn = run(1, {3: 2, 6: 4, 9: 1})
    print(json.dumps({"n": len(ref), "dp2": dp2 == ref, "dyn": dyn == ref}))
    """)
    assert res["n"] == 4
    assert res["dp2"], "dp=2 streams diverged from the dp=1 baseline"
    assert res["dyn"], "mid-stream dp retune altered a live stream"


@pytest.mark.slow
def test_traffic_driven_autoscale_end_to_end():
    """Policy-driven fabric: a burst triggers at least one recomposition and
    every request still completes with its full token budget."""
    res = _run("""
    from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                    TenantSpec)
    from repro.serve import ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)
    srv = ComposedServer(mesh, [
        TenantSpec("a", "minitron-4b", serve=sc),
        TenantSpec("b", "minitron-4b", seed=1, serve=sc),
    ], policy=AnalyticalPolicy(), decide_every=4)
    rng = np.random.default_rng(0)
    vocab = srv.cfgs["a"].vocab_size
    for _ in range(3):
        srv.submit("a", rng.integers(1, vocab, size=8), max_new_tokens=12)
    srv.submit("b", rng.integers(1, vocab, size=8), max_new_tokens=6)
    out = srv.drain(max_steps=400)
    lens = {t: sorted(len(v) for v in d.values()) for t, d in out.items()}
    print(json.dumps({"recomps": len(srv.events), "lens": lens}))
    """)
    assert res["recomps"] >= 1
    assert res["lens"]["a"] == [12, 12, 12]
    assert res["lens"]["b"] == [6]
