"""Shared AST index for fabriclint: parse every file once, build the
function/class tables, the name-based call graph, the jit-traced set, and
the suppression-comment maps that all rules consume.

Resolution is *name-based* on purpose: the fabric's call sites are
``self.method(...)``, bare module functions, and ``ClassName.method(...)``
— a simple-name graph over those covers the hot path without needing a type
checker.  Calls through arbitrary receivers (``self._exec.get_or_build``,
``eng.step()``) are NOT edges: objects like :class:`ExecutableCache` and
:class:`Telemetry` own their internal discipline and are linted on their
own roots, not dragged into every caller's reachable set.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*fabriclint:\s*disable=([\w,\-]+|all)(?:\s*--\s*(?P<reason>.+))?")
DEPRECATED_SINCE_RE = re.compile(
    r"#\s*fabriclint:\s*deprecated-since=PR(\d+)", re.IGNORECASE)
PR_RE = re.compile(r"\bPR\s*(\d+)\b")

# method names whose call mutates the receiver (``self.X.append(...)`` is a
# mutation of attribute X for the thread-safety rule)
MUTATOR_METHODS = frozenset({
    "append", "add", "pop", "popleft", "remove", "discard", "clear",
    "update", "extend", "insert", "setdefault", "appendleft",
})


def current_pr_from_changes(changes_path: Path) -> int:
    """The deprecation rule's clock: highest PR number in CHANGES.md."""
    try:
        text = changes_path.read_text()
    except OSError:
        return 0
    nums = [int(m) for m in PR_RE.findall(text)]
    return max(nums) if nums else 0


def attr_root(node: ast.AST) -> Optional[str]:
    """Leftmost name of an attribute chain (``jax.experimental.x`` -> 'jax')."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """('jax', 'device_get') for ``jax.device_get``; None if not a pure
    Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return tuple(reversed(parts))


def snippet(node: ast.AST, limit: int = 80) -> str:
    try:
        text = ast.unparse(node)
    except Exception:           # pragma: no cover - unparse is total on 3.9+
        text = ast.dump(node)[:limit]
    text = " ".join(text.split())
    return text if len(text) <= limit else text[:limit - 1] + "…"


@dataclasses.dataclass
class Mutation:
    attr: str
    line: int
    locked: bool
    code: str


@dataclasses.dataclass
class FuncInfo:
    name: str
    qualname: str              # "Class.method" or bare function name
    cls: Optional[str]
    path: str                  # repo-relative
    node: ast.AST              # FunctionDef / AsyncFunctionDef
    calls: Set[str] = dataclasses.field(default_factory=set)
    lambda_calls: Set[str] = dataclasses.field(default_factory=set)
    mutations: List[Mutation] = dataclasses.field(default_factory=list)
    decorators: Set[str] = dataclasses.field(default_factory=set)

    @property
    def is_property(self) -> bool:
        return "property" in self.decorators


@dataclasses.dataclass
class ClassInfo:
    name: str
    path: str
    node: ast.ClassDef
    bases: List[str]
    methods: Dict[str, FuncInfo]
    class_attrs: Set[str]          # Assign/AnnAssign at class level
    init_attrs: Set[str]           # ``self.X = ...`` in __init__
    properties: Set[str]

    @property
    def is_protocol(self) -> bool:
        return "Protocol" in self.bases


class _FuncScanner:
    """One pass over a function body: call edges (self.X / bare / Class.X),
    lambda-scoped call names, ``self.X`` mutations with lock-scope tracking,
    and ``jax.jit`` references (jit-traced function names)."""

    def __init__(self, info: FuncInfo, jitted: Set[str],
                 submit_seeds: Set[str]):
        self.info = info
        self.jitted = jitted
        self.submit_seeds = submit_seeds

    def scan(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt, lock_depth=0, lambda_depth=0)

    # -- statements ----------------------------------------------------
    def _stmt(self, node: ast.AST, lock_depth: int, lambda_depth: int) -> None:
        if isinstance(node, ast.With):
            held = any(self._is_lock(item.context_expr)
                       for item in node.items)
            for item in node.items:
                self._expr(item.context_expr, lock_depth, lambda_depth)
            depth = lock_depth + (1 if held else 0)
            for child in node.body:
                self._stmt(child, depth, lambda_depth)
            return
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            self._assignment(node, lock_depth, lambda_depth)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs (closures like _counted's run) belong to the
            # enclosing method: same self, same lock discipline
            for child in node.body:
                self._stmt(child, lock_depth, lambda_depth)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._stmt(child, lock_depth, lambda_depth)
            elif isinstance(child, ast.expr):
                self._expr(child, lock_depth, lambda_depth)

    def _assignment(self, node: ast.AST, lock_depth: int,
                    lambda_depth: int) -> None:
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for tgt in targets:
            leaves = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                else [tgt]
            for leaf in leaves:
                attr = self._self_attr(leaf)
                if attr is not None:
                    self.info.mutations.append(Mutation(
                        attr=attr, line=leaf.lineno,
                        locked=lock_depth > 0, code=snippet(node)))

    @staticmethod
    def _self_attr(node: ast.AST) -> Optional[str]:
        """'X' for ``self.X`` / ``self.X[...]`` assignment targets."""
        if isinstance(node, ast.Subscript):
            node = node.value
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        return None

    @staticmethod
    def _is_lock(ctx: ast.AST) -> bool:
        """``with self._lock:`` / ``with self._builds_lock:`` — any context
        manager whose source mentions a lock."""
        return "lock" in snippet(ctx).lower()

    # -- expressions ---------------------------------------------------
    def _expr(self, node: ast.AST, lock_depth: int, lambda_depth: int) -> None:
        if isinstance(node, ast.Lambda):
            self._expr(node.body, lock_depth, lambda_depth + 1)
            return
        if isinstance(node, ast.Call):
            self._call(node, lock_depth, lambda_depth)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._expr(child, lock_depth, lambda_depth)

    def _call(self, node: ast.Call, lock_depth: int,
              lambda_depth: int) -> None:
        chain = attr_chain(node.func)
        name: Optional[str] = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif chain is not None and len(chain) == 2 \
                and chain[0] in ("self", "cls"):
            name = chain[1]
        elif chain is not None and len(chain) == 2 and chain[0][:1].isupper():
            name = chain[1]                    # ClassName.method(...)
        if name is not None:
            bucket = (self.info.lambda_calls if lambda_depth > 0
                      else self.info.calls)
            bucket.add(name)

        # jax.jit(self._fn): _fn runs traced, not host-side.  Only
        # attribute refs are recorded — a bare local name (the ``step``
        # closure inside ``_build_decode``) would shadow same-named methods
        # (every engine's ``step``!), and builder-local closures are already
        # excluded with their enclosing builder.
        if chain is not None and chain[-1] == "jit" and chain[0] == "jax":
            for arg in node.args[:1]:
                ref = attr_chain(arg)
                if ref is not None and isinstance(arg, ast.Attribute):
                    self.jitted.add(ref[-1])

        # pool.submit(fn, ...) / Thread(target=fn): fn runs on a background
        # thread — its call names seed the thread-safety rule's BG roots
        is_submit = (isinstance(node.func, ast.Attribute)
                     and node.func.attr == "submit")
        is_thread = chain is not None and chain[-1] == "Thread"
        if is_submit:
            for arg in node.args[:1]:
                self._seed_background(arg)
        if is_thread:
            for kw in node.keywords:
                if kw.arg == "target":
                    self._seed_background(kw.value)

        # mutating method call on a self attribute: self.X.append(...)
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in MUTATOR_METHODS:
            attr = self._self_attr(node.func.value)
            if attr is not None:
                self.info.mutations.append(Mutation(
                    attr=attr, line=node.lineno,
                    locked=lock_depth > 0, code=snippet(node)))

    def _seed_background(self, arg: ast.AST) -> None:
        if isinstance(arg, ast.Lambda):
            for sub in ast.walk(arg.body):
                if isinstance(sub, ast.Call):
                    if isinstance(sub.func, ast.Name):
                        self.submit_seeds.add(sub.func.id)
                    elif isinstance(sub.func, ast.Attribute):
                        self.submit_seeds.add(sub.func.attr)
            return
        ref = attr_chain(arg)
        if ref is not None:
            self.submit_seeds.add(ref[-1])


class Index:
    """The parsed repo: files, functions by simple name, classes by simple
    name, the jit-traced name set, background-thread seeds, and suppression
    comments."""

    def __init__(self, repo_root: Optional[Path] = None):
        self.repo_root = repo_root or Path.cwd()
        self.files: Dict[str, str] = {}
        self.functions: Dict[str, List[FuncInfo]] = {}
        self.classes: Dict[str, List[ClassInfo]] = {}
        self.jitted: Set[str] = set()
        self.submit_seeds: Set[str] = set()
        # path -> line -> (rules or {'all'}, reason)
        self.suppressions: Dict[str, Dict[int, Tuple[Set[str], str]]] = {}
        # path -> line -> PR number of a deprecated-since annotation
        self.deprecated_since: Dict[str, Dict[int, int]] = {}

    # -- construction --------------------------------------------------
    def add_path(self, path: Path) -> None:
        if path.is_dir():
            for py in sorted(path.rglob("*.py")):
                if "__pycache__" not in py.parts:
                    self.add_file(py)
        else:
            self.add_file(path)

    def add_file(self, path: Path) -> None:
        source = path.read_text()
        try:
            rel = str(path.resolve().relative_to(self.repo_root.resolve()))
        except ValueError:
            rel = str(path)
        self.add_source(rel, source)

    def add_source(self, rel: str, source: str) -> None:
        """Index one file from source text (tests feed fixture snippets
        through here without touching disk)."""
        tree = ast.parse(source)
        self.files[rel] = source
        self._scan_comments(rel, source)
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_function(node, rel, cls=None)
            elif isinstance(node, ast.ClassDef):
                self._add_class(node, rel)

    def _scan_comments(self, rel: str, source: str) -> None:
        for lineno, line in enumerate(source.splitlines(), start=1):
            m = SUPPRESS_RE.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",")}
                reason = (m.group("reason") or "").strip()
                self.suppressions.setdefault(rel, {})[lineno] = (rules, reason)
            d = DEPRECATED_SINCE_RE.search(line)
            if d:
                self.deprecated_since.setdefault(rel, {})[lineno] = \
                    int(d.group(1))

    def _add_function(self, node, rel: str, cls: Optional[str]) -> FuncInfo:
        qual = f"{cls}.{node.name}" if cls else node.name
        info = FuncInfo(name=node.name, qualname=qual, cls=cls, path=rel,
                        node=node)
        for dec in node.decorator_list:
            ref = attr_chain(dec.func if isinstance(dec, ast.Call) else dec)
            if ref is not None:
                info.decorators.add(ref[-1])
        _FuncScanner(info, self.jitted, self.submit_seeds).scan(node.body)
        self.functions.setdefault(node.name, []).append(info)
        return info

    def _add_class(self, node: ast.ClassDef, rel: str) -> None:
        bases = [b for b in (attr_chain(base) for base in node.bases)
                 if b is not None]
        info = ClassInfo(
            name=node.name, path=rel, node=node,
            bases=[b[-1] for b in bases],
            methods={}, class_attrs=set(), init_attrs=set(),
            properties=set())
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = self._add_function(item, rel, cls=node.name)
                info.methods[item.name] = fn
                if fn.is_property:
                    info.properties.add(item.name)
            elif isinstance(item, ast.AnnAssign) \
                    and isinstance(item.target, ast.Name):
                info.class_attrs.add(item.target.id)
            elif isinstance(item, ast.Assign):
                for tgt in item.targets:
                    if isinstance(tgt, ast.Name):
                        info.class_attrs.add(tgt.id)
        init = info.methods.get("__init__")
        if init is not None:
            info.init_attrs = {m.attr for m in init.mutations}
        self.classes.setdefault(node.name, []).append(info)

    # -- queries --------------------------------------------------------
    def mro_chain(self, cls: ClassInfo) -> List[ClassInfo]:
        """``cls`` plus transitive bases resolvable within the scanned file
        set, in method-resolution order (first match wins)."""
        chain: List[ClassInfo] = []
        seen: Set[str] = set()

        def visit(c: ClassInfo) -> None:
            if c.name in seen:
                return
            seen.add(c.name)
            chain.append(c)
            for base in c.bases:
                for candidate in self.classes.get(base, []):
                    visit(candidate)
        visit(cls)
        return chain

    def resolve_method(self, cls: ClassInfo, name: str) -> Optional[FuncInfo]:
        for c in self.mro_chain(cls):
            if name in c.methods:
                return c.methods[name]
        return None

    def reachable(self, roots: Iterable[str], *, include_lambda: bool = False,
                  boundary: frozenset = frozenset(),
                  skip_builders: bool = False) -> Set[str]:
        """Simple names reachable from ``roots`` over the call graph.
        jit-traced functions never traverse (their bodies run staged, not
        host-side); ``boundary`` names and (optionally) ``_build_*``
        compile-time builders stop traversal."""
        seen: Set[str] = set()
        frontier = [r for r in roots]
        while frontier:
            name = frontier.pop()
            if name in seen or name in boundary or name in self.jitted:
                continue
            if skip_builders and name.startswith("_build"):
                continue
            seen.add(name)
            for info in self.functions.get(name, []):
                nxt = set(info.calls)
                if include_lambda:
                    nxt |= info.lambda_calls
                frontier.extend(n for n in nxt if n not in seen)
        return seen

    def suppressed(self, finding) -> bool:
        """Inline ``# fabriclint: disable=<rule>`` on the finding's line or
        the line directly above."""
        per_file = self.suppressions.get(finding.path, {})
        for line in (finding.line, finding.line - 1):
            entry = per_file.get(line)
            if entry and (finding.rule in entry[0] or "all" in entry[0]):
                return True
        return False

    def deprecated_since_for(self, path: str, start: int,
                             end: int) -> Optional[int]:
        """PR number of a ``deprecated-since`` annotation in [start, end]."""
        per_file = self.deprecated_since.get(path, {})
        hits = [pr for ln, pr in per_file.items() if start <= ln <= end]
        return max(hits) if hits else None
