"""Blockwise (flash) attention Pallas kernel for TPU.

Causal attention with optional sliding window.  The grid iterates
(batch*heads, q_blocks, kv_blocks) with running (m, l, acc) state in VMEM
scratch; blocks strictly above the causal diagonal (or outside the sliding
window) are *skipped* via ``pl.when`` — the kernel-level version of the
triangular pair-scan used by the portable jnp path.

Layout: q, k, v are (BH, S, D) with the head dim folded into batch (the
ops.py wrapper handles GQA expansion and reshaping).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq, bk, nkv, causal, window, scale):
    _, qi, kj = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # live block predicate: causal diagonal / sliding window
    q_lo = qi * bq
    k_lo = kj * bk
    live = jnp.asarray(True)
    if causal:
        live = live & (k_lo <= q_lo + bq - 1)
    if window:
        live = live & (k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.asarray(True)
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[...],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nkv - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 256, bk: int = 256, interpret: bool = False):
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    BH, S, D = q.shape
    assert k.shape == v.shape == (BH, S, D)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (BH, S // bq, S // bk)
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nkv=grid[2],
                               causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running sum
            pltpu.VMEM((bq, D), jnp.float32),      # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
