"""Workload-class subsystem: heterogeneous tenant engines for the composed
serving fabric (transformer decode / SSM recurrent decode / encoder
embedding), behind one :class:`Engine` protocol.  See ``base.py`` for the
workload taxonomy and ``repro.serve.fabric`` for the fabric that mixes them.
"""
from repro.workloads.base import (DECODE, ENCODER, SSM, WORKLOAD_CLASSES,
                                  Engine, build_engine, workload_class_of)
from repro.workloads.compile_cache import ExecutableCache
from repro.workloads.decode import DecodeEngine, Request, ServeConfig
from repro.workloads.encoder import EncodeJob, EncoderEngine
from repro.workloads.ssm import SSMEngine

__all__ = [
    "DECODE", "ENCODER", "SSM", "WORKLOAD_CLASSES",
    "Engine", "build_engine", "workload_class_of",
    "DecodeEngine", "Request", "ServeConfig",
    "EncodeJob", "EncoderEngine",
    "ExecutableCache",
    "SSMEngine",
]
