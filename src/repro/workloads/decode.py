"""Transformer decode engine: batched serving with continuous batching and a
FlexArena-backed slot allocator (the PR-1/2 ``ServeEngine``, now one workload
class among several — see ``repro.workloads.base``).

The FILCO connection: serving-time KV/workspace memory is exactly the
diverse-workload storage problem the FMU solves — requests of wildly
different prompt lengths share one flat arena through runtime views instead
of per-request padded buffers.  The engine tracks per-request views in a
host-side FlexArena whose capacity mirrors the device cache pool, so
admission control (can this prompt fit?) is the paper's Fig. 5(b) check.

Decode state on device is a fixed pool of batch slots (functional pytree);
prefill fills a slot, decode steps advance all live slots in lock-step
(continuous batching: slots join/leave between steps).

Three properties make the engine a real-time-recomposable accelerator
(paper §1/§2.1) rather than just a batcher:

* **Tensor parallelism per composition.**  Given ``rules`` (normally
  ``serve_rules()``), params and the pooled KV cache shard over the
  sub-mesh's model axis — more CUs mean less per-device work, so the
  recomposition policy's predicted gains are measured gains.  Leaves whose
  dims don't divide the mesh fall back to replication per-leaf (never an
  error).  ``reshard_to`` is then a sharded→sharded ``device_put``.
* **AOT-warmable executables.**  Decode and prefill run from explicitly
  managed compiled executables keyed by (config fingerprint, mesh
  fingerprint, shapes), so the fabric can pre-compile a candidate
  composition before committing a switch (``warm_compile``) and the
  post-move step skips the XLA recompile stall.  The cache may be shared
  fabric-wide: same-config tenants then reuse each other's programs.
* **Pipelined decode dispatch.**  When termination is length-based
  (``eos_id < 0``), step *k*'s decode is dispatched from device-resident
  step *k-1* tokens before the host reads them, so per-step host
  bookkeeping overlaps device execution instead of serializing on
  ``device_get``.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.arena import (AllocationError, FlexArena, PagedArena,
                              ROLE_ACT)
from repro.core.composer import mesh_fingerprint
from repro.core.dse import DesignPoint
from repro.distribution import partitioning as part
from repro.models.model import Model
from repro.obs import Telemetry
from repro.workloads.base import (DecayedLengthEstimator, EngineTelemetry,
                                  sanitize_check, sanitize_guard)
from repro.workloads.compile_cache import ExecutableCache

PyTree = Any

# Ragged decode programs are specialized on a static KV upper bound (the max
# live per-row length, rounded up).  Rounding to this block keeps the number
# of distinct decode executables per config at most max_len / KV_BOUND_BLOCK.
KV_BOUND_BLOCK = 32


def _env_use_kernels() -> bool:
    """Default for ``ServeConfig.use_kernels``: on unless REPRO_USE_KERNELS
    is set to an off value (escape hatch for A/B runs and the kernel-off
    benchmark leg)."""
    return os.environ.get("REPRO_USE_KERNELS", "1").lower() not in (
        "0", "false", "off")


def _env_paged_kv() -> bool:
    """Default for ``ServeConfig.paged_kv``: on unless REPRO_PAGED_KV is set
    to an off value (escape hatch for the slot-granular baseline leg of the
    SLO-attainment benchmark)."""
    return os.environ.get("REPRO_PAGED_KV", "1").lower() not in (
        "0", "false", "off")


def _round_block(n: int) -> int:
    return -(-max(n, 1) // KV_BOUND_BLOCK) * KV_BOUND_BLOCK


def _mesh_of(sub) -> Optional[Mesh]:
    """Accept a Mesh, a composer SubAccelerator, or None."""
    if sub is None or isinstance(sub, Mesh):
        return sub
    return sub.mesh


def _rules_fp(rules: Optional[part.ShardingRules]):
    """Hashable identity of a rule set for executable-cache keys: two
    same-config engines under different rules (replicated vs TP) lower
    different programs and must never share a compiled executable."""
    if rules is None:
        return None
    return tuple(sorted(rules.rules.items()))


@dataclasses.dataclass
class Request:
    """One submitted request's host-side lifecycle record (``tokens`` is
    the prompt for decode/ssm engines, the source sequence — token ids or
    precomputed (S, d_model) frame embeddings — for enc-dec)."""

    rid: int
    tokens: np.ndarray                  # prompt
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    view: Any = None                    # arena view (admission accounting)
    done: bool = False
    # tokens scheduled for emission (prefill first token + dispatched decode
    # steps).  Runs ahead of len(out_tokens) by the in-flight step under
    # pipelined decode; equal to it otherwise.
    scheduled: int = 0
    # enc-dec forced decoding: target-prefix token ids prepended (after BOS)
    # to the decoder prompt; None decodes from BOS alone
    prefix: Optional[np.ndarray] = None
    # perf_counter() at submit — SLO telemetry (queue wait, TTFT).  Rides
    # the request record so a dp rebalance that adopts a queued request
    # keeps its original arrival time.  0.0 = unknown (synthetic request).
    submitted_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Per-tenant serving dimensions (they shape the compiled programs, so
    they are part of every executable-cache key)."""

    max_slots: int = 4                 # concurrent decode slots
    max_len: int = 128                 # per-slot cache capacity (tokens)
    eos_id: int = 0
    greedy: bool = True
    prefill_bucket: int = 32           # prompts padded up to this length
    # overlap decode dispatch with host bookkeeping (applies when eos_id < 0,
    # i.e. termination is length-based and known at dispatch time)
    pipeline_decode: bool = True
    # enc-dec tenants: per-slot cross-attention source-cache capacity in
    # source frames (0 -> max_len); submit()'s tokens are the SOURCE sequence
    max_src_len: int = 0
    # decoder start token for enc-dec jobs (the decoder prompt is [bos])
    bos_id: int = 1
    # sequence-length program buckets for batched encode phases
    # (EncoderEngine jobs / EncDecEngine sources): compile one program per
    # bucket, run each job in the smallest fitting one.  () = capacity only.
    len_buckets: Tuple[int, ...] = ()
    # structural ceiling one engine's step program may batch to: apply()
    # clamps slot resizes here no matter the grant width.  Past this point
    # a grant only buys throughput via data-parallel replicas (the
    # ReplicaGroup dp axis), not a wider batch.
    slot_cap: int = 64
    # ragged Pallas decode kernels on the hot path: decode attention reads
    # only the live KV prefix (per-row true lengths, empty slots skipped)
    # instead of the padded max_len cache, and SSM steps run the fused
    # single-step scan.  Token streams are bit-identical either way (pinned
    # by tests/test_ragged_decode.py).  Default on; REPRO_USE_KERNELS=0
    # flips the default for A/B benchmarking without code changes.  Part of
    # every executable-cache key (the lowered decode program differs).
    use_kernels: bool = dataclasses.field(default_factory=_env_use_kernels)
    # paged KV admission arena: fixed-size pages over the FlexArena
    # substrate.  Admission reserves only the pages covering the prompt and
    # caches grow page-at-a-time, instead of pinning len(prompt)+max_new
    # rows for the request's whole lifetime.  kv_arena_frac scales the
    # arena budget against the per-slot worst case for BOTH arena kinds
    # (paged and slot-granular run at the same HBM budget, so benchmark
    # arms compare fairly); under paging, page exhaustion during growth
    # preempts the largest-remaining request (device state saved
    # host-side, resumed bit-identically once pages free).  Host-side
    # accounting only — compiled programs are unaffected, so none of
    # these is part of the executable-cache key.
    paged_kv: bool = dataclasses.field(default_factory=_env_paged_kv)
    kv_page_rows: int = 16             # rows (tokens) per page
    kv_arena_frac: float = 1.0         # arena budget / dense worst case


@dataclasses.dataclass
class _Inflight:
    """One dispatched decode step whose tokens the host hasn't read yet."""

    nxt: Any                            # device (B,) int32
    entries: List[Tuple[int, Request, bool]]   # (slot, request, finishing)
    pipelined: bool


class DecodeEngine(EngineTelemetry):
    """Batched transformer decode on a composed sub-accelerator (the
    ``decode`` workload class) — continuous batching over a pooled slot
    cache, FlexArena admission control, tensor parallelism per composition,
    AOT-warmable executables and pipelined decode dispatch (see the module
    docstring; the Engine-protocol contract is docs/workloads.md)."""

    workload_class = "decode"

    def __init__(self, model: Model, params: PyTree, cfg: ServeConfig,
                 mesh=None, rules: Optional[part.ShardingRules] = None,
                 exec_cache: Optional[ExecutableCache] = None,
                 obs: Optional[Telemetry] = None):
        self.model = model
        self.cfg = cfg
        # telemetry handle: histograms/spans for this engine's hot path.
        # Always present (a private registry when the fabric didn't pass
        # one) so instrumentation below never branches on None; recording
        # is a no-op when the handle is disabled.
        self._obs = obs if obs is not None else Telemetry()
        self.rules = rules
        self._rules_eff = rules or part.ShardingRules(rules={})
        self.reshard_count = 0
        # tensor-parallel degree over the granted sub-mesh: None = the whole
        # grant (the pre-DSE default); the serving-side DSE Stage 1 sets it
        # per design point via apply(point.tp)
        self._tp: Optional[int] = None
        self._granted = None               # last granted sub-mesh (unsliced)
        self._recent_lens = DecayedLengthEstimator()
        self._per_token_elems = self._per_token_cache_elems()
        self.arena = self._make_arena()
        self._queue: List[Request] = []
        self._active: Dict[int, Request] = {}
        # preempted requests parked host-side: (Request, exported cache
        # block) — pages/slot released, resumed by _admit when space frees
        self._parked: List[Tuple[Request, PyTree]] = []
        self.preempt_count = 0
        # finished rid -> emitted tokens; bounded so a long-running engine
        # doesn't grow host memory with every request ever served
        self._finished: Dict[int, List[int]] = {}
        self.finished_cap = 10_000
        self._next_rid = 0
        self._free_slots = list(range(cfg.max_slots))

        # sharding plans: treedef + per-leaf (shape, dtype, logical spec),
        # captured before strip() so any composed sub-mesh's shardings and
        # lowering avals can be derived without re-annotating live state
        self._param_plan = part.ShardingPlan.of(params)
        self.params = part.strip(params)
        if rules is not None and not self._param_plan.annotated:
            raise ValueError(
                "tensor-parallel serving needs annotated params: pass "
                "model.init(...) without strip() when rules are given")
        cache_ann = self._init_cache_ann(cfg.max_slots)
        self._cache_plan = part.ShardingPlan.of(cache_ann)
        self.cache = part.strip(cache_ann)
        # one reusable single-slot prefill cache: prefill is functional, so
        # the prototype is never mutated — no init_cache(1, ...) per request
        single_ann = self._init_cache_ann(1)
        self._single_plan = part.ShardingPlan.of(single_ann)
        self._single = part.strip(single_ann)
        self._slot_axes = model.cache_slot_axes(self.cache)

        # AOT executables per (kind, config fp, mesh fp, shape).  The cache
        # may be fabric-shared (same-config tenants reuse programs), so every
        # key carries this engine's config fingerprint — model config plus
        # the serve dims that shape the compiled program.
        self._exec = exec_cache if exec_cache is not None else ExecutableCache()
        self._own_builds = 0
        # the memo fills from both the serving loop and the prewarm
        # thread (warm_compile pricing candidate slot counts)
        self._plan_lock = threading.Lock()
        self._plan_memo: Dict[int, part.ShardingPlan] = {
            cfg.max_slots: self._cache_plan}
        self._cfg_key = self._config_key(cfg.max_slots)
        # seed the bucketed prompt length only for archs that actually pad
        # to it; SSM/hybrid archs prefill at exact lengths (see
        # _prefill_into_slot), and warm_compile must not burn seconds per
        # candidate composition on a program that never dispatches
        self._prefill_lens = ({self._bucketed(cfg.prefill_bucket)}
                              if model.cfg.ssm is None else set())

        self._inflight: Optional[_Inflight] = None
        self._inject: Dict[int, int] = {}   # slot -> first token since last dispatch
        self._emit_buf: List[Tuple[int, int]] = []

        self.mesh: Optional[Mesh] = None
        self.reshard_to(mesh)          # commit params+cache to the sub-mesh
        self.reshard_count = 0         # construction placement isn't a move

    # ------------------------------------------------------------------
    # admission-accounting / cache-shape hooks (overridden by the SSM
    # engine, whose per-slot state is constant-size rather than
    # length-proportional, and by the enc-dec engine, which adds the
    # per-slot cross-attention source cache)
    # ------------------------------------------------------------------
    def _init_cache_ann(self, batch: int):
        """Annotated decode-cache pytree for ``batch`` slots (pooled cache
        and the reusable single-slot prefill cache are both built here)."""
        return self.model.init_cache(batch, self.cfg.max_len)

    def _per_token_cache_elems(self) -> int:
        """Per-layer per-token KV elements (admission accounting)."""
        mc = self.model.cfg
        if mc.mla is not None:
            per_tok = mc.mla.kv_lora_rank + mc.mla.qk_rope_head_dim
        elif mc.attention_free:
            per_tok = 0
        else:
            per_tok = 2 * mc.num_kv_heads * mc.resolved_head_dim
        return max(per_tok, 1) * mc.num_layers

    def _arena_capacity(self) -> int:
        return self.cfg.max_slots * self.cfg.max_len * self._per_token_elems

    def _slot_rows(self, req: Request) -> int:
        """Arena rows a request occupies while holding a slot."""
        return len(req.tokens) + req.max_new_tokens

    def _row_cap(self) -> int:
        """Per-slot arena row capacity (mirrors the device cache rows)."""
        return self.cfg.max_len

    def _page_rows(self) -> int:
        return max(1, min(self.cfg.kv_page_rows, self._row_cap()))

    def _arena_pages(self) -> int:
        """Paged-arena page budget: the dense per-slot worst case scaled by
        ``kv_arena_frac``, floored at one slot's worth so any admissible
        request can always run alone (growth can never wedge)."""
        per_slot = -(-self._row_cap() // self._page_rows())
        frac = max(min(self.cfg.kv_arena_frac, 1.0), 0.0)
        want = int(round(frac * self.cfg.max_slots * per_slot))
        return max(want, per_slot, 1)

    def _make_arena(self, min_pages: int = 0):
        """Admission arena for the current config: paged (fixed-size pages,
        grow-at-a-time) or the PR-1 slot-granular FlexArena.  Both honor
        ``kv_arena_frac`` — the paired benchmark arms (paged vs dense)
        compare at the SAME HBM budget — floored at one slot's worst case
        so an admissible request can always run alone.  ``min_pages``
        floors the page budget when a rebuild must re-admit live tables
        (adoption bursts may briefly exceed the configured budget)."""
        if not self.cfg.paged_kv:
            frac = max(min(self.cfg.kv_arena_frac, 1.0), 0.0)
            per_slot = self._row_cap() * self._per_token_elems
            floor = min_pages * self._page_rows() * self._per_token_elems
            return FlexArena(max(int(round(frac * self._arena_capacity())),
                                 per_slot, floor, 1))
        return PagedArena(max(self._arena_pages(), min_pages),
                          self._page_rows(), self._per_token_elems)

    @property
    def _paged(self) -> bool:
        return isinstance(self.arena, PagedArena)

    def _live_rows(self, req: Request) -> int:
        """Rows a paged request's table must cover for the next dispatch:
        current KV occupancy plus the row that dispatch writes."""
        return min(self._dec_len(req) + 1, self._row_cap())

    def _arena_rows(self, req: Request) -> int:
        """Arena rows to reserve for a request entering a slot: its current
        coverage under paging, the len+budget worst case otherwise."""
        return self._live_rows(req) if self._paged else self._slot_rows(req)

    def _oversized(self, req: Request) -> bool:
        """True when the request could never fit a slot (hard reject)."""
        return self._slot_rows(req) > self.cfg.max_len

    def _config_key(self, slots: int, buckets=None) -> Tuple:
        """Shared-executable-cache config fingerprint at a (possibly
        prospective) slot count — warm_compile prices candidate design
        points before they are applied.  ``buckets`` is unused here (decode
        has no encode phase); the enc-dec engine extends the key with it."""
        del buckets
        return (self.workload_class, self.model.cfg, slots,
                self.cfg.max_len, _rules_fp(self.rules),
                self.cfg.use_kernels)

    def _plan_for_slots(self, slots: int) -> part.ShardingPlan:
        """ShardingPlan of the pooled cache at ``slots`` — abstract-eval'd
        (no device allocation), memoized; lets warm_compile lower programs
        for a candidate slot count without building the pool."""
        with self._plan_lock:
            if slots not in self._plan_memo:
                ann = jax.eval_shape(lambda: self._init_cache_ann(slots))
                self._plan_memo[slots] = part.ShardingPlan.of(ann)
            return self._plan_memo[slots]

    # ------------------------------------------------------------------
    def reshard_to(self, sub) -> None:
        """Migrate this engine — params AND live decode state — onto a new
        sub-accelerator (FILCO real-time recomposition, §1/§2.1).

        The engine is purely functional on device: everything it owns is the
        params pytree and the two cache pytrees, so growing, shrinking or
        moving its composition is one sharded→sharded device_put of each,
        with every leaf's sharding refit to the target mesh under the
        engine's rules.  Host-side state (queues, slots, arena views) is
        untouched.  Token streams are preserved across any grow/shrink/unify
        sequence: replicated engines are bit-identical, tensor-parallel ones
        greedy-decode the same tokens (the property tests/test_fabric.py
        pins across 1/2/4-way TP).
        """
        self._harvest()                 # inflight tokens live on the old mesh
        with self._obs.span("reshard"):
            self._granted = _mesh_of(sub)
            # the engine computes on the grant restricted to its TP degree
            # (the serving DSE's per-tenant design knob); None = whole grant
            mesh = part.tp_submesh(self._granted, self._tp)
            self.mesh = mesh
            # hot-path executable-cache key: recomputing the device-id tuple
            # per dispatch is a per-step O(devices) loop on a pod-scale mesh
            self._mesh_fp = mesh_fingerprint(mesh)
            if mesh is not None:
                rules = self._rules_eff
                self.params = jax.device_put(
                    self.params, self._param_plan.shardings(mesh, rules))
                self.cache = jax.device_put(
                    self.cache, self._cache_plan.shardings(mesh, rules))
                self._single = jax.device_put(
                    self._single, self._single_plan.shardings(mesh, rules))
        self.reshard_count += 1
        self._obs.inc("reshards")

    def sync(self) -> None:
        """Block until this engine's device state (params + pooled cache) is
        ready — recomposition migration timing and post-move stall probing."""
        jax.block_until_ready((self.params, self.cache))

    # ------------------------------------------------------------------
    # live design-point reconfiguration (serving DSE Stage 1's knobs)
    # ------------------------------------------------------------------
    def design(self) -> Dict[str, Any]:
        """The engine's currently applied design point (the runtime knobs
        the serving DSE optimizes): TP degree (None = whole grant), slot
        count, encode bucket ladder (None for classes without one)."""
        return {"tp": self._tp, "slots": self.cfg.max_slots, "buckets": None}

    def apply(self, sub=None,
              point: Optional[DesignPoint] = None) -> Dict[str, Any]:
        """Apply a design-point delta live — the engine-side half of the
        serving DSE's Stage-1 → fabric loop.  ``point`` carries the knobs
        (``None`` fields = keep the current setting):

        * ``sub``          — migrate onto a new sub-accelerator (reshard_to);
        * ``point.tp``     — tensor-parallel degree over the grant: params
          and pooled state reshard onto the first ``tp`` model-axis columns;
        * ``point.slots``  — resize the pooled decode cache: live slots are
          migrated (exact device-side copy) into the new pool, so pinned
          streams are bit-identical across the resize; never shrinks below
          the current occupancy (live streams are migrated, not evicted);
        * ``point.buckets`` — swap the encode-program ladder (encoder /
          enc-dec subclasses; numerics-safe because encodes are
          bucket-invariant);
        * ``point.dp``     — ignored here: replica count is a *group* knob,
          consumed by :class:`~repro.serve.fabric.ReplicaGroup` before it
          fans the per-replica point out to its engines.

        Every step re-enters the shared AOT executable cache under the new
        config/mesh fingerprint, so a preceding ``warm_compile`` with the
        same point makes the first post-apply step stall-free.  Returns the
        knobs actually applied (slot clamps included).
        """
        point = point if point is not None else DesignPoint(cus=0)
        self._harvest()                 # in-flight tokens shaped by old pool
        applied: Dict[str, Any] = {}
        if point.tp is not None and point.tp != (self._tp or 0):
            self._tp = max(int(point.tp), 1)
            applied["tp"] = self._tp
        if sub is not None or "tp" in applied:
            # commit the (new) grant under the (new) degree
            self.reshard_to(sub if sub is not None else self._granted)
        if point.slots is not None and int(point.slots) != self.cfg.max_slots:
            applied["slots"] = self._resize_slots(int(point.slots))
        b = self._apply_buckets(point.buckets)
        if b is not None:
            applied["buckets"] = b
        return applied

    def _apply_buckets(self, buckets):
        """Bucket-ladder hook: plain decode has no encode phase."""
        del buckets
        return None

    def _resize_slots(self, slots: int) -> int:
        """Resize the pooled slot cache live, migrating every live slot.

        The new pool is allocated (sharded on the current mesh), each live
        slot's cache rows are copied device-side into the lowest new slot
        ids (an exact copy — decode rows are batch-independent, so pinned
        streams stay bit-identical), and the host-side slot bookkeeping and
        admission arena are rebuilt at the new capacity.  Shrinking clamps
        at the live occupancy: streams are migrated, never evicted.
        """
        live = sorted(self._active)
        cap = max(self.cfg.slot_cap, 1)
        slots = max(min(int(slots), cap), len(live), 1)
        if slots == self.cfg.max_slots:
            return slots
        with self._obs.timed("slot_migration", "slot_migration_s",
                             src=self.cfg.max_slots, dst=slots,
                             live=len(live)):
            self._do_resize_slots(slots, live)
        return slots

    def _do_resize_slots(self, slots: int, live: List[int]) -> None:
        mapping = {old: new for new, old in enumerate(live)}
        new_ann = self._init_cache_ann(slots)
        new_plan = part.ShardingPlan.of(new_ann)
        new_cache = part.strip(new_ann)
        if self.mesh is not None:
            new_cache = jax.device_put(
                new_cache, new_plan.shardings(self.mesh, self._rules_eff))
        axes = self.model.cache_slot_axes(new_cache)
        if live:
            # one pass per leaf: gather the live slots' rows from the old
            # pool (exact copy — bit-identical streams) and write them as
            # a block into the lowest new slot ids; free slots keep their
            # freshly initialized values
            new_cache = _migrate_slots(new_cache, self.cache, live, axes)
        self.cache = new_cache
        self._cache_plan = new_plan
        self._slot_axes = axes
        self.cfg = dataclasses.replace(self.cfg, max_slots=slots)
        with self._plan_lock:
            self._plan_memo[slots] = new_plan
        self._cfg_key = self._config_key(slots)
        # host bookkeeping follows the migrated slots
        self._active = {mapping[s]: r for s, r in self._active.items()}
        for s, req in self._active.items():
            req.slot = s
        self._inject = {mapping[s]: v for s, v in self._inject.items()
                        if s in mapping}
        self._free_slots = list(range(len(live), slots))
        # admission arena mirrors the new pool capacity; live views re-admit
        # (len(live) <= slots and per-request rows <= per-slot rows; a paged
        # rebuild floors the page budget at the live tables' need, so the
        # re-allocation cannot fail)
        self._readmit_live_views()

    def _readmit_live_views(self) -> None:
        """Rebuild the admission arena and re-alloc every live request's
        view/page table at its current size."""
        pr = self._page_rows()
        need = sum(-(-self._arena_rows(r) // pr)
                   for r in self._active.values())
        arena = self._make_arena(min_pages=need)
        for req in self._active.values():
            req.view = arena.alloc(self._arena_rows(req),
                                   self._per_token_elems, ROLE_ACT)
        self.arena = arena

    # ------------------------------------------------------------------
    # cross-replica live migration (ReplicaGroup dp retune): a retiring
    # replica's requests move to a sibling engine by exact cache-row copy —
    # never by re-prefilling, whose different reduction order could flip an
    # argmax and break the bit-identical-streams contract
    # ------------------------------------------------------------------
    def _export_slot(self, slot: int) -> PyTree:
        """One slot's cache rows as a host-side block (slot dim kept at
        size 1, so the block write-back is a plain dynamic_update_slice);
        leaves without a slot axis export a scalar placeholder."""
        idx = jnp.asarray([slot], jnp.int32)

        def take(ax, leaf):
            if ax < 0:
                return np.zeros((), np.int32)
            return np.asarray(jax.device_get(jnp.take(leaf, idx, axis=ax)))

        return jax.tree.map(take, self._slot_axes, self.cache)

    def evacuate(self) -> Tuple[List[Tuple[Request, PyTree]], List[Request]]:
        """Strip this engine of ALL work so sibling replicas can adopt it
        (ReplicaGroup dp shrink).  Returns ``(live, queued)``: ``live`` is
        ``[(Request, host cache block)]`` for every active slot, ``queued``
        the unadmitted requests.  The engine is left idle; its finished
        records stay readable via ``results()``."""
        self._harvest()
        live = []
        for slot in sorted(self._active):
            req = self._active[slot]
            live.append((req, self._export_slot(slot)))
            self.arena.free_view(req.view)
        self._active.clear()
        self._inject.clear()
        self._free_slots = list(range(self.cfg.max_slots))
        # preempted requests ride along with their saved cache blocks: the
        # adopter restores them exactly like an exported live slot
        live.extend(self._parked)
        self._parked = []
        queued, self._queue = self._queue, []
        return live, queued

    def _rebuild_arena(self, extra_rows: int = 0) -> None:
        """Re-admit every live view into a fresh arena (defragmentation:
        adoption allocs land in an arena shaped by a different admission
        history than a freshly resized pool's).  ``extra_rows`` reserves
        headroom for a request about to be adopted."""
        pr = self._page_rows()
        need = sum(-(-self._arena_rows(r) // pr)
                   for r in self._active.values())
        need += -(-extra_rows // pr)
        arena = self._make_arena(min_pages=need)
        for req in self._active.values():
            req.view = arena.alloc(self._arena_rows(req),
                                   self._per_token_elems, ROLE_ACT)
        self.arena = arena

    def adopt_request(self, req: Request, block: PyTree) -> int:
        """Adopt a live request evacuated from a sibling replica: assign a
        fresh rid (engine rids are per-engine; the ReplicaGroup owns the
        stable group-level rid), write its cache block into a free slot and
        resume decoding exactly where the source replica stopped (the last
        emitted token is host-injected, as after any harvest)."""
        self._harvest()
        if not self._free_slots:
            # callers size the pool before adopting; this is the backstop
            self._resize_slots(self.cfg.max_slots + 1)
        try:
            view = self.arena.alloc(self._arena_rows(req),
                                    self._per_token_elems, ROLE_ACT)
        except AllocationError:
            self._rebuild_arena(extra_rows=self._arena_rows(req))
            view = self.arena.alloc(self._arena_rows(req),
                                    self._per_token_elems, ROLE_ACT)
        rid = self._next_rid
        self._next_rid += 1
        req.rid, req.view = rid, view
        req.slot = self._free_slots.pop(0)
        dev = jax.tree.map(lambda ax, b: b if ax < 0 else jnp.asarray(b),
                           self._slot_axes, block)
        self.cache = _write_slot(self.cache, dev, req.slot, self._slot_axes)
        if self.mesh is not None:
            # the AOT decode executable requires its exact input shardings;
            # the eager block write above may have disturbed them
            self.cache = jax.device_put(
                self.cache,
                self._cache_plan.shardings(self.mesh, self._rules_eff))
        self._active[req.slot] = req
        if req.out_tokens:
            self._inject[req.slot] = req.out_tokens[-1]
        return rid

    def adopt_queued(self, req: Request) -> int:
        """Adopt a queued (unadmitted) request from a sibling replica:
        fresh engine rid, no recent-lengths double count (the group already
        observed the submission once)."""
        rid = self._next_rid
        self._next_rid += 1
        req.rid = rid
        req.slot, req.view = -1, None
        self._queue.append(req)
        return rid

    def export_queued(self) -> List[Request]:
        """Hand back the unadmitted queue (ReplicaGroup queue rebalance on
        a dp grow); live slots stay put."""
        queued, self._queue = self._queue, []
        return queued

    # ------------------------------------------------------------------
    # preemption: park a victim's device state host-side (the dp-retune
    # export/adopt machinery turned inward), release its slot and pages,
    # resume later with a bit-identical continuation.  Triggered by page
    # exhaustion during growth (_ensure_capacity) and by the fabric's
    # SLO scheduler (preempt_one).
    # ------------------------------------------------------------------
    def _release_slot(self, slot: int, req: Request) -> None:
        """Single exit point returning a finished/preempted/rejected
        request's slot AND its arena reservation together — every path that
        gives up a slot goes through here, so slot and arena accounting can
        never diverge (arena bytes return to zero once every request
        drains; pinned by tests/test_paged_arena.py)."""
        if req.view is not None:
            self.arena.free_view(req.view)
            req.view = None
        if slot in self._active:
            del self._active[slot]
        self._inject.pop(slot, None)
        self._free_slots.append(slot)
        req.slot = -1

    def preempt_slot(self, slot: int) -> Optional[int]:
        """Preempt the request in ``slot``: harvest any in-flight step, save
        the slot's cache rows host-side, free its pages + slot, and park it
        for re-admission.  Continuation is bit-identical: the saved block is
        an exact device copy and the last emitted token is host-injected on
        resume, exactly as a dp retune's adopt_request does."""
        self._harvest()
        req = self._active.get(slot)
        if req is None:
            return None
        block = self._export_slot(slot)
        self._release_slot(slot, req)
        self._parked.append((req, block))
        self.preempt_count += 1
        self._obs.inc("preemptions")
        return req.rid

    def _victim_slot(self) -> Optional[int]:
        """Deterministic preemption victim: the active request with the most
        remaining budget (its pages stay pinned longest); newest rid breaks
        ties.  None when nothing is preemptible."""
        best = None
        for slot, req in self._active.items():
            rem = req.max_new_tokens - req.scheduled
            if rem <= 0:
                continue
            key = (rem, req.rid, slot)
            if best is None or key > best[0]:
                best = (key, slot)
        return best[1] if best is not None else None

    def preempt_one(self) -> Optional[int]:
        """SLO-scheduler entry point: preempt the policy victim.  Returns
        its rid, or None when no active request is preemptible."""
        self._harvest()
        slot = self._victim_slot()
        if slot is None:
            return None
        return self.preempt_slot(slot)

    def _ensure_capacity(self) -> None:
        """Grow each live slot's page table to cover the next dispatch.
        Page exhaustion preempts the largest-remaining victim until the
        growth fits; the arena floor (one slot's worst case) guarantees a
        lone request always fits, so this never wedges."""
        if not self._paged:
            return
        for slot in sorted(self._active):
            req = self._active.get(slot)
            if req is None or req.view is None:
                continue
            need = self._live_rows(req)
            while True:
                try:
                    self.arena.grow(req.view, need)
                    break
                except AllocationError:
                    victim = self._victim_slot()
                    if victim is None:
                        break   # everything is finishing this step
                    self.preempt_slot(victim)
                    if victim == slot:
                        break   # the grower itself was the best victim

    def _resume_parked(self) -> None:
        """Re-admit preempted requests (exact state restore) while a slot
        and their pages are available.  Runs after the queue loop in
        ``_admit``: fresh arrivals keep admission priority so an SLO-forced
        preemption cannot thrash with its own victim."""
        harvested = False
        while self._parked and self._free_slots:
            req, block = self._parked[0]
            try:
                view = self.arena.alloc(self._arena_rows(req),
                                        self._per_token_elems, ROLE_ACT)
            except AllocationError:
                break
            if not harvested:
                self._harvest()   # cache write-back wants a settled pool
                harvested = True
            self._parked.pop(0)
            req.view = view
            req.slot = self._free_slots.pop(0)
            dev = jax.tree.map(lambda ax, b: b if ax < 0 else jnp.asarray(b),
                               self._slot_axes, block)
            self.cache = _write_slot(self.cache, dev, req.slot,
                                     self._slot_axes)
            if self.mesh is not None:
                # the AOT decode executable requires its exact input
                # shardings; the eager block write may have disturbed them
                self.cache = jax.device_put(
                    self.cache,
                    self._cache_plan.shardings(self.mesh, self._rules_eff))
            self._active[req.slot] = req
            if req.out_tokens:
                self._inject[req.slot] = req.out_tokens[-1]
            self._obs.inc("preempt_resumes")

    # ------------------------------------------------------------------
    # compiled executables (build counting: EngineTelemetry)
    # ------------------------------------------------------------------
    def _vec_aval(self, mesh, dtype, shape):
        if mesh is None:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, P()))

    def _decode_fn(self, params, cache, prev_tokens, inject_vals,
                   inject_mask, live_mask, *, kv_bound=None, src_bound=None):
        # next input token per slot: host-injected (fresh prefill / sync
        # mode) or the previous step's device-resident output (pipelined)
        toks = jnp.where(inject_mask, inject_vals, prev_tokens)[:, None]
        logits, cache = self.model.decode_step(
            params, cache, toks, use_kernels=self.cfg.use_kernels,
            kv_bound=kv_bound, src_bound=src_bound, live_mask=live_mask)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(live_mask, nxt, 0)
        return nxt, cache

    # ------------------------------------------------------------------
    # ragged-kernel decode bounds: with use_kernels on, decode attention
    # reads only cache[:, :kv_bound].  The bound — max live per-row length
    # rounded up to KV_BOUND_BLOCK — is baked into the executable as a
    # static slice, so the engine lowers at most max_len/KV_BOUND_BLOCK
    # decode programs per config; retunes and dp replicas reuse them
    # stall-free through the shared ExecutableCache.
    # ------------------------------------------------------------------
    def _dec_len(self, req: Request) -> int:
        """Host-side mirror of a slot's KV occupancy for the *next*
        dispatch: attention reads ``pos + 1 = len(prompt) + scheduled``
        entries (the enc-dec engine overrides for its decoder prompt)."""
        return len(req.tokens) + req.scheduled

    def _kv_bound(self) -> int:
        longest = max((self._dec_len(r) for r in self._active.values()),
                      default=1)
        return min(_round_block(longest), self.cfg.max_len)

    def _decode_bounds(self) -> Tuple[int, ...]:
        """Static KV bounds of the decode program about to be dispatched:
        ``()`` when the padded path is active (or the arch holds no KV
        cache), ``(kv_bound,)`` for self-attention; the enc-dec engine adds
        the cross-attention source bound."""
        if not self.cfg.use_kernels or self.model.cfg.attention_free:
            return ()
        return (self._kv_bound(),)

    def _full_bounds(self) -> Tuple[int, ...]:
        """Worst-case bounds (full cache capacity), warmed alongside the
        current ones so long-running slots never hit a cold build."""
        if not self.cfg.use_kernels or self.model.cfg.attention_free:
            return ()
        return (self.cfg.max_len,)

    def _next_bounds(self) -> Tuple[int, ...]:
        """The current bounds bumped one block per axis (clamped to
        capacity) — warmed ahead so live lengths growing across the next
        block boundary dispatch a pre-built program."""
        return tuple(min(b + KV_BOUND_BLOCK, cap) for b, cap
                     in zip(self._decode_bounds(), self._full_bounds()))

    def _covering_bounds(self, bounds: Tuple[int, ...]) -> list:
        """All block-quantized bounds that dominate ``bounds`` elementwise
        (excluding itself), smallest total slack first — the fallback
        ladder when the exact bound was never warmed."""
        axes = [range(b, cap + 1, KV_BOUND_BLOCK)
                for b, cap in zip(bounds, self._full_bounds())]
        cands = sorted(itertools.product(*axes), key=lambda t: (sum(t), t))
        return [t for t in cands if t != tuple(bounds)]

    def _prefill_fn(self, params, pool_cache, single, tokens, true_len, slot):
        """Prefill one prompt into the reusable single-slot cache and write
        it into the pool at `slot` — one fused dispatch per admission."""
        logits, filled = self.model.prefill(params, {"tokens": tokens},
                                            single, true_len=true_len)
        pool = _write_slot(pool_cache, filled, slot, self._slot_axes)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return first, pool

    def _build_decode(self, mesh, slots: Optional[int] = None,
                      bounds: Tuple[int, ...] = ()):
        B = slots or self.cfg.max_slots
        plan = self._plan_for_slots(B)
        rules = self._rules_eff
        kwargs = {}
        if mesh is not None:
            kwargs["out_shardings"] = (
                NamedSharding(mesh, P()),
                plan.shardings(mesh, rules))
        # bounds bind as keywords so donate_argnums=(1,) keeps pointing at
        # the cache positional
        step = functools.partial(
            self._decode_fn, **dict(zip(("kv_bound", "src_bound"), bounds)))
        fn = jax.jit(step, donate_argnums=(1,), **kwargs)
        return fn.lower(
            self._param_plan.avals(mesh, rules),
            plan.avals(mesh, rules),
            self._vec_aval(mesh, jnp.int32, (B,)),
            self._vec_aval(mesh, jnp.int32, (B,)),
            self._vec_aval(mesh, jnp.bool_, (B,)),
            self._vec_aval(mesh, jnp.bool_, (B,)),
        ).compile()

    def _build_prefill(self, mesh, nb: int, slots: Optional[int] = None):
        plan = self._plan_for_slots(slots or self.cfg.max_slots)
        rules = self._rules_eff
        kwargs = {}
        if mesh is not None:
            kwargs["out_shardings"] = (
                NamedSharding(mesh, P()),
                plan.shardings(mesh, rules))
        fn = jax.jit(self._prefill_fn, donate_argnums=(1,), **kwargs)
        return fn.lower(
            self._param_plan.avals(mesh, rules),
            plan.avals(mesh, rules),
            self._single_plan.avals(mesh, rules),
            self._vec_aval(mesh, jnp.int32, (1, nb)),
            self._vec_aval(mesh, jnp.int32, ()),
            self._vec_aval(mesh, jnp.int32, ()),
        ).compile()

    def _decode_exec(self, mesh, bounds: Tuple[int, ...] = ()):
        key = ("decode", self._cfg_key, self._mesh_fp, bounds)
        if bounds and not self._exec.contains(key):
            # a bound whose program was never pre-built (live lengths grew
            # past the warm set between warm_compile calls): dispatch the
            # smallest WARM bound covering it — full capacity is always
            # warm — instead of compiling on the serving path; the exact
            # program arrives with the next warm_compile
            for cand in self._covering_bounds(bounds):
                ck = ("decode", self._cfg_key, self._mesh_fp, cand)
                if self._exec.contains(ck):
                    bounds, key = cand, ck
                    break
        return self._exec.get_or_build(
            key, self._counted(
                lambda: self._build_decode(mesh, bounds=bounds)))

    def _prefill_exec(self, mesh, nb: int):
        key = ("prefill", self._cfg_key, self._mesh_fp, nb)
        self._prefill_lens.add(nb)
        return self._exec.get_or_build(
            key, self._counted(lambda: self._build_prefill(mesh, nb)))

    def warm_compile(self, sub,
                     point: Optional[DesignPoint] = None) -> int:
        """Pre-compile this engine's decode + known prefill executables for
        a *candidate* sub-accelerator, without moving any state.  Called by
        the fabric before committing a recomposition (possibly from a
        background thread) so the first step on the new composition hits a
        warm executable.  ``point`` warms a candidate *design point*
        (prospective slot count / TP degree / bucket ladder — the serving
        DSE's Stage-1 knobs; ``dp`` is consumed by the ReplicaGroup, which
        warms every replica slice) rather than the engine's current
        configuration.  Returns the number of cold builds performed."""
        point = point if point is not None else DesignPoint(cus=0)
        with self._obs.timed("warm_compile", "warm_compile_s") as sp:
            mesh = part.tp_submesh(
                _mesh_of(sub), point.tp if point.tp is not None else self._tp)
            B = point.slots or self.cfg.max_slots
            key = self._config_key(B)
            fp = mesh_fingerprint(mesh)
            # warm the decode program at the bounds about to dispatch, one
            # block above them (live lengths grow between warm_compile
            # calls) AND at full cache capacity, so neither the first
            # post-switch step nor a later long slot hits a cold build on
            # the new composition
            built = 0
            for bounds in sorted({self._decode_bounds(), self._next_bounds(),
                                  self._full_bounds()}):
                built += self._exec.ensure(
                    ("decode", key, fp, bounds),
                    self._counted(lambda bounds=bounds:
                                  self._build_decode(mesh, B, bounds)))
            # snapshot: the serving thread appends new prefill lengths while
            # a background prewarm iterates
            for nb in sorted(tuple(self._prefill_lens)):
                built += self._exec.ensure(
                    ("prefill", key, fp, nb),
                    self._counted(
                        lambda nb=nb: self._build_prefill(mesh, nb, B)))
            if sp is not None:
                sp["builds"] = built
        return built

    # ------------------------------------------------------------------
    # load metrics consumed by the recomposition policy
    @property
    def queue_depth(self) -> int:
        """Requests awaiting admission (count)."""
        return len(self._queue)

    @property
    def active_count(self) -> int:
        """Live decode slots (count)."""
        return len(self._active)

    @property
    def preempted_depth(self) -> int:
        """Preempted requests parked host-side awaiting re-admission."""
        return len(self._parked)

    @property
    def has_work(self) -> bool:
        """True while the queue, slots, parked preemptions or an in-flight
        dispatch hold work."""
        return bool(self._queue or self._active or self._inflight
                    or self._parked)

    def pending_tokens(self) -> int:
        """Decode steps of work still owed: remaining tokens of active and
        parked (preempted) requests plus full budgets of queued ones."""
        owed = sum(req.max_new_tokens - req.scheduled
                   for req in self._active.values())
        owed += sum(req.max_new_tokens - req.scheduled
                    for req, _ in self._parked)
        owed += sum(req.max_new_tokens + len(req.tokens)
                    for req in self._queue)
        return max(owed, 0)

    def queue_head_wait_s(self, now: Optional[float] = None) -> float:
        """Seconds the oldest queued request has been waiting (0.0 when the
        queue is empty) — the SLO scheduler's TTFT-risk signal."""
        stamps = [r.submitted_s for r in self._queue if r.submitted_s > 0.0]
        if not stamps:
            return 0.0
        return max((now if now is not None else time.perf_counter())
                   - min(stamps), 0.0)

    def arena_utilization(self) -> float:
        """KV-arena pressure, 0..1 (admission-accounting fill fraction)."""
        return self.arena.utilization()

    def recent_lengths(self) -> Tuple[int, ...]:
        """Recently submitted prompt/source lengths, exponentially decayed
        toward the newest traffic (a weighted resample, not a flat window) —
        the observed-traffic signal the serving DSE's Stage-1 bucket-ladder
        search optimizes against."""
        return self._recent_lens.lengths()

    def stats(self) -> Dict[str, Any]:
        """Load/telemetry snapshot: queue depth (requests), live slots,
        owed decode steps, arena pressure (0..1), migrations performed,
        cold executable builds and the applied design point."""
        return {
            "workload_class": self.workload_class,
            "queue_depth": self.queue_depth,
            "active": self.active_count,
            "pending_tokens": self.pending_tokens(),
            "arena_utilization": round(self.arena_utilization(), 4),
            "preempted": self.preempted_depth,
            "preemptions": self.preempt_count,
            "reshard_count": self.reshard_count,
            "compile_builds": self.compile_builds,
            "design": self.design(),
        }

    # ------------------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int = 16) -> int:
        """Queue one request; returns its rid.  Requests never vanish:
        ones that could never fit a slot are rejected-but-recorded."""
        rid = self._next_rid
        self._next_rid += 1
        toks = np.asarray(tokens, np.int32)
        self._recent_lens.append(len(toks))
        self._queue.append(Request(rid, toks, max_new_tokens,
                                   submitted_s=time.perf_counter()))
        self._obs.inc("requests_submitted")
        return rid

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Move queued requests into free slots while the arena admits them
        (FILCO Fig. 5(b) fit check), then prefill the batch just admitted."""
        admitted: List[Request] = []
        while self._queue and self._free_slots:
            req = self._queue[0]
            if self._oversized(req):
                # rejected (would never fit a slot): still recorded, with
                # whatever was emitted (nothing) — requests never vanish
                req.done = True
                self._queue.pop(0)
                self._record_finished(req)
                continue
            try:
                view = self.arena.alloc(self._arena_rows(req),
                                        self._per_token_elems, ROLE_ACT)
            except AllocationError:
                break  # arena full: stay queued (admission control);
                # anything else (bad sizes, dtype bugs) propagates
            self._queue.pop(0)
            req.view = view
            req.slot = self._free_slots.pop(0)
            self._active[req.slot] = req
            admitted.append(req)
        if admitted:
            obs = self._obs
            if obs.enabled:
                now = time.perf_counter()
                for req in admitted:
                    if req.submitted_s > 0.0:
                        obs.observe("queue_wait_s", now - req.submitted_s)
            with obs.span("admit", n=len(admitted)):
                self._prefill_admitted(admitted)
        self._resume_parked()

    def _prefill_admitted(self, reqs: List[Request]) -> None:
        """Prefill the requests just admitted (hook: the enc-dec engine
        overrides this to share one batched source encode across them)."""
        for req in reqs:
            self._prefill_into_slot(req)

    def _bucketed(self, length: int) -> int:
        bucket = max(self.cfg.prefill_bucket, 8)
        return -(-length // bucket) * bucket

    def _prefill_into_slot(self, req: Request) -> None:
        """Prefill one request into its slot.

        Attention archs: pad to the bucket and pass true_len (garbage KV
        beyond true_len is masked by per-row cache pos and overwritten by
        subsequent decodes).  SSM/hybrid archs carry recurrent state that
        padding would corrupt, so they prefill at the exact prompt length
        (bounded recompiles: one per distinct length)."""
        L = len(req.tokens)
        nb = self._bucketed(L) if self.model.cfg.ssm is None else L
        toks = np.zeros((1, nb), np.int32)
        toks[0, :L] = req.tokens
        # the device_get of the first token is an existing sync point, so
        # the prefill span/histogram and TTFT cost no extra synchronization
        with self._obs.timed("prefill", "prefill_s", len=L):
            exe = self._prefill_exec(self.mesh, nb)
            first_dev, self.cache = exe(self.params, self.cache, self._single,
                                        toks, np.int32(L), np.int32(req.slot))
            first = int(jax.device_get(first_dev))
        req.out_tokens.append(first)
        req.scheduled = 1
        self._inject[req.slot] = first
        self._record_ttft(req)

    def _record_ttft(self, req: Request) -> None:
        """First token just landed on the host: record time-to-first-token
        against the request's original submit stamp."""
        if req.submitted_s > 0.0 and self._obs.enabled:
            self._obs.observe("ttft_s", time.perf_counter() - req.submitted_s)

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration: admit -> dispatch decode -> harvest.
        Returns [(rid, token)] newly observed on the host — under pipelined
        decode these are the *previous* dispatch's tokens (the current one
        is still on device); totals and per-request streams are identical.
        """
        with sanitize_guard():
            self._admit()
            if not self._active:
                self._harvest()
                sanitize_check(self)
                return self._drain_emitted()
            # span + histogram around the dispatch/harvest pair: the
            # harvest's device_get of the PREVIOUS dispatch is the existing
            # sync point the host-side timing rides on — no extra syncs,
            # pipelining preserved
            with self._obs.timed("decode_step", "decode_step_s"):
                self._step_dispatch()
            out = self._drain_emitted()
        sanitize_check(self)
        obs = self._obs
        if obs.enabled:
            obs.set_gauge("slot_utilization",
                          len(self._active) / max(self.cfg.max_slots, 1))
            obs.set_gauge("arena_utilization", self.arena.utilization())
        return out

    def _step_dispatch(self) -> None:
        self._ensure_capacity()
        if not self._active:
            return
        B = self.cfg.max_slots
        pipelined = self.cfg.pipeline_decode and self.cfg.eos_id < 0
        inject_vals = np.zeros((B,), np.int32)
        inject_mask = np.zeros((B,), bool)
        live = np.zeros((B,), bool)
        for slot, req in self._active.items():
            live[slot] = True
            if not pipelined:
                inject_mask[slot] = True
                inject_vals[slot] = req.out_tokens[-1]
            elif slot in self._inject:
                inject_mask[slot] = True
                inject_vals[slot] = self._inject[slot]
        prev = (self._inflight.nxt if self._inflight is not None
                else np.zeros((B,), np.int32))
        exe = self._decode_exec(self.mesh, self._decode_bounds())
        nxt, self.cache = exe(self.params, self.cache, prev,
                              inject_vals, inject_mask, live)
        self._inject.clear()

        entries = []
        for slot in list(self._active):
            req = self._active[slot]
            req.scheduled += 1
            finishing = req.scheduled >= req.max_new_tokens
            entries.append((slot, req, finishing))
            if pipelined and finishing:
                # length-based completion is known at dispatch time: release
                # the slot now so the next admit can reuse it; the token
                # value lands at harvest
                req.done = True
                self._release_slot(slot, req)

        # harvest the PREVIOUS dispatch (its compute is done or in flight):
        # host bookkeeping below overlaps the step dispatched above.  Its
        # continuing slots are fed by the dispatch just made, so their
        # tokens must NOT be re-injected next step (they'd be stale).
        self._harvest(register_inject=False)
        self._inflight = _Inflight(nxt, entries, pipelined)
        if not pipelined or not self._active:
            # sync mode consumes immediately (eos handling needs the value);
            # a draining engine flushes so callers see complete streams as
            # soon as queue+active are empty
            self._harvest()

    def _harvest(self, register_inject: bool = True) -> None:
        """Read one in-flight dispatch's tokens back to the host.

        register_inject: when harvesting with no newer dispatch outstanding
        (snapshot/results/reshard), a continuing slot's next input token is
        no longer device-resident — record it for host injection."""
        inf = self._inflight
        if inf is None:
            return
        self._inflight = None
        nxt = np.asarray(jax.device_get(inf.nxt))
        for slot, req, finishing in inf.entries:
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self._emit_buf.append((req.rid, tok))
            if inf.pipelined:
                if finishing:
                    self._record_finished(req)
                elif register_inject:
                    self._inject[slot] = tok
            elif tok == self.cfg.eos_id or \
                    len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self._release_slot(slot, req)
                self._record_finished(req)

    def _drain_emitted(self) -> List[Tuple[int, int]]:
        out, self._emit_buf = self._emit_buf, []
        if out:
            self._obs.inc("tokens_emitted", len(out))
        return out

    def _record_finished(self, req: Request) -> None:
        self._finished[req.rid] = list(req.out_tokens)
        self._evict_finished()

    def run_to_completion(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        """Step until idle (or ``max_steps``); returns ``snapshot()``."""
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()
        return self.snapshot()

    def results(self) -> Dict[int, List[int]]:
        """Completed (or rejected) requests' emitted tokens."""
        self._harvest()
        return {rid: list(toks) for rid, toks in self._finished.items()}

    def snapshot(self) -> Dict[int, List[int]]:
        """Every request seen so far -> tokens emitted (in-flight, queued
        and finished)."""
        self._harvest()
        out = {req.rid: list(req.out_tokens)
               for req in list(self._active.values()) + self._queue}
        out.update({req.rid: list(req.out_tokens)
                    for req, _ in self._parked})
        out.update({rid: list(toks) for rid, toks in self._finished.items()})
        return out


def _write_slot(pool_cache: PyTree, single_cache: PyTree, slot,
                slot_axes: PyTree) -> PyTree:
    """Copy a 1-batch cache into slot `slot` of the pooled cache.

    `slot_axes` names each leaf's slot-axis position explicitly
    (Model.cache_slot_axes): scanned stacks are (layers, slots, ...), all
    other leaves are slot-leading, -1 means no slot axis.  Positional, never
    inferred from shape mismatch — a max_slots == 1 pool updates exactly
    like any other."""
    def write(ax, pool, one):
        if ax < 0:
            return pool
        start = (0,) * ax + (slot,) + (0,) * (pool.ndim - ax - 1)
        return jax.lax.dynamic_update_slice(pool, one.astype(pool.dtype),
                                            start)

    return jax.tree.map(write, slot_axes, pool_cache, single_cache)


def _migrate_slots(dst_cache: PyTree, src_cache: PyTree,
                   src_slots: List[int], slot_axes: PyTree) -> PyTree:
    """Copy ``src_slots``' rows from ``src_cache`` into slots [0, n) of
    ``dst_cache`` (pool→pool; the pools may differ in slot count but share
    every other dim).  One gather + one block write per leaf — an exact
    device-side copy, because live slot migration during an ``apply`` slot
    resize must preserve streams bit-for-bit."""
    idx = jnp.asarray(src_slots, jnp.int32)

    def cp(ax, dst, src):
        if ax < 0:
            return dst
        block = jnp.take(src, idx, axis=ax)
        start = (0,) * dst.ndim
        return jax.lax.dynamic_update_slice(dst, block.astype(dst.dtype),
                                            start)

    return jax.tree.map(cp, slot_axes, dst_cache, src_cache)
