"""Public wrapper for the fused selective scan with CPU fallback."""
from __future__ import annotations

import jax

from repro.kernels.mamba_scan import kernel as K
from repro.kernels.mamba_scan import ref as R


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def selective_scan_fused(x, dt, b, c, a_log, d, *, bd=512, bs=128, impl="auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.mamba_scan_ref(x, dt, b, c, a_log, d)
    interpret = impl == "interpret" or not _on_tpu()
    return K.mamba_scan(x, dt, b, c, a_log, d, bd=bd, bs=bs,
                        interpret=interpret)
