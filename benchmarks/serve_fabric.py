"""Serving-fabric benchmark: traffic-driven multi-tenant recomposition.

Emits machine-readable ``BENCH_serve_fabric.json`` (per-tenant throughput,
recompositions performed, time-to-recompose) — the perf trajectory's first
datapoint for the real-time recomposition controller.

The scenario is the launcher's own ``--fabric`` traffic driver
(``repro.launch.serve.run_fabric``), run in a subprocess because it fakes 8
host devices and the device count is locked at first jax init.

Run: PYTHONPATH=src python -m benchmarks.serve_fabric
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

OUT_PATH = pathlib.Path("BENCH_serve_fabric.json")

_CMD = [sys.executable, "-m", "repro.launch.serve", "--fabric",
        "--arch", "minitron-4b", "--arch", "qwen2.5-32b",
        "--reduced", "--requests", "4", "--max-new-tokens", "12",
        "--seed", "0"]


def main() -> None:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(_CMD, capture_output=True, text=True, timeout=900,
                         env=env)
    if out.returncode != 0:
        raise RuntimeError(f"serve_fabric scenario failed:\n"
                           f"{out.stdout[-2000:]}\n{out.stderr[-4000:]}")
    stats = json.loads(out.stdout[out.stdout.index("{"):])

    wall_s = stats["wall_s"]
    recompose_s = [e["seconds"] for e in stats["events"]]
    # the honest cost of a recomposition: the migration device_put PLUS the
    # first post-move step, where the XLA recompile for the new composition
    # lands (it dominates)
    stall_s = [s for e in stats["events"]
               for s in e["post_step_seconds"].values()]
    record = {
        "bench": "serve_fabric",
        "devices": 8,
        "decode_steps": stats["decode_steps"],
        "wall_s": wall_s,
        "tokens_emitted": stats["tokens_emitted"],
        "tokens_per_s_per_tenant": {
            t: round(n / wall_s, 2)
            for t, n in stats["tokens_emitted"].items()},
        "recompositions": stats["recompositions"],
        "recompose_reasons": [e["reason"] for e in stats["events"]],
        "time_to_recompose_s": {
            "migration_each": [round(s, 4) for s in recompose_s],
            "migration_mean": round(
                sum(recompose_s) / max(len(recompose_s), 1), 4),
            "post_step_stall_each": [round(s, 4) for s in stall_s],
            "post_step_stall_max": round(max(stall_s, default=0.0), 4),
        },
    }
    OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
    for key in ("decode_steps", "recompositions", "wall_s"):
        print(f"serve_fabric,{key},{record[key]}")
    for t, tps in record["tokens_per_s_per_tenant"].items():
        print(f"serve_fabric,tokens_per_s[{t}],{tps}")
    print(f"serve_fabric,migration_mean_s,"
          f"{record['time_to_recompose_s']['migration_mean']}")
    print(f"serve_fabric,post_step_stall_max_s,"
          f"{record['time_to_recompose_s']['post_step_stall_max']}")
    print(f"# wrote {OUT_PATH.resolve()}")


if __name__ == "__main__":
    main()
