"""Ragged decode kernels on the serving hot path: bit-equality of the
kernel-backed decode programs vs the padded XLA path, the fused single-step
mamba scan, the decayed length estimator behind ``recent_lengths()``, and
the kernel-aware analytical step-cost terms.

The load-bearing invariant: ``ServeConfig.use_kernels`` must be a pure
performance knob — every engine's token stream is bit-identical with it on
or off, including across mid-stream recompositions (pinned here and in the
subprocess scenario at the bottom).
"""
import subprocess
import sys
import textwrap

import json
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.kernels.mamba_scan import (mamba_step_fused, mamba_step_kernel,
                                      mamba_step_ref)
from repro.kernels.ragged_decode import (ragged_decode_attention,
                                         ragged_decode_attention_ref,
                                         ragged_decode_kernel)
from repro.models import ssm as S
from repro.models.layers import decode_attention
from repro.serve.dse import Stage1Optimizer, TenantDesignSpace
from repro.serve.fabric import AnalyticalPolicy
from repro.workloads.base import DECODE, ENCODER, DecayedLengthEstimator

RNG = np.random.default_rng(11)


def _qkv(B, T, Hq, Hkv, D, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(B, 1, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, T, Hkv, D)), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# ragged decode attention: ref == padded decode_attention, kernel == ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,logit_cap,is_global", [
    (0, 0.0, None), (16, 0.0, None), (8, 30.0, None),
    (8, 0.0, True), (0, 50.0, None),
])
def test_ragged_ref_is_bitexact_vs_padded_path(window, logit_cap, is_global):
    """The oracle IS the padded path op-for-op: exact equality, not close."""
    B, T, Hq, Hkv, D = 5, 64, 8, 2, 16
    q, k, v = _qkv(B, T, Hq, Hkv, D)
    lens = jnp.asarray([1, 17, 64, 5, 33], jnp.int32)
    ref = ragged_decode_attention_ref(q, k, v, lens, window=window,
                                      logit_cap=logit_cap,
                                      is_global=is_global)
    padded = decode_attention(q, k, v, lens, window=window,
                              logit_cap=logit_cap, is_global=is_global)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(padded))


def test_sliced_cache_is_bitexact():
    """Foundation of the KV-bound fast path: attention over k[:, :Tc] for
    any Tc >= max(lengths) equals the full-T computation exactly."""
    B, T, Hq, Hkv, D = 4, 96, 4, 4, 8
    q, k, v = _qkv(B, T, Hq, Hkv, D)
    lens = jnp.asarray([3, 30, 11, 25], jnp.int32)
    full = ragged_decode_attention_ref(q, k, v, lens)
    for tc in (32, 64, 96):
        cut = ragged_decode_attention_ref(q, k[:, :tc], v[:, :tc], lens)
        np.testing.assert_array_equal(np.asarray(cut), np.asarray(full))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       hkv=st.sampled_from([1, 2, 4]),
       groups=st.sampled_from([1, 2, 4]),
       window=st.sampled_from([0, 8]),
       logit_cap=st.sampled_from([0.0, 30.0]))
def test_ragged_kernel_matches_ref(seed, hkv, groups, window, logit_cap):
    B, T, D = 4, 64, 16
    q, k, v = _qkv(B, T, hkv * groups, hkv, D)
    lens = jnp.asarray(np.random.default_rng(seed).integers(1, T + 1, size=B),
                       jnp.int32)
    out = ragged_decode_attention(q, k, v, lens, window=window,
                                  logit_cap=logit_cap, impl="interpret",
                                  bk=32)
    ref = ragged_decode_attention_ref(q, k, v, lens, window=window,
                                      logit_cap=logit_cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ragged_kernel_empty_slots_are_exact_zero():
    B, T, Hq, Hkv, D = 6, 64, 4, 2, 16
    q, k, v = _qkv(B, T, Hq, Hkv, D)
    lens = jnp.asarray([9, 64, 1, 200, 3, 17], jnp.int32)  # 200: dead junk
    live = jnp.asarray([1, 1, 0, 0, 1, 0], bool)
    for impl in ("ref", "interpret"):
        out = np.asarray(ragged_decode_attention(
            q, k, v, lens, live=live, impl=impl, bk=32))
        assert np.abs(out[[2, 3, 5]]).max() == 0.0
        ref = np.asarray(ragged_decode_attention_ref(q, k, v, lens))
        np.testing.assert_allclose(out[[0, 1, 4]], ref[[0, 1, 4]],
                                   rtol=2e-5, atol=2e-5)


def test_ragged_kernel_block_multiple_boundaries():
    """Lengths straddling kv-block boundaries (the DMA-skip index map)."""
    B, T, Hq, Hkv, D = 4, 128, 2, 2, 8
    q, k, v = _qkv(B, T, Hq, Hkv, D)
    lens = jnp.asarray([32, 33, 127, 128], jnp.int32)
    out = ragged_decode_kernel(q[:, 0], k, v, lens,
                               jnp.ones((B,), jnp.int32),
                               jnp.zeros((1,), jnp.int32),
                               bk=32, interpret=True)[:, None]
    ref = ragged_decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused single-step mamba scan
# ---------------------------------------------------------------------------

def _mamba_setup(B=3):
    cfg = get_reduced("falcon-mamba-7b")
    p = {k: getattr(v, "value", v)
         for k, v in S.mamba_init(jax.random.PRNGKey(3), cfg).items()}
    d_in, _, n, w = S.dims(cfg)
    x1 = jnp.asarray(RNG.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    cache = {"conv": jnp.asarray(RNG.normal(size=(B, w - 1, d_in)),
                                 jnp.float32),
             "h": jnp.asarray(RNG.normal(size=(B, d_in, n)), jnp.float32)}
    return cfg, p, x1, cache


def test_mamba_step_ref_is_bitexact_vs_inline_chain():
    cfg, p, x1, cache = _mamba_setup()
    out_i, new_i = S.mamba_step(p, cfg, x1, dict(cache))
    out_r, conv_r, h_r = mamba_step_ref(
        x1, cache["conv"], cache["h"], p["in_proj"], p["conv_w"],
        p["conv_b"], p["x_proj"], p["dt_proj"], p["dt_bias"], p["A_log"],
        p["D"], p["out_proj"])
    np.testing.assert_array_equal(np.asarray(out_r), np.asarray(out_i))
    np.testing.assert_array_equal(np.asarray(conv_r),
                                  np.asarray(new_i["conv"]))
    np.testing.assert_array_equal(np.asarray(h_r), np.asarray(new_i["h"]))


def test_mamba_step_kernel_matches_ref():
    cfg, p, x1, cache = _mamba_setup()
    args = (x1, cache["conv"], cache["h"], p["in_proj"], p["conv_w"],
            p["conv_b"], p["x_proj"], p["dt_proj"], p["dt_bias"], p["A_log"],
            p["D"], p["out_proj"])
    out_r, conv_r, h_r = mamba_step_ref(*args)
    out_k, conv_k, h_k = mamba_step_fused(*args, impl="interpret")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(conv_k), np.asarray(conv_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=3e-5, atol=3e-5)


def test_mamba_step_dead_rows_freeze_state():
    """Dead slots: zero output, conv/h untouched (kernel and ref agree)."""
    cfg, p, x1, cache = _mamba_setup()
    live = jnp.asarray([1, 0, 1], bool)
    args = (x1, cache["conv"], cache["h"], p["in_proj"], p["conv_w"],
            p["conv_b"], p["x_proj"], p["dt_proj"], p["dt_bias"], p["A_log"],
            p["D"], p["out_proj"])
    for impl in ("ref", "interpret"):
        out, conv, h = mamba_step_fused(*args, live=live, impl=impl)
        assert np.abs(np.asarray(out)[1]).max() == 0.0
        np.testing.assert_array_equal(np.asarray(conv)[1],
                                      np.asarray(cache["conv"])[1])
        np.testing.assert_array_equal(np.asarray(h)[1],
                                      np.asarray(cache["h"])[1])


# ---------------------------------------------------------------------------
# KV-bound dispatch: growth past the warm set never compiles on the
# serving path — it falls back to the smallest warm covering bound
# ---------------------------------------------------------------------------

def test_decode_exec_falls_back_to_warm_covering_bound():
    import dataclasses
    from repro.models import build_model
    from repro.distribution import strip
    from repro.workloads import DecodeEngine, ServeConfig
    cfg = dataclasses.replace(get_reduced("minitron-4b"), dtype="float32")
    model = build_model(cfg)
    params = strip(model.init(jax.random.key(0)))
    eng = DecodeEngine(model, params,
                       ServeConfig(max_slots=2, max_len=128, eos_id=-1))
    assert eng._covering_bounds((32,)) == [(64,), (96,), (128,)]
    assert eng._next_bounds() == (64,)       # idle engine: current is (32,)

    full = eng._decode_exec(eng.mesh, (128,))
    builds = eng.compile_builds
    # (96,) was never built: the dispatch must reuse the warm full-bound
    # program, not compile inline
    assert eng._decode_exec(eng.mesh, (96,)) is full
    assert eng.compile_builds == builds


# ---------------------------------------------------------------------------
# decayed length estimator -> Stage-1 bucket choice tracks shifted traffic
# ---------------------------------------------------------------------------

def test_decayed_estimator_tracks_shift_within_bounded_observations():
    est = DecayedLengthEstimator()
    for _ in range(200):
        est.observe(12)
    assert 11.0 <= est.mean() <= 13.0
    # traffic shifts: within ~80 observations (far under the old flat-256
    # window, which would still be majority-stale) the estimate must be
    # dominated by the new regime
    for _ in range(80):
        est.observe(100)
    assert est.mean() > 90.0
    lens = est.lengths()
    assert lens and sum(1 for L in lens if L == 100) > 0.9 * len(lens)


def test_shifted_lengths_change_stage1_bucket_choice():
    pol = AnalyticalPolicy()
    cfg = get_reduced("minitron-4b")
    space = TenantDesignSpace(wclass=ENCODER, max_len=128, base_slots=4,
                              tp_allowed=False)
    est = DecayedLengthEstimator()
    for _ in range(200):
        est.observe(12)
    before = pol.stage1.best(cfg, space, 8, 4, lengths=est.lengths())
    for _ in range(80):
        est.observe(100)
    after = pol.stage1.best(cfg, space, 8, 4, lengths=est.lengths())
    assert before.buckets != after.buckets
    assert before.buckets[0] <= 16      # fit to the short regime
    assert after.buckets[0] >= 96       # re-fit to the shifted regime


# ---------------------------------------------------------------------------
# analytical model: KV-read term and the prefill-padding tax
# ---------------------------------------------------------------------------

def test_step_cost_prices_kv_length():
    pol = AnalyticalPolicy()
    cfg = get_reduced("minitron-4b")
    free = pol.step_cost(cfg, 8, 4, DECODE)               # pre-kernel price
    short = pol.step_cost(cfg, 8, 4, DECODE, kv_len=16)
    full = pol.step_cost(cfg, 8, 4, DECODE, kv_len=512)
    assert free < short < full


def test_cost_of_kernel_mode_prices_true_lengths():
    """Short observed prompts make the kernel-mode decode step strictly
    cheaper than the padded path (which always streams max_len)."""
    pol = AnalyticalPolicy()
    cfg = get_reduced("minitron-4b")
    kw = dict(wclass=DECODE, max_len=512, base_slots=8, tp_allowed=False)
    on = TenantDesignSpace(use_kernels=True, **kw)
    off = TenantDesignSpace(use_kernels=False, **kw)
    from repro.core.dse import DesignPoint
    point = DesignPoint(cus=4, tp=4, slots=8)
    lengths = (12, 20, 16, 9) * 16
    c_on = pol.stage1.cost_of(cfg, on, 8, point, lengths)
    c_off = pol.stage1.cost_of(cfg, off, 8, point, lengths)
    assert c_on < c_off
    # no observations: never under-price an idle tenant
    assert pol.stage1.cost_of(cfg, on, 8, point, ()) == \
        pol.stage1.cost_of(cfg, off, 8, point, ())


def test_cost_of_prices_prefill_padding():
    """Decode-side prompt padding stops being free: a coarser prefill
    bucket on short prompts raises the Stage-1 price."""
    pol = AnalyticalPolicy()
    cfg = get_reduced("minitron-4b")
    kw = dict(wclass=DECODE, max_len=512, base_slots=8, tp_allowed=False)
    from repro.core.dse import DesignPoint
    point = DesignPoint(cus=4, tp=4, slots=8)
    lengths = (5, 9, 7, 12) * 16
    costs = [pol.stage1.cost_of(
        cfg, TenantDesignSpace(prefill_bucket=b, **kw), 8, point, lengths)
        for b in (0, 16, 256)]
    assert costs[0] < costs[1] < costs[2]


# ---------------------------------------------------------------------------
# engine streams: use_kernels on/off bit-identical through recomposition
# and tensor parallelism (8 fake host devices, subprocess)
# ---------------------------------------------------------------------------

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import json
import jax
import numpy as np
"""


def _run(body: str, timeout=900):
    out = subprocess.run([sys.executable, "-c",
                          _PRELUDE + textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_kernel_streams_invariant_tp_and_recomposition():
    """DecodeEngine token streams with use_kernels on == off, at tp 1 and
    2, and across a mid-stream recomposition + slot retune (the KV-bound
    program swap and the dp/tp reshard must never perturb a stream)."""
    res = _run("""
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.core.dse import DesignPoint
    from repro.models import build_model
    from repro.serve import serve_engine_rules
    from repro.workloads import DecodeEngine, ServeConfig

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh)
    cfg = dataclasses.replace(get_reduced("qwen2.5-32b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, size=L)
               for L in (5, 23, 40, 3, 17)]

    def run(tp, rules, use_kernels, script=None):
        sc = ServeConfig(max_slots=4, max_len=96, eos_id=-1,
                         prefill_bucket=16, use_kernels=use_kernels)
        eng = DecodeEngine(model, params, sc,
                           mesh=comp.submesh(range(tp), f"tp{tp}"),
                           rules=rules)
        for p in prompts:
            eng.submit(p, max_new_tokens=12)
        step = 0
        while eng.has_work:
            if script and step in script:
                eng.apply(comp.submesh(range(script[step]), "re"),
                          DesignPoint(cus=script[step]))
            eng.step()
            step += 1
            assert step < 300
        return {str(r): t for r, t in eng.results().items()}

    rules = serve_engine_rules()
    ref = run(1, None, False)                   # padded, replicated
    out = {
        "k1": run(1, None, True) == ref,        # kernels, replicated
        "k2": run(2, rules, True) == ref,       # kernels, 2-way TP
        "p2": run(2, rules, False) == ref,      # padded, 2-way TP
        # kernels + mid-stream recomposition (shrink -> grow -> back)
        "kdyn": run(2, rules, True, {3: 1, 7: 4, 11: 2}) == ref,
        "n": len(ref),
    }
    print(json.dumps(out))
    """)
    assert res["n"] == 5
    assert res["k1"] and res["k2"] and res["p2"] and res["kdyn"]
