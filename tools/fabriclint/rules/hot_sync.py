"""hot-sync: device→host syncs reachable from the serving step.

The fabric's throughput story rests on pipelined dispatch: ``step()``
enqueues device work and returns; the sync happens one step later at the
harvest point.  Any *implicit* device→host transfer on that path —
``float()`` / ``int()`` / ``bool()`` / ``.item()`` / ``np.asarray`` on a
jax value — stalls the pipeline silently (and under
``REPRO_SANITIZE=1``'s transfer guard, crashes).  This rule walks the
name-based call graph from every ``step`` method, skipping jit-traced
bodies (they run staged), compile-time ``_build_*`` builders, and the
recompose boundary (``autoscale``/``apply``/``reshard_to``/
``warm_compile``/``sync`` are event-time, not step-time).

*Explicit* syncs (``jax.device_get`` / ``jax.block_until_ready``) on the
hot path are also reported: they are sometimes the design (the TTFT
read-back, the pipelined harvest) — those carry a reason string in the
baseline, which is exactly where such judgment calls belong.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.fabriclint import Finding
from tools.fabriclint.walker import Index, attr_chain, snippet

RULE = "hot-sync"

ROOTS = frozenset({"step"})
# recompose / lifecycle entry points: reachable from step() but event-time,
# not per-step — their syncs are priced by the DSE, not the hot path
BOUNDARY = frozenset({
    "autoscale", "apply", "reshard_to", "warm_compile", "sync",
    "evacuate", "adopt_queued", "adopt_active", "export_queued",
    "run_to_completion", "drain",
})

COERCIONS = frozenset({"float", "int", "bool"})
NP_ROOTS = frozenset({"np", "numpy"})
JAX_ROOTS = frozenset({"jnp", "jax", "lax"})
# jax.* calls that RESOLVE a transfer rather than produce a device value
EXPLICIT_SYNCS = frozenset({
    ("jax", "device_get"), ("jax", "block_until_ready"),
})


def _is_jax_producer(call: ast.Call) -> bool:
    chain = attr_chain(call.func)
    if chain is None or chain[0] not in JAX_ROOTS:
        return False
    return tuple(chain[:2]) not in EXPLICIT_SYNCS and chain[-1] != "jit"


class _Taint:
    """Per-function forward pass: local names assigned from jnp/jax calls
    (or aliases of them) hold device values."""

    def __init__(self, fn: ast.AST):
        self.names: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if self._tainted_expr(node.value):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            self.names.add(tgt.id)

    def _tainted_expr(self, expr: ast.AST) -> bool:
        if isinstance(expr, ast.Call):
            return _is_jax_producer(expr)
        if isinstance(expr, ast.Name):
            return expr.id in self.names
        if isinstance(expr, ast.Subscript):
            return self._tainted_expr(expr.value)
        if isinstance(expr, ast.BinOp):
            return (self._tainted_expr(expr.left)
                    or self._tainted_expr(expr.right))
        return False

    def is_device_value(self, expr: ast.AST) -> bool:
        return self._tainted_expr(expr)


def check(index: Index, config: Dict) -> List[Finding]:
    hot = index.reachable(ROOTS, boundary=BOUNDARY, skip_builders=True)
    findings: List[Finding] = []
    for name in sorted(hot):
        for info in index.functions.get(name, []):
            if info.name in index.jitted:
                continue
            taint = _Taint(info.node)
            for node in _host_calls(info.node):
                f = _classify(node, taint, info)
                if f is not None:
                    findings.append(f)
    return findings


def _host_calls(fn: ast.AST) -> List[ast.Call]:
    """Call nodes outside nested lambdas (compile-builder thunks like
    ``_counted(lambda: self._build_decode(...))`` run at compile time)."""
    out: List[ast.Call] = []

    def walk(node: ast.AST) -> None:
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, ast.Call):
            out.append(node)
        for child in ast.iter_child_nodes(node):
            walk(child)
    walk(fn)
    return out


def _classify(node: ast.Call, taint: _Taint,
              info) -> Optional[Finding]:
    chain = attr_chain(node.func)

    if chain is not None and tuple(chain[:2]) in EXPLICIT_SYNCS:
        return Finding(
            rule=RULE, path=info.path, line=node.lineno,
            symbol=info.qualname, code=snippet(node),
            message=(f"explicit device→host sync `{chain[-1]}` on the step "
                     "hot path — baseline with a reason if deliberate"))

    if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
            and not node.args and not node.keywords:
        return Finding(
            rule=RULE, path=info.path, line=node.lineno,
            symbol=info.qualname, code=snippet(node),
            message="implicit device→host sync: `.item()` on the step "
                    "hot path (use jax.device_get at a harvest point)")

    arg = node.args[0] if node.args else None
    if arg is None:
        return None

    if isinstance(node.func, ast.Name) and node.func.id in COERCIONS \
            and taint.is_device_value(arg):
        return Finding(
            rule=RULE, path=info.path, line=node.lineno,
            symbol=info.qualname, code=snippet(node),
            message=(f"implicit device→host sync: `{node.func.id}()` of a "
                     "jax value on the step hot path"))

    if chain is not None and chain[0] in NP_ROOTS \
            and chain[-1] in ("asarray", "array") \
            and taint.is_device_value(arg):
        return Finding(
            rule=RULE, path=info.path, line=node.lineno,
            symbol=info.qualname, code=snippet(node),
            message=(f"implicit device→host sync: `{'.'.join(chain)}` of a "
                     "jax value on the step hot path"))
    return None
