"""Predicted-vs-measured accounting for design-point commitments.

Every time the serving policy (``AnalyticalPolicy`` / ``Stage1Optimizer``)
commits a design point, the fabric records the *predicted* per-unit step
cost (seconds per owed work unit, i.e. ``DesignPoint.cost``) against a
compact design key.  The steady-state serving loop then feeds *measured*
per-unit step times (host-side step wall time / tokens emitted, taken
around the existing pipelined-dispatch sync point — no extra device
syncs) into a histogram for the same ``(tenant, class, design key)``.

``summary()`` is the substrate the ROADMAP's online-calibration item
regresses against: per-entry predicted/measured ratios plus an aggregate
log-error, directly answering "how wrong is ``core/analytical.py`` and
in which direction" (PR 5 measured 1.55x predicted vs 1.11x realized —
this makes that gap a first-class metric instead of a benchmark
anecdote).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from .metrics import Histogram

__all__ = ["PredictionLedger"]


class _Entry:
    __slots__ = ("wclass", "predicted", "commits", "hist")

    def __init__(self, wclass: str = "") -> None:
        self.wclass = wclass
        self.predicted: Optional[float] = None
        self.commits = 0
        self.hist = Histogram()


class PredictionLedger:
    """Maps (tenant, design key) -> predicted unit cost + measured hist."""

    def __init__(self) -> None:
        self._entries: Dict[Tuple[str, str], _Entry] = {}

    def commit(self, tenant: str, wclass: str, key: str,
               predicted_unit_s: float) -> None:
        """Record that the policy committed ``key`` for ``tenant`` with a
        predicted per-unit step cost (seconds per token / work unit)."""
        if not (math.isfinite(predicted_unit_s) and predicted_unit_s > 0):
            return
        e = self._entries.setdefault((tenant, key), _Entry(wclass))
        e.wclass = wclass or e.wclass
        e.predicted = float(predicted_unit_s)
        e.commits += 1

    def observe(self, tenant: str, key: str, measured_unit_s: float,
                wclass: str = "") -> None:
        """Feed one measured per-unit step time for the active design."""
        e = self._entries.setdefault((tenant, key), _Entry(wclass))
        e.hist.observe(measured_unit_s)

    def __len__(self) -> int:
        return len(self._entries)

    def summary(self) -> Dict[str, object]:
        """Per-(tenant, class, design key) predicted/measured ratios.

        ``ratio`` > 1 means the analytical model over-predicts cost.  The
        aggregate reports the mean |log2 ratio| (symmetric in over/under
        prediction) over entries that have both sides.
        """
        entries = {}
        log_errs = []
        for (tenant, key), e in sorted(self._entries.items()):
            measured = e.hist.quantile(0.5) if e.hist.count else None
            ratio = None
            if e.predicted is not None and measured:
                ratio = e.predicted / measured
                log_errs.append(abs(math.log2(ratio)))
            entries[f"{tenant}|{key}"] = {
                "class": e.wclass,
                "design": key,
                "predicted_unit_s": e.predicted,
                "measured_p50_unit_s": measured,
                "measured_n": e.hist.count,
                "commits": e.commits,
                "ratio": ratio,
            }
        agg: Dict[str, object] = {"entries_with_both": len(log_errs)}
        if log_errs:
            agg["mean_abs_log2_error"] = sum(log_errs) / len(log_errs)
            agg["worst_abs_log2_error"] = max(log_errs)
        return {"entries": entries, "aggregate": agg}
