"""CLI: ``python -m tools.fabriclint src/`` — exit 0 when every finding is
baselined (with a reason) or inline-suppressed, 1 otherwise.

The CI gate runs exactly that invocation; ``--write-baseline`` seeds the
ledger from current findings (reasons default to TODO — fill them in, the
reason string is the point), ``--current-pr`` pins the deprecation clock
for red-before-removal checks, ``--rules`` narrows a run to one family.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.fabriclint import run_lint
from tools.fabriclint import baseline as baseline_mod
from tools.fabriclint.rules import ALL_RULES

DEFAULT_BASELINE = Path(__file__).parent / "baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.fabriclint",
        description="static analysis pinning the fabric's invariants "
                    "(see docs/static-analysis.md)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="accepted-findings ledger (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit")
    ap.add_argument("--current-pr", type=int, default=None,
                    help="deprecation clock override (default: highest PR "
                         "number in CHANGES.md)")
    ap.add_argument("--rules", default=None,
                    help=f"comma-separated subset of {','.join(ALL_RULES)}")
    ap.add_argument("--verbose", action="store_true",
                    help="also list baselined findings with their reasons")
    args = ap.parse_args(argv)

    rules = args.rules.split(",") if args.rules else None
    baseline_path = None if args.no_baseline or args.write_baseline \
        else args.baseline
    findings, baselined, stale = run_lint(
        args.paths, rules=rules, current_pr=args.current_pr,
        baseline_path=baseline_path)

    if args.write_baseline:
        entries = [baseline_mod.entry_for(f, "TODO: justify this entry")
                   for f in findings]
        baseline_mod.save(args.baseline, entries)
        print(f"wrote {len(entries)} entries to {args.baseline}")
        return 0

    for f in findings:
        print(f.render())
    if args.verbose:
        for f, reason in baselined:
            print(f"baselined: {f.render()}  [{reason}]")
    for entry in stale:
        print(f"stale baseline entry (fixed? delete it): "
              f"{entry['path']} {entry['symbol']} {entry['code']}")

    active = ",".join(rules) if rules else "all " + str(len(ALL_RULES))
    print(f"fabriclint: {len(findings)} finding(s), "
          f"{len(baselined)} baselined, {len(stale)} stale baseline "
          f"entr{'y' if len(stale) == 1 else 'ies'} ({active} rules)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
