import os

# Tests must see the real (single) CPU device — only the dry-run fakes 512.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
