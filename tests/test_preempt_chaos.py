"""Preemption chaos: seeded preempt–readmit–recompose interleavings over the
mixed four-class fleet must be invisible in the token streams.

The contract under test is the strongest one the paged-KV PR makes:
scheduling — page-pressure preemption, SLO preemption, parking, resume,
live recomposition — is a pure *placement* decision.  Device state is
exported exactly on preempt and re-injected on resume, and greedy decode
rows are batch-independent, so any interleaving of chaos operations yields
streams bit-identical to the undisturbed run.

Subprocess-pinned (8 host devices) like tests/test_ragged_decode.py, with
the ``use_kernels`` on/off axis: kernels are a pure performance knob and
must hold the same bit-identity under chaos.
"""
import json
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import json
import jax
import numpy as np
"""


def _run(body: str, timeout=900):
    out = subprocess.run([sys.executable, "-c",
                          _PRELUDE + textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


_CHAOS_BODY = """
from repro.launch.serve import MIXED_FLEET, _streams_digest
from repro.serve import ComposedServer, ServeConfig, TenantSpec

mesh = jax.make_mesh((1, 8), ("data", "model"))
serve = ServeConfig(max_slots=2, max_len=48, eos_id=-1, kv_page_rows=8,
                    use_kernels=__UK__)
tenants = [TenantSpec(f"{w}-{arch}", arch, reduced=True, serve=serve,
                      seed=i, workload=w)
           for i, (w, arch) in enumerate(MIXED_FLEET)]

def run(chaos_seed):
    # no policy, no warm pool: chaos drives every schedule change itself
    server = ComposedServer(mesh, tenants, policy=None, warm=False)
    rng = np.random.default_rng(5)
    for t in server.engines:
        vocab = server.cfgs[t].vocab_size
        for _ in range(3):
            server.submit(t, rng.integers(1, vocab,
                                          size=int(rng.integers(4, 16))),
                          max_new_tokens=8)
    crng = (np.random.default_rng(chaos_seed)
            if chaos_seed is not None else None)
    names = sorted(server.engines)
    steps = 0
    while any(e.has_work for e in server.engines.values()):
        if crng is not None and steps % 2 == 1:
            op = int(crng.integers(0, 3))
            if op == 0:
                # preempt: park a live stream on a random tenant
                t = names[int(crng.integers(0, len(names)))]
                server.engines[t].preempt_one()
            elif op == 1:
                # recompose: move one CU between two random tenants (the
                # evacuate/adopt path must carry parked requests along)
                sizes = server.sizes()
                i, j = crng.choice(len(names), size=2, replace=False)
                a, b = names[int(i)], names[int(j)]
                if sizes.get(a, 0) > 1 and sizes.get(b, 0) > 0:
                    sizes[a] -= 1
                    sizes[b] += 1
                    server.recompose(sizes, reason="chaos")
            # op == 2: plain step (interleaving spacer)
        server.step()
        steps += 1
        assert steps < 3000, "chaos run did not drain"
    server.drain(max_steps=300)
    stats = server.stats()
    return (_streams_digest(server.results()),
            sum(stats["preemptions"].values()),
            stats["recompositions"])

ref, _, _ = run(None)
digests, preempts, recomps = [], 0, 0
for seed in (3, 11):
    d, p, r = run(seed)
    digests.append(d)
    preempts += p
    recomps += r
print(json.dumps({"match": all(d == ref for d in digests),
                  "preempts": preempts, "recomps": recomps}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("use_kernels", [True, False])
def test_chaos_interleavings_keep_streams_bitexact(use_kernels):
    res = _run(_CHAOS_BODY.replace("__UK__", str(use_kernels)))
    # the chaos schedule must actually have exercised both operations
    assert res["preempts"] >= 1, res
    assert res["recomps"] >= 1, res
    assert res["match"], res
