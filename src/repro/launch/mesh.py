"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only the
dry-run is allowed to fake 512 host devices).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 dual-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh(shape, axes)


def sanitize_spec(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes a PartitionSpec references that this mesh lacks (the
    'pod' axis on single-pod meshes)."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in names)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """sanitize_spec + divisibility: drop sharded axes whose product does not
    evenly divide the array dim (hymba's 25 heads on a 16-wide model axis,
    batch=1 long-context cells, odd vocabularies).  Explicit NamedShardings
    must divide evenly; replication is the graceful degradation, and the
    roofline table shows its cost."""
    spec = sanitize_spec(spec, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))

    def fit(dim, entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        if not kept:
            return None
        return tuple(kept) if len(kept) > 1 else kept[0]

    return P(*(fit(d, e) for d, e in zip(shape, entries)))
