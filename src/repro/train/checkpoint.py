"""Sharded checkpointing with elastic resharding.

Format: a directory per step containing
  manifest.json — step, mesh shape/axes, flat tree structure + dtypes/shapes
  <leaf-path>.npy — one array per pytree leaf (gathered; production would
                    write per-shard slices, same manifest contract)

Restore places every leaf onto the *current* mesh with the *current* rules —
the mesh may differ from the save-time mesh (elastic scaling: N pods -> M
pods), since the manifest stores logical shapes, not device layouts.
Atomicity: written to ``<dir>.tmp`` then renamed; ``latest_step`` scans for
complete checkpoints only.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append((_SEP.join(keys), leaf))
    return out


def save(ckpt_dir: str, step: int, tree: PyTree,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Write checkpoint atomically. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace(_SEP, "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree, *,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore into the structure of `like`; optionally place each leaf with
    the given shardings pytree (elastic: any mesh, any rules)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _flatten_with_paths(like)]
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")
    leaves = []
    for name in names:
        meta = manifest["leaves"][name]
        arr = np.load(os.path.join(final, meta["file"]))
        leaves.append(arr)
    treedef = jax.tree.structure(like)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest["extra"]
