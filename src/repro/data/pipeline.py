"""Deterministic, resumable synthetic data pipeline.

Fault-tolerance contract: the stream is a pure function of (seed, step,
host), so a restart at step k reproduces the exact remaining stream on any
host layout — no data-loader state to checkpoint beyond the step counter.
This is the property elastic restarts rely on (repro.train.checkpoint).

The generator synthesizes packed LM documents: zipf-ish token ids with EOS
boundaries, plus frame embeddings for the enc-dec (audio-frontend stub).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig

EOS = 0


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 128


class SyntheticLM:
    """Host-sharded deterministic token stream."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0,
                 num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.host_batch = cfg.global_batch // num_hosts

    def _rng(self, step: int, row: int) -> np.random.Generator:
        # counter-based: independent of visitation order
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, row]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S, V = self.host_batch, self.cfg.seq_len, self.cfg.vocab_size
        tokens = np.empty((B, S + 1), np.int32)
        for r in range(B):
            grow = self.host_id * self.host_batch + r
            rng = self._rng(step, grow)
            # packed documents with EOS separators
            pos = 0
            while pos < S + 1:
                dlen = int(rng.geometric(1.0 / self.cfg.mean_doc_len))
                dlen = min(max(dlen, 2), S + 1 - pos)
                # zipf-ish ids in [1, V)
                z = rng.zipf(1.3, size=dlen - 1)
                tokens[r, pos: pos + dlen - 1] = np.clip(z, 1, V - 1)
                pos += dlen - 1
                if pos < S + 1:
                    tokens[r, pos] = EOS
                    pos += 1
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:].copy()}

    def batch_with_frames(self, step: int, d_model: int) -> Dict[str, np.ndarray]:
        out = self.batch(step)
        B, S = out["tokens"].shape
        rng = self._rng(step, 1 << 20)
        out["frames"] = rng.standard_normal((B, S, d_model)).astype(np.float32)
        return out


def make_pipeline(model_cfg: ModelConfig, seq_len: int, global_batch: int,
                  seed: int = 0, host_id: int = 0,
                  num_hosts: int = 1) -> SyntheticLM:
    return SyntheticLM(
        DataConfig(model_cfg.vocab_size, seq_len, global_batch, seed),
        host_id=host_id, num_hosts=num_hosts)
