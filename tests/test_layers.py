"""Layer-level unit tests: attention variants vs naive reference, chunked
xent vs direct, selective scan vs naive recurrence, RoPE/norm properties."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T

RNG = np.random.default_rng(3)


def naive_attention(q, k, v, *, causal, window=0, q_offset=0):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    kx = jnp.repeat(k, Hq // Hkv, axis=2)
    vx = jnp.repeat(v, Hq // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vx.astype(jnp.float32)
                      ).astype(q.dtype)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 24)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
def test_blockwise_attention_vs_naive(causal, window, hq, hkv):
    B, Sq, D = 2, 64, 16
    q = jnp.asarray(RNG.normal(size=(B, Sq, hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Sq, hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Sq, hkv, D)), jnp.float32)
    out = L.blockwise_attention(q, k, v, causal=causal, window=window,
                                block_size=16)
    ref = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [0, 32])
def test_triangular_equals_blockwise(window):
    B, Sq, H, D = 2, 128, 4, 16
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Sq, 2, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Sq, 2, D)), jnp.float32)
    a = L.blockwise_attention(q, k, v, causal=True, window=window,
                              block_size=32)
    b = L.triangular_attention(q, k, v, window=window, block_size=32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_decode_attention_per_row_lengths():
    B, T, H, D = 3, 32, 4, 16
    q = jnp.asarray(RNG.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, H, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, H, D)), jnp.float32)
    lens = jnp.asarray([5, 17, 32])
    out = L.decode_attention(q, k, v, lens)
    for r in range(B):
        l = int(lens[r])
        ref = naive_attention(q[r:r + 1], k[r:r + 1, :l], v[r:r + 1, :l],
                              causal=False)
        np.testing.assert_allclose(out[r], ref[0], rtol=1e-5, atol=1e-5)


def test_scatter_kv_per_row_positions():
    cache = jnp.zeros((3, 8, 2, 4))
    new = jnp.ones((3, 1, 2, 4)) * jnp.asarray([1., 2., 3.])[:, None, None, None]
    pos = jnp.asarray([0, 3, 7])
    out = L.scatter_kv(cache, new, pos)
    for r, p in enumerate((0, 3, 7)):
        assert float(out[r, p].sum()) == pytest.approx((r + 1) * 8.0)
        assert float(jnp.abs(out[r]).sum()) == pytest.approx((r + 1) * 8.0)


@settings(max_examples=15, deadline=None)
@given(seq=st.integers(2, 40), vocab=st.integers(8, 64),
       chunk=st.integers(2, 16))
def test_chunked_xent_matches_direct(seq, vocab, chunk):
    d = 12
    x = jnp.asarray(RNG.normal(size=(2, seq, d)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(d, vocab)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, vocab, size=(2, seq)))
    mask = jnp.asarray(RNG.integers(0, 2, size=(2, seq)), jnp.float32)
    got = T.chunked_softmax_xent(x, w, labels, mask, chunk=chunk)
    logits = x @ w
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_selective_scan_matches_naive():
    B, Sq, D, N = 2, 37, 6, 4
    dA = jnp.asarray(RNG.uniform(0.5, 0.99, size=(B, Sq, D, N)), jnp.float32)
    dBx = jnp.asarray(RNG.normal(size=(B, Sq, D, N)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, D, N)), jnp.float32)
    h_all, h_last = S.selective_scan(dA, dBx, h0, chunk=8)
    h = h0
    for t in range(Sq):
        h = dA[:, t] * h + dBx[:, t]
        np.testing.assert_allclose(h_all[:, t], h, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h_last, h, rtol=1e-4, atol=1e-5)


def test_rope_rotation_invariance():
    """RoPE preserves norms and relative-position dot products."""
    D = 32
    x = jnp.asarray(RNG.normal(size=(1, 8, 2, D)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = L.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1),
                               rtol=1e-5, atol=1e-5)
    # <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, D)), jnp.float32)
    dots = []
    for i, j in [(3, 1), (7, 5), (12, 10)]:
        qi = L.apply_rope(q, jnp.asarray([[i]]))
        kj = L.apply_rope(k, jnp.asarray([[j]]))
        dots.append(float(jnp.sum(qi * kj)))
    assert max(dots) - min(dots) < 1e-4


def test_norms():
    x = jnp.asarray(RNG.normal(size=(4, 16)) * 10, jnp.float32)
    y = L.rms_norm(x, jnp.ones(16), 1e-6)
    np.testing.assert_allclose(jnp.mean(y * y, -1), 1.0, rtol=1e-3)
    z = L.layer_norm(x, jnp.ones(16), jnp.zeros(16), 1e-6)
    np.testing.assert_allclose(jnp.mean(z, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.mean(z * z, -1), 1.0, rtol=1e-3)


def test_mamba_prefill_then_step_continuity():
    """Prefill state then one step == full forward on S+1 tokens."""
    from repro.configs import get_reduced
    cfg = get_reduced("falcon-mamba-7b")
    p = S.mamba_init(jax.random.key(0), cfg)
    from repro.distribution import strip
    p = strip(p)
    x = jnp.asarray(RNG.normal(size=(2, 9, cfg.d_model)), jnp.float32)
    full = S.mamba_fwd(p, cfg, x, chunk=4)
    cache = strip(S.mamba_cache_init(cfg, 2, jnp.float32))
    out, cache = S.mamba_prefill(p, cfg, x[:, :8], cache, chunk=4)
    np.testing.assert_allclose(out, full[:, :8], rtol=2e-3, atol=2e-3)
    step, _ = S.mamba_step(p, cfg, x[:, 8:9], cache)
    np.testing.assert_allclose(step, full[:, 8:9], rtol=2e-3, atol=2e-3)
