"""Enc-dec serving engine: full encode→decode jobs through the composed
fabric — the fourth workload class, completing FILCO's "diverse workloads on
one fabric" story (paper §1; Herald's scheduling win comes from covering
*every* class in the mix).

An enc-dec job (e.g. seamless-m4t speech-to-text) is two phases with
opposite bound resources:

* **encode** — one compute-bound bidirectional pass over the source frames
  (:meth:`Model.encode`'s encoder stack).  The engine batches the encodes of
  every request admitted in the same step and compiles the batched program
  **per source-length bucket** (``ServeConfig.len_buckets``), so short
  sources skip the padded FLOPs of the full-capacity program;
* **decode** — pooled-slot autoregressive decode on the shared
  continuous-batching substrate of :class:`DecodeEngine` (slots, pipelined
  dispatch, AOT executables, ``ShardingPlan`` TP, live ``reshard_to``),
  where each step additionally reads the slot's **cross-attention source
  cache**: per-layer (max_slots, max_src_len, kv_heads, head_dim) K/V
  computed from the encoder output once at admission and masked per row by
  the slot's true source length (``cache["src_len"]``, an int32 vector the
  model side threads through ``init_cache``/``decode_step``).

Admission accounting covers *both* caches: a request holds
``src_len + 1 + max_new_tokens`` arena rows (source frames + BOS + decode
budget — cross K/V and decoder KV have the same per-row footprint of
``2·kv_heads·head_dim`` elements per layer), so the FlexArena fit check
backpressures on source-cache pressure exactly like it does on KV pressure.

The job contract: ``submit(tokens)`` takes the SOURCE sequence (embedded as
stand-in frames — the audio frontend is a STUB per the assignment); the
decoder starts from ``ServeConfig.bos_id`` and emits ``max_new_tokens``
target tokens through the inherited ``step()``/``results()`` stream API.

Determinism note: sources are right-padded to their bucket and the
bidirectional encoder attends its own row's padding, so encoder outputs
depend (numerically, deterministically) on the bucket — a job of length L
always lands in the same bucket, so streams are reproducible and invariant
across recompositions (pinned in tests/test_workloads.py).  Cross-attention
itself never reads padded positions: prefill and decode both mask at the
true source length.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.composer import mesh_fingerprint
from repro.distribution import partitioning as part
from repro.models.model import Model
from repro.workloads.base import length_buckets, pick_bucket
from repro.workloads.compile_cache import ExecutableCache
from repro.workloads.decode import (DecodeEngine, Request, ServeConfig,
                                    _mesh_of, _write_slot)


class EncDecEngine(DecodeEngine):
    """Full encode→decode serving on enc-dec archs (the ``encdec`` workload
    class): batched bucketed source encode at admission, per-slot
    cross-attention source cache, inherited pooled-slot decode (see the
    module docstring; the Engine-protocol contract is docs/workloads.md)."""

    workload_class = "encdec"

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 mesh=None, rules: Optional[part.ShardingRules] = None,
                 exec_cache: Optional[ExecutableCache] = None):
        mc = model.cfg
        if not (mc.is_encdec and mc.cross_attention):
            raise ValueError(
                f"EncDecEngine serves encoder-decoder archs with "
                f"cross-attention; {mc.name!r} is family={mc.family!r} "
                "(use DecodeEngine/SSMEngine for decoder-only archs, or "
                "EncoderEngine for embedding-only traffic)")
        # source-cache capacity and encode-program buckets must exist before
        # super().__init__ builds the pooled/single caches through the
        # _init_cache_ann hook
        self._max_src = cfg.max_src_len or cfg.max_len
        self._src_buckets = length_buckets(cfg.len_buckets, self._max_src)
        self._bucket_hits: Dict[int, int] = {b: 0 for b in self._src_buckets}
        super().__init__(model, params, cfg, mesh=mesh, rules=rules,
                         exec_cache=exec_cache)
        # the serve dims that shape enc-dec programs extend the shared-cache
        # config fingerprint: two tenants differing only in source capacity
        # or bucket ladder must not share compiled executables
        self._cfg_key = self._cfg_key + (self._max_src, self._src_buckets)
        # the decoder prompt is always [bos]: the token-bucketed prefill
        # programs of the base engine never dispatch, so warm_compile must
        # not burn time building them per candidate composition
        self._prefill_lens = set()

    # ------------------------------------------------------------------
    # cache shapes / admission accounting (hooks from DecodeEngine)
    # ------------------------------------------------------------------
    def _init_cache_ann(self, batch: int):
        """Decoder KV pool plus per-slot cross-attention source cache
        (per-layer (batch, max_src, kv_heads, head_dim) K/V and the (batch,)
        int32 ``src_len`` mask bounds)."""
        return self.model.init_cache(batch, self.cfg.max_len,
                                     src_len=self._max_src)

    def _arena_capacity(self) -> int:
        """Arena elements mirroring the device pools: per slot, ``max_len``
        decoder-KV rows plus ``max_src`` source-cache rows (cross K/V and
        decoder KV share the 2·kv_heads·head_dim per-layer row footprint)."""
        return (self.cfg.max_slots * (self.cfg.max_len + self._max_src)
                * self._per_token_elems)

    def _slot_rows(self, req: Request) -> int:
        """Arena rows a job occupies: its source frames (cross-cache side)
        plus BOS + generation budget (decoder-KV side)."""
        return len(req.tokens) + 1 + req.max_new_tokens

    def _oversized(self, req: Request) -> bool:
        """Hard reject: source longer than the cross cache, or a generation
        budget (plus BOS) overflowing a decoder slot."""
        return (len(req.tokens) > self._max_src
                or 1 + req.max_new_tokens > self.cfg.max_len)

    # ------------------------------------------------------------------
    # compiled executables: batched bucketed encode + per-slot prefill
    # (decode is inherited — the pooled cache carries the cross state)
    # ------------------------------------------------------------------
    def _encode_fn(self, params, tokens):
        """(E, S_b) right-padded source tokens -> (E, S_b, d) encoder hidden
        states (bidirectional stack; token embeddings stand in for the
        stubbed audio frontend's precomputed frames)."""
        return self.model.encode(params, {"tokens": tokens})

    def _build_encode(self, mesh, sb: int):
        E = self.cfg.max_slots
        kwargs = {}
        if mesh is not None:
            kwargs["out_shardings"] = NamedSharding(mesh, P())
        fn = jax.jit(self._encode_fn, **kwargs)
        return fn.lower(
            self._param_plan.avals(mesh, self._rules_eff),
            self._vec_aval(mesh, jnp.int32, (E, sb)),
        ).compile()

    def _encdec_prefill_fn(self, params, pool_cache, single, enc, idx,
                           src_len, slot):
        """Write one encoded job into its slot: row ``idx`` of the batched
        encoder output becomes the slot's cross K/V (masked at ``src_len``),
        and a BOS-only decoder prefill seeds the slot's KV + first token."""
        enc_row = jax.lax.dynamic_slice_in_dim(enc, idx, 1, axis=0)
        toks = jnp.full((1, 1), self.cfg.bos_id, jnp.int32)
        logits, filled = self.model.prefill(
            params, {"tokens": toks}, single, enc_out=enc_row,
            src_len=src_len)
        pool = _write_slot(pool_cache, filled, slot, self._slot_axes)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return first, pool

    def _build_prefill_encdec(self, mesh, sb: int):
        E = self.cfg.max_slots
        rules = self._rules_eff
        kwargs = {}
        if mesh is not None:
            kwargs["out_shardings"] = (
                NamedSharding(mesh, P()),
                self._cache_plan.shardings(mesh, rules))
        fn = jax.jit(self._encdec_prefill_fn, donate_argnums=(1,), **kwargs)
        act = self.model.cfg.activation_dtype
        return fn.lower(
            self._param_plan.avals(mesh, rules),
            self._cache_plan.avals(mesh, rules),
            self._single_plan.avals(mesh, rules),
            self._vec_aval(mesh, act, (E, sb, self.model.cfg.d_model)),
            self._vec_aval(mesh, jnp.int32, ()),
            self._vec_aval(mesh, jnp.int32, ()),
            self._vec_aval(mesh, jnp.int32, ()),
        ).compile()

    def _encode_exec(self, mesh, sb: int):
        key = ("encdec_encode", self._cfg_key, self._mesh_fp, sb)
        return self._exec.get_or_build(
            key, self._counted(lambda: self._build_encode(mesh, sb)))

    def _prefill_exec_encdec(self, mesh, sb: int):
        key = ("encdec_prefill", self._cfg_key, self._mesh_fp, sb)
        return self._exec.get_or_build(
            key, self._counted(lambda: self._build_prefill_encdec(mesh, sb)))

    def warm_compile(self, sub) -> int:
        """Pre-compile decode plus every bucket's encode and prefill
        programs for a candidate sub-accelerator (no state moves).  The
        bucket ladder is static, so this fully covers the composition.
        Returns the number of cold builds performed."""
        mesh = _mesh_of(sub)
        fp = mesh_fingerprint(mesh)
        built = self._exec.ensure(
            ("decode", self._cfg_key, fp),
            self._counted(lambda: self._build_decode(mesh)))
        for sb in self._src_buckets:
            built += self._exec.ensure(
                ("encdec_encode", self._cfg_key, fp, sb),
                self._counted(lambda sb=sb: self._build_encode(mesh, sb)))
            built += self._exec.ensure(
                ("encdec_prefill", self._cfg_key, fp, sb),
                self._counted(
                    lambda sb=sb: self._build_prefill_encdec(mesh, sb)))
        return built

    # ------------------------------------------------------------------
    # admission: one batched encode per bucket group, then per-slot writes
    # ------------------------------------------------------------------
    def _prefill_admitted(self, reqs: List[Request]) -> None:
        by_bucket: Dict[int, List[Request]] = {}
        for req in reqs:
            by_bucket.setdefault(
                pick_bucket(self._src_buckets, len(req.tokens)),
                []).append(req)
        E = self.cfg.max_slots
        for sb in sorted(by_bucket):
            group = by_bucket[sb]
            for at in range(0, len(group), E):
                chunk = group[at:at + E]
                toks = np.zeros((E, sb), np.int32)
                for i, req in enumerate(chunk):
                    toks[i, :len(req.tokens)] = req.tokens
                enc = self._encode_exec(self.mesh, sb)(self.params, toks)
                exe = self._prefill_exec_encdec(self.mesh, sb)
                for i, req in enumerate(chunk):
                    self._bucket_hits[sb] += 1
                    first_dev, self.cache = exe(
                        self.params, self.cache, self._single, enc,
                        np.int32(i), np.int32(len(req.tokens)),
                        np.int32(req.slot))
                    first = int(jax.device_get(first_dev))
                    req.out_tokens.append(first)
                    req.scheduled = 1
                    self._inject[req.slot] = first

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Base decode-engine stats plus per-bucket encode-program hit
        counts (jobs served per source-length bucket)."""
        out = super().stats()
        out["bucket_hits"] = {str(b): n for b, n in self._bucket_hits.items()}
        return out
