"""Public wrapper: ragged decode attention with CPU fallback.

The serving engines call this through ``gqa_step``/``cross_step`` when
``ServeConfig.use_kernels`` is on.  Dispatch follows the package idiom:

* ``impl="auto"`` — the Pallas kernel on TPU; on CPU the pure-jnp ref,
  whose live rows are bit-identical to the padded path (the engine-side
  ragged win on CPU comes from the statically KV-bounded decode programs
  that slice the cache before calling here);
* ``impl="ref"`` — the oracle;
* ``impl="interpret"`` — the Pallas kernel in interpreter mode (CPU CI).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ragged_decode import kernel as K
from repro.kernels.ragged_decode import ref as R


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _block(T: int, bk: int) -> int:
    bk = min(bk, T)
    while T % bk:
        bk //= 2
    return max(bk, 1)


def ragged_decode_attention(q, k, v, lengths, *, window: int = 0,
                            logit_cap: float = 0.0, is_global=None,
                            live=None, impl: str = "auto", bk: int = 128):
    """q: (B, 1, Hq, D); k, v: (B, T, Hkv, D); lengths: int32 scalar or (B,)
    true KV lengths; live: optional (B,) bool empty-slot mask ->
    (B, 1, Hq, D).  Live rows are bit-identical to
    ``layers.decode_attention``; dead rows return zeros."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.ragged_decode_attention_ref(
            q, k, v, lengths, window=window, logit_cap=logit_cap,
            is_global=is_global, live=live)
    interpret = impl == "interpret" or not _on_tpu()
    B = q.shape[0]
    T = k.shape[1]
    lens = jnp.clip(jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,)),
                    1, T)
    live_i = (jnp.ones((B,), jnp.int32) if live is None
              else jnp.asarray(live).astype(jnp.int32))
    if is_global is None:
        glob = jnp.zeros((1,), jnp.int32)
    else:
        glob = jnp.reshape(jnp.asarray(is_global).astype(jnp.int32), (1,))
    out = K.ragged_decode_kernel(
        q[:, 0], k, v, lens, live_i, glob, window=window,
        logit_cap=logit_cap, bk=_block(T, bk), interpret=interpret)
    return out[:, None]
