"""Fabric telemetry (repro.obs): histogram determinism and merge algebra,
span tracing + Perfetto export, predicted-vs-measured accounting, and the
acceptance invariant — token streams bit-identical with telemetry on or
off across a live recomposition (device scenario in an 8-host-device
subprocess; device count is fixed at first jax init)."""
import importlib.util
import json
import math
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.obs import (Histogram, MetricsRegistry, PredictionLedger,
                       SpanTracer, Telemetry, bucket_bounds, metric_key)
from repro.obs.metrics import HIST_NBUCKETS

# ---------------------------------------------------------------------------
# histograms: exact stats, bucket resolution, deterministic quantiles, merge
# ---------------------------------------------------------------------------


def test_histogram_exact_stats():
    h = Histogram()
    vals = [0.004, 0.001, 0.0017, 0.25, 0.001]
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.sum == pytest.approx(sum(vals))
    assert h.min == min(vals) and h.max == max(vals)
    assert h.mean == pytest.approx(sum(vals) / len(vals))


def test_histogram_bucket_resolution_separates_benchmark_gate():
    """~9% relative bucket width must separate the ragged-kernels p50 gap
    (1.71 ms vs 1.98 ms in BENCH_serve_fabric) — the quantiles the SLO
    block reports have to resolve the differences the benchmarks gate on."""
    a, b = Histogram(), Histogram()
    for _ in range(32):
        a.observe(1.71e-3)
        b.observe(1.98e-3)
    assert a.quantile(0.5) < b.quantile(0.5)


def test_histogram_quantiles_deterministic_and_clamped():
    h1, h2 = Histogram(), Histogram()
    vals = [1e-4 * (i % 37 + 1) for i in range(500)]
    for v in vals:
        h1.observe(v)
    for v in reversed(vals):                  # insertion order must not matter
        h2.observe(v)
    for q in (0.0, 0.01, 0.5, 0.95, 0.99, 1.0):
        assert h1.quantile(q) == h2.quantile(q)
        assert h1.min <= h1.quantile(q) <= h1.max
    assert h1.quantile(1.0) == h1.max
    # clamping: a single value's every quantile IS that value
    single = Histogram()
    single.observe(0.0042)
    assert single.quantile(0.5) == 0.0042 == single.quantile(0.99)


def test_histogram_merge_equals_single_stream():
    a, b, ref = Histogram(), Histogram(), Histogram()
    for i in range(200):
        v = 1e-5 * (i + 1)
        (a if i % 2 else b).observe(v)
        ref.observe(v)
    a.merge(b)
    assert a.count == ref.count and a.sum == pytest.approx(ref.sum)
    assert a.min == ref.min and a.max == ref.max
    assert list(a.counts) == list(ref.counts)
    for q in (0.1, 0.5, 0.9, 0.99):
        assert a.quantile(q) == ref.quantile(q)


def test_histogram_out_of_range_values_clamp_to_edge_buckets():
    h = Histogram()
    h.observe(1e-12)                          # below base -> bucket 0
    h.observe(1e12)                           # beyond top -> last bucket
    assert h.count == 2
    assert h.counts[0] == 1 and h.counts[HIST_NBUCKETS - 1] == 1
    lo, hi = bucket_bounds(0)
    assert lo < hi


# ---------------------------------------------------------------------------
# registry: label keys, merge semantics, filtered merges, snapshot
# ---------------------------------------------------------------------------


def test_metric_key_renders_sorted_labelsets():
    """Label sorting happens once at handle creation (the registry's
    ``_labelset``), so kwargs order never forks a metric's identity."""
    r = MetricsRegistry()
    assert (r.counter("x", b="2", a="1")
            is r.counter("x", a="1", b="2"))
    r.counter("x", b="2", a="1").inc()
    assert r.snapshot()["counters"] == {"x{a=1,b=2}": 1}
    assert metric_key("x", ()) == "x"


def test_registry_merge_semantics():
    """Counters sum, gauges keep the max (the hottest replica), histograms
    bucket-add — the ReplicaGroup merge contract."""
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    r1.counter("toks", tenant="a").inc(3)
    r2.counter("toks", tenant="a").inc(5)
    r1.gauge("util", tenant="a").set(0.25)
    r2.gauge("util", tenant="a").set(0.75)
    r1.histogram("lat", tenant="a").observe(0.001)
    r2.histogram("lat", tenant="a").observe(0.004)
    merged = MetricsRegistry.merged([r1, r2])
    assert merged.counter("toks", tenant="a").value == 8
    assert merged.gauge("util", tenant="a").value == 0.75
    assert merged.histogram("lat", tenant="a").count == 2
    # merging must not mutate the sources
    assert r1.counter("toks", tenant="a").value == 3


def test_merged_histogram_filters_by_label_subset():
    r = MetricsRegistry()
    r.histogram("lat", tenant="a", wclass="decode").observe(0.001)
    r.histogram("lat", tenant="a", wclass="decode").observe(0.002)
    r.histogram("lat", tenant="b", wclass="ssm").observe(0.009)
    assert r.merged_histogram("lat", tenant="a").count == 2
    assert r.merged_histogram("lat").count == 3
    assert r.merged_histogram("lat", tenant="c").count == 0


def test_registry_snapshot_shape():
    r = MetricsRegistry()
    r.counter("n", t="x").inc()
    r.histogram("lat").observe(0.5)
    snap = r.snapshot()
    assert snap["counters"] == {"n{t=x}": 1}
    assert snap["histograms"]["lat"]["count"] == 1
    json.dumps(snap)                          # JSON-serializable end to end


# ---------------------------------------------------------------------------
# span tracer: nesting, ring eviction, Perfetto export schema
# ---------------------------------------------------------------------------


def test_span_nesting_and_args():
    tr = SpanTracer()
    with tr.span("outer", kind="parent"):
        with tr.span("inner") as payload:
            payload["extra"] = 7
    ev = tr.events()
    by_name = {e["name"]: e for e in ev}
    assert set(by_name) == {"outer", "inner"}
    outer, inner = by_name["outer"], by_name["inner"]
    # the child nests inside the parent on the timeline
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"kind": "parent"}
    assert inner["args"] == {"extra": 7}


def test_span_ring_eviction():
    tr = SpanTracer(capacity=4)
    for i in range(7):
        tr.record(f"s{i}", 0.0, 0.001)
    assert len(tr) == 4
    assert {e["name"] for e in tr.events()} == {"s3", "s4", "s5", "s6"}


def _load_export_trace():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "tools" / "export_trace.py")
    spec = importlib.util.spec_from_file_location("export_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_export_schema_roundtrip(tmp_path):
    """dump() output must survive a JSON round trip AND satisfy the
    trace-event schema tools/export_trace.py validates (the CI gate)."""
    tr = SpanTracer()
    with tr.span("recompose", reason="test"):
        with tr.span("migrate", tenant="a"):
            pass
    path = tmp_path / "trace.json"
    tr.dump(str(path))
    trace = json.loads(path.read_text())
    assert trace["displayTimeUnit"] == "ms"
    mod = _load_export_trace()
    assert mod.validate(trace) == []
    summary = mod.summarize(trace["traceEvents"])
    assert summary["recompose"]["count"] == 1
    assert mod.main([str(path), "--require-span", "recompose"]) == 0
    assert mod.main([str(path), "--require-span", "decode_step"]) == 1


# ---------------------------------------------------------------------------
# telemetry handle: no-op discipline when disabled, scoping
# ---------------------------------------------------------------------------


def test_disabled_telemetry_records_nothing():
    obs = Telemetry.off()
    obs.observe("lat", 0.5)
    obs.inc("n")
    obs.set_gauge("g", 1.0)
    with obs.span("s") as payload:
        assert payload is None                # callers guard before writing
    with obs.timed("t", "lat2") as payload:
        assert payload is None
    snap = obs.registry.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}
    assert len(obs.tracer) == 0


def test_scoped_shares_registry_fresh_does_not():
    root = Telemetry()
    scoped = root.scoped(tenant="a")
    scoped.observe("lat", 0.1)
    assert root.registry.histogram("lat", tenant="a").count == 1
    fresh = scoped.fresh()
    fresh.observe("lat", 0.2)                 # lands in the replica registry
    assert root.registry.histogram("lat", tenant="a").count == 1
    assert fresh.registry.histogram("lat", tenant="a").count == 1
    assert fresh.tracer is root.tracer        # spans still share one ring


def test_timed_records_span_and_histogram():
    obs = Telemetry().scoped(tenant="a")
    with obs.timed("work", "work_s", size=3) as payload:
        payload["done"] = True
    assert obs.registry.histogram("work_s", tenant="a").count == 1
    (ev,) = obs.tracer.events()
    assert ev["name"] == "work" and ev["args"] == {"size": 3, "done": True}


# ---------------------------------------------------------------------------
# predicted-vs-measured ledger
# ---------------------------------------------------------------------------


def test_ledger_ratio_and_aggregate():
    led = PredictionLedger()
    led.commit("a", "decode", "c4-tp2-dp1-s4", predicted_unit_s=0.002)
    for _ in range(5):
        led.observe("a", "c4-tp2-dp1-s4", 0.001, wclass="decode")
    s = led.summary()
    entry = s["entries"]["a|c4-tp2-dp1-s4"]
    assert entry["ratio"] == pytest.approx(2.0)      # over-prediction
    assert entry["measured_n"] == 5 and entry["commits"] == 1
    agg = s["aggregate"]
    assert agg["entries_with_both"] == 1
    assert agg["mean_abs_log2_error"] == pytest.approx(1.0)


def test_ledger_rejects_non_positive_predictions():
    led = PredictionLedger()
    led.commit("a", "decode", "k", predicted_unit_s=0.0)
    led.commit("a", "decode", "k", predicted_unit_s=float("inf"))
    led.observe("a", "k", 0.001, wclass="decode")
    entry = led.summary()["entries"]["a|k"]
    assert entry["predicted_unit_s"] is None and entry["ratio"] is None
    assert led.summary()["aggregate"]["entries_with_both"] == 0


# ---------------------------------------------------------------------------
# fabric integration: bounded events with fold totals (single CPU device)
# ---------------------------------------------------------------------------


def test_bounded_events_totals_survive_eviction():
    """The events deque evicts, the stats() totals don't (the ISSUE-8
    bugfix: a long-running fabric must not grow per recomposition, and
    `recompositions`/`retunes`/`recompose_seconds` must stay correct)."""
    import jax
    from repro.serve import ComposedServer, ServeConfig, TenantSpec

    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    srv = ComposedServer(
        mesh, [TenantSpec("a", "minitron-4b", reduced=True,
                          serve=ServeConfig(max_slots=2, max_len=32,
                                            eos_id=-1))],
        policy=None, events_cap=2)
    for i in range(5):
        srv.recompose({"a": srv.composer.num_cus}, reason=f"r{i}")
    assert len(srv.events) == 2               # deque evicted the first three
    assert [e.reason for e in srv.events] == ["r3", "r4"]
    st = srv.stats()
    assert st["recompositions"] == 5
    assert st["recompose_seconds"] >= 0
    assert len(st["recompose_seconds_recent"]) == 2


# ---------------------------------------------------------------------------
# device scenario: streams bit-identical with telemetry on/off across a
# live recomposition (8 fake host devices, subprocess)
# ---------------------------------------------------------------------------

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import numpy as np
"""


def _run(body: str, timeout=900):
    out = subprocess.run([sys.executable, "-c",
                          _PRELUDE + textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_streams_bit_identical_with_telemetry_on_off():
    """Acceptance invariant: instrumentation must observe, never steer.
    The same traffic through the same recompose schedule emits identical
    token streams with the registry/tracer live and with telemetry=False —
    and the on-arm actually recorded (non-empty step histograms, spans),
    while the off-arm recorded nothing."""
    res = _run("""
    from repro.serve.fabric import ComposedServer, TenantSpec
    from repro.serve import ServeConfig

    sc = ServeConfig(max_slots=2, max_len=64, eos_id=-1)

    def run(telemetry):
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        srv = ComposedServer(mesh, [
            TenantSpec("a", "minitron-4b", serve=sc),
            TenantSpec("b", "falcon-mamba-7b", seed=1, serve=sc,
                       workload="ssm"),
        ], policy=None, telemetry=telemetry)
        rng = np.random.default_rng(0)
        for t in ("a", "b"):
            vocab = srv.cfgs[t].vocab_size
            for _ in range(3):
                srv.submit(t, rng.integers(1, vocab, size=8),
                           max_new_tokens=8)
        for _ in range(6):
            srv.step()
        srv.recompose({"a": 6, "b": 2}, reason="mid-stream")
        srv.drain(max_steps=300)
        streams = {t: {str(r): toks for r, toks in out.items()}
                   for t, out in srv.results().items()}
        return streams, srv

    on_streams, on_srv = run(True)
    off_streams, off_srv = run(False)
    on_snap = on_srv.metrics_snapshot()
    off_snap = off_srv.metrics_snapshot()
    on_hist = {k: h for k, h in on_snap["histograms"].items()
               if k.startswith("decode_step_s") and h["count"] > 0}
    print(json.dumps({
        "match": on_streams == off_streams,
        "n_requests": sum(len(s) for s in on_streams.values()),
        "on_decode_step_hists": sorted(on_hist),
        "on_spans": len(on_srv.obs.tracer),
        # the off arm records nothing: no histograms, no spans (the
        # exec-cache gauges and recompose fold counters survive — they
        # are the fabric's own bookkeeping, not registry recordings)
        "off_hists": sorted(off_snap["histograms"]),
        "off_registry_empty": off_srv.obs.registry.snapshot() ==
            {"counters": {}, "gauges": {}, "histograms": {}},
        "off_spans": len(off_srv.obs.tracer),
        "on_pvm_entries": len(on_srv.stats()
                              ["predicted_vs_measured"]["entries"]),
    }))
    """)
    assert res["match"], "telemetry changed the token streams"
    assert res["n_requests"] == 6
    assert res["on_decode_step_hists"], "on-arm recorded no step histograms"
    assert res["on_spans"] > 0
    assert res["off_hists"] == [] and res["off_spans"] == 0
    assert res["off_registry_empty"]
    assert res["on_pvm_entries"] > 0
