"""Model/architecture configuration schema.

Every assigned architecture is expressed as a :class:`ModelConfig`; the shape
cells (train_4k / prefill_32k / decode_32k / long_500k) as :class:`ShapeCell`.
``reduced()`` derives the CPU smoke-test configuration for each family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0              # d_ff of each shared expert (0 -> expert_d_ff)
    dense_residual: bool = False      # Arctic: dense FFN in parallel with MoE
    dense_residual_d_ff: int = 0
    first_k_dense: int = 0            # DeepSeek: first k layers use dense FFN
    first_dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # dispatch group size (tokens); capacity C scales with the group, so the
    # (G,T,E,C) dispatch tensors shrink linearly with it (GShard groups).
    group_size: int = 1024


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0              # 0 -> full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    dt_rank: int = 0                  # 0 -> ceil(d_model / 16)
    d_inner: int = 0                  # 0 -> expand * d_model


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    # attention
    attn_type: str = "full"            # full | sliding | none
    window_size: int = 1024
    global_attn_layers: Tuple[int, ...] = ()   # layers forced to full attn
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # submodules
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_parallel: bool = False      # hymba: attn ∥ ssm heads in one layer
    # encoder-decoder
    encoder_layers: int = 0            # >0 => enc-dec; num_layers = decoder layers
    encoder_bidirectional: bool = True
    cross_attention: bool = False
    # modality frontend stub
    frontend: str = "tokens"           # tokens | frames (precomputed embeddings)
    # misc
    act: str = "silu"                  # silu (swiglu) | gelu (geglu / plain)
    glu: bool = True
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # training memory policy
    remat: bool = True
    optimizer: str = "adamw"           # adamw | adafactor (factored, for >=100B)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/logits shard
        evenly on any power-of-two mesh axis (seamless's 256206 and hymba's
        32001 otherwise fall back to replication — 4.2 GiB/device fp32
        logits in the xent backward)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.attn_type == "none"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM or sliding-window everywhere)."""
        if self.ssm is not None and (self.attn_type == "none" or self.hybrid_parallel):
            return True
        return self.attn_type == "sliding"

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + per-layer weights)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        if self.attn_type != "none" and not self.hybrid_parallel:
            if self.mla is not None:
                m = self.mla
                qdim = nq * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_layer += d * (m.q_lora_rank or 0) or 0
                per_layer += (m.q_lora_rank or d) * qdim if m.q_lora_rank else d * qdim
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                per_layer += nq * m.v_head_dim * d
            else:
                per_layer += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.hybrid_parallel:
            per_layer += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        # ssm
        if self.ssm is not None:
            s = self.ssm
            d_in = s.d_inner or s.expand * d
            dt_rank = s.dt_rank or -(-d // 16)
            per_layer += d * 2 * d_in                      # in_proj
            per_layer += d_in * s.conv_width               # conv
            per_layer += d_in * (dt_rank + 2 * s.state_dim)  # x_proj
            per_layer += dt_rank * d_in                    # dt_proj
            per_layer += d_in * s.state_dim + 2 * d_in     # A_log, D, dt bias
            per_layer += d_in * d                          # out_proj
        # ffn
        ffn_mult = 3 if self.glu else 2
        dense_correction = 0
        if self.moe is None:
            if self.d_ff:
                per_layer += ffn_mult * d * self.d_ff
        else:
            mo = self.moe
            per_layer += d * mo.num_experts                # router
            per_layer += mo.num_experts * ffn_mult * d * mo.expert_d_ff
            if mo.num_shared_experts:
                per_layer += mo.num_shared_experts * ffn_mult * d * (
                    mo.shared_d_ff or mo.expert_d_ff)
            if mo.dense_residual:
                per_layer += ffn_mult * d * (mo.dense_residual_d_ff or self.d_ff)
            if mo.first_k_dense:
                # prologue layers swap the MoE FFN for a dense one
                moe_ffn = (d * mo.num_experts
                           + mo.num_experts * ffn_mult * d * mo.expert_d_ff
                           + mo.num_shared_experts * ffn_mult * d
                           * (mo.shared_d_ff or mo.expert_d_ff))
                dense_ffn = ffn_mult * d * (mo.first_dense_d_ff or self.d_ff)
                dense_correction = mo.first_k_dense * (dense_ffn - moe_ffn)
        total = emb + self.num_layers * per_layer + dense_correction
        if self.encoder_layers:
            enc_layer = d * nq * hd * 2 + 2 * d * nkv * hd * 2 + ffn_mult * d * self.d_ff
            # self-attn + cross-attn q/o for decoder already counted once; add
            # encoder layers + decoder cross-attention.
            total += self.encoder_layers * (d * nq * hd + 2 * d * nkv * hd + nq * hd * d
                                            + ffn_mult * d * self.d_ff)
            total += self.num_layers * (d * nq * hd + 2 * d * nkv * hd + nq * hd * d)
            del enc_layer
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        ffn_mult = 3 if self.glu else 2
        routed_all = self.num_layers * mo.num_experts * ffn_mult * self.d_model * mo.expert_d_ff
        routed_active = self.num_layers * mo.top_k * ffn_mult * self.d_model * mo.expert_d_ff
        return full - routed_all + routed_active


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode
    # decode/long cells: kv_len = seq_len (cache length), one new token.


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")

ALL_CELLS = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
CELLS_BY_NAME = {c.name: c for c in ALL_CELLS}


def cells_for(config: ModelConfig) -> Tuple[ShapeCell, ...]:
    """The shape cells an architecture actually runs (skips documented in
    DESIGN.md §4: long_500k only for sub-quadratic archs)."""
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if config.supports_long_context:
        cells.append(LONG_500K)
    return tuple(cells)
