"""Analytical latency model for composed accelerators (paper §3, Fig. 6:
"DDR profiling results + platform information" -> per-layer latency table).

The model prices one MM layer (m, k, n) on an accelerator *design point*:

  compute   — atomic-op count / (CUs x AIEs x clock), with the FILCO
              flexible-parallelism (FP) flag deciding whether invalid padded
              atoms are issued (static designs compute whole fixed tiles);
  DDR       — operand/result traffic with classic tiled-MM reuse
              (A read ceil(n/Tn) times, B read ceil(m/Tm) times, C
              read+written per k-pass), with the FMV flag deciding whether
              transfers are padded to static buffer shapes and FMF deciding
              whether the on-chip capacity can be re-split between operands;
  streams   — on-chip FMU<->CU traffic at the stream bandwidth;
  total     — max(compute, ddr, stream) under double buffering + a fixed
              per-invocation launch overhead.

Baselines (CHARM-1/2/3, RSN) are specific design points of the same model —
exactly how the paper frames them (§1, Fig. 1).  TPU design points reuse the
model with the TPU_V5E profile (atoms = MXU macro-ops, DDR = HBM).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.common.platform import PlatformProfile, VCK190


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    """A (sub-)accelerator design point."""

    name: str
    num_cus: int
    aies_per_cu: int
    onchip_elems: int                    # total FMU capacity (elements)
    num_fmus: int = 16
    # static designs: fixed on-chip buffer shapes (rows, cols) per operand
    buf_a: Optional[Tuple[int, int]] = None
    buf_b: Optional[Tuple[int, int]] = None
    buf_c: Optional[Tuple[int, int]] = None
    # fixed compute tile per CU pass (static designs); None = flexible
    tile: Optional[Tuple[int, int, int]] = None
    # FILCO feature flags
    fp: bool = False                     # flexible computation parallelism
    fmv: bool = False                    # flexible on-chip memory view
    fmf: bool = False                    # flexible memory functionality
    # RSN-style: memory units of a fixed shape, count assignable per operand
    mem_unit_shape: Optional[Tuple[int, int]] = None

    @property
    def fmu_capacity(self) -> int:
        return self.onchip_elems // self.num_fmus


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    compute_s: float
    ddr_s: float
    stream_s: float
    launch_s: float
    total_s: float
    flops_valid: float
    flops_issued: float
    ddr_bytes: float
    num_fmus: int
    num_cus: int

    @property
    def compute_efficiency(self) -> float:
        return self.flops_valid / max(self.flops_issued, 1.0)


LAUNCH_OVERHEAD_S = 2.0e-6        # instruction decode + stream setup per pass
# VLIW/MXU pipeline fill per tile pass — calibrated so the single-engine
# efficiency curve matches the paper's Fig. 8 (<=5% loss at 14x24x16, i.e.
# ~2 atoms of fill against 42 issued atoms); DESIGN.md §8.
PIPELINE_FILL_ATOMS = 2


def _onchip_tiles(cfg: AccelConfig, m: int, k: int, n: int,
                  dtype_bytes: int) -> Tuple[int, int, int]:
    """On-chip macro-tile (Tm, Tk, Tn) governing DDR reuse."""
    cap = cfg.onchip_elems
    if cfg.fmf:
        # FMF: re-split the whole arena to the operand aspect (with FMV the
        # transfers are also exact; without it they stay quantized to the
        # chosen tile shapes).  Heuristic: clamp each dim, shrink the
        # largest until A+B+C fits (Fig. 5b).
        tm, tk, tn = min(m, 1024), min(k, 1024), min(n, 1024)
        while tm * tk + tk * tn + tm * tn > cap:
            # shrink the largest tile dim
            if tm >= tk and tm >= tn:
                tm = max(tm // 2, 8)
            elif tn >= tk:
                tn = max(tn // 2, 8)
            else:
                tk = max(tk // 2, 8)
        return tm, tk, tn
    if cfg.mem_unit_shape is not None:
        # RSN: units of fixed shape; counts per operand chosen freely (their
        # flexible mapping), but each operand tile is quantized to whole units.
        ur, uc = cfg.mem_unit_shape
        units = cap // (ur * uc)
        # give each operand a share proportional to its footprint, >=1 unit
        fa = m * k
        fb = k * n
        fc = m * n
        tot = fa + fb + fc
        na = max(1, int(units * fa / tot))
        nb = max(1, int(units * fb / tot))
        nc = max(1, units - na - nb)
        # square-ish tiling of units per operand
        tm = min(m, ur * max(1, int(na ** 0.5)))
        tk = min(k, uc * max(1, na // max(1, int(na ** 0.5))))
        tn = min(n, uc * max(1, int(nb ** 0.5)))
        return max(tm, ur), max(tk, uc), max(tn, uc)
    # CHARM-style: fixed buffer shapes
    assert cfg.buf_a and cfg.buf_b
    return cfg.buf_a[0], cfg.buf_a[1], cfg.buf_b[1]


def layer_latency(cfg: AccelConfig, platform: PlatformProfile,
                  m: int, k: int, n: int, *, dtype_bytes: int = 4,
                  num_cus: Optional[int] = None,
                  tile_override: Optional[Tuple[int, int, int]] = None,
                  ) -> LatencyBreakdown:
    """Price one (m x k) @ (k x n) layer on a design point."""
    am, ak, an = platform.atom_shape
    cus = num_cus if num_cus is not None else cfg.num_cus
    flops_valid = 2.0 * m * k * n

    # ---- compute side ----------------------------------------------------
    if cfg.fp:
        # flexible loop bounds: issue only atoms covering the valid region
        atoms = _ceil(m, am) * _ceil(k, ak) * _ceil(n, an)
        tm_c, tk_c, tn_c = (tile_override or
                            _onchip_tiles(cfg, m, k, n, dtype_bytes))
        passes = _ceil(m, tm_c) * _ceil(k, tk_c) * _ceil(n, tn_c)
    else:
        # static instruction block: every pass computes the whole fixed tile
        tile = tile_override or cfg.tile or _onchip_tiles(cfg, m, k, n,
                                                          dtype_bytes)
        tm_c, tk_c, tn_c = tile
        passes = _ceil(m, tm_c) * _ceil(k, tk_c) * _ceil(n, tn_c)
        atoms = passes * (_ceil(tm_c, am) * _ceil(tk_c, ak) * _ceil(tn_c, an))
    flops_issued = atoms * platform.atom_flops
    pipeline = passes * PIPELINE_FILL_ATOMS
    engines = cus * cfg.aies_per_cu
    compute_cycles = (atoms + pipeline) * platform.atom_cycles / max(engines, 1)
    compute_s = compute_cycles / platform.compute_clock_hz

    # ---- DDR side ----------------------------------------------------------
    tm, tk, tn = tile_override or _onchip_tiles(cfg, m, k, n, dtype_bytes)
    if cfg.fmv:
        eff_a = m * k
        eff_b = k * n
        eff_c = m * n
    else:
        # padded transfers: operands quantized to buffer/unit shapes
        if cfg.mem_unit_shape is not None:
            ur, uc = cfg.mem_unit_shape
            eff_a = _ceil(m, ur) * ur * _ceil(k, uc) * uc
            eff_b = _ceil(k, ur) * ur * _ceil(n, uc) * uc
            eff_c = _ceil(m, ur) * ur * _ceil(n, uc) * uc
        else:
            ba = cfg.buf_a or (tm, tk)
            bb = cfg.buf_b or (tk, tn)
            bc = cfg.buf_c or (tm, tn)
            eff_a = _ceil(m, ba[0]) * ba[0] * _ceil(k, ba[1]) * ba[1]
            eff_b = _ceil(k, bb[0]) * bb[0] * _ceil(n, bb[1]) * bb[1]
            eff_c = _ceil(m, bc[0]) * bc[0] * _ceil(n, bc[1]) * bc[1]
    reuse_a = _ceil(n, tn)              # A streamed once per N-tile
    reuse_b = _ceil(m, tm)              # B streamed once per M-tile
    kpasses = _ceil(k, tk)              # C accumulated on-chip across k? only
    c_passes = 1 if tk >= k else 2 * kpasses - 1   # read+write per extra pass
    ddr_bytes = dtype_bytes * (eff_a * reuse_a + eff_b * reuse_b
                               + eff_c * c_passes)
    ddr_s = ddr_bytes / platform.hbm_bw

    # ---- on-chip streams ---------------------------------------------------
    stream_bytes = dtype_bytes * (eff_a * reuse_a + eff_b * reuse_b
                                  + eff_c * c_passes)
    stream_s = stream_bytes / platform.onchip_bw

    launch_s = LAUNCH_OVERHEAD_S * passes / max(cus, 1)
    total = max(compute_s, ddr_s, stream_s) + launch_s
    return LatencyBreakdown(compute_s, ddr_s, stream_s, launch_s, total,
                            flops_valid, flops_issued, ddr_bytes,
                            cfg.num_fmus, cus)


# per-hop latency of one ring all-reduce phase on the serving mesh's ICI.
# What makes the serving DSE's TP-degree choice non-trivial: sharding a step
# over p CUs divides its bandwidth terms by p but adds 2(p-1) latency-bound
# collective phases per layer — for a small/reduced model the phases dominate
# and Stage 1 correctly picks tp < cus.
ICI_HOP_LATENCY_S = 1.0e-6

# per-step host cost of each extra data-parallel engine replica in a grant.
# Replica slices execute concurrently on disjoint CUs, but the fabric
# dispatches their steps from one host thread, so every replica past the
# first adds one serialized launch (same scale as LAUNCH_OVERHEAD_S) — the
# COAC-style switching tax that keeps Stage 1 from tiling a grant into
# replicas the queue cannot fill.
REPLICA_DISPATCH_OVERHEAD_S = 2.0e-6


def dp_dispatch_overhead(replicas: int) -> float:
    """Per-step host serialization cost of running ``replicas`` engine
    replicas of one tenant inside a grant (zero at dp=1)."""
    return max(int(replicas) - 1, 0) * REPLICA_DISPATCH_OVERHEAD_S


def tp_collective_latency(platform: PlatformProfile, degree: int,
                          bytes_per_device: float) -> float:
    """Seconds for one tensor-parallel all-reduce of ``bytes_per_device``
    activation bytes across ``degree`` chips (ring: 2(p-1) phases, each
    moving ~bytes/p over one ICI link plus a fixed hop latency).  Degree
    <= 1 costs nothing; a platform without a profiled ICI bandwidth
    (``ici_bw`` 0, e.g. the Versal board's stream fabric) prices the
    latency phases only."""
    p = max(int(degree), 1)
    if p <= 1:
        return 0.0
    phases = 2 * (p - 1)
    if platform.ici_bw <= 0:
        return phases * ICI_HOP_LATENCY_S
    return phases * (ICI_HOP_LATENCY_S
                     + bytes_per_device / (p * platform.ici_bw))


def decode_kv_read_latency(cfg: AccelConfig, platform: PlatformProfile,
                           batch: int, kv_heads: int, head_dim: int,
                           kv_len: int, *, dtype_bytes: int = 4) -> float:
    """Per-layer HBM seconds one decode step spends streaming a KV cache:
    2·kv_heads·head_dim·kv_len K/V elements per live slot, pure bandwidth
    on the composed sub-accelerator (each CU owns its HBM slice, so the
    read scales down with the grant like every other bandwidth term).

    ``kv_len`` is what the step actually reads: the full per-slot capacity
    on the padded decode path, but only the live prefix under the ragged
    decode kernels (``ServeConfig.use_kernels``) — the traffic difference
    the serving DSE prices through this term.  Also prices the enc-dec
    cross-attention source-cache read (same per-row footprint)."""
    if kv_len <= 0:
        return 0.0
    kv_bytes = (dtype_bytes * max(batch, 1) * float(kv_len)
                * 2.0 * kv_heads * head_dim)
    return kv_bytes / (max(cfg.num_cus, 1) * platform.hbm_bw)


def ssm_step_latency(cfg: AccelConfig, platform: PlatformProfile,
                     batch: int, d_model: int, d_inner: int, state_dim: int,
                     conv_width: int, dt_rank: int, *,
                     dtype_bytes: int = 4) -> float:
    """Price ONE mamba-block decode step on a design point.

    An SSM decode step is not a GEMM pipeline: the projections are batched
    GEMVs against once-streamed weights, and the recurrence is an
    elementwise update of the (batch, d_inner, N) hidden state that must be
    read AND written every token.  The step is therefore bound by *state +
    parameter bandwidth*, with compute far below the MM roofline — the
    class-aware serving policy prices SSM tenants with this model instead of
    the decode-GEMM model, which is exactly where heterogeneous composition
    wins (a bandwidth-starved class and a compute-starved class happily
    split one fabric).
    """
    b = max(batch, 1)
    # weights streamed once per step (in/x/dt/out projections + conv taps)
    param_elems = (2 * d_model * d_inner          # in_proj (x and z)
                   + conv_width * d_inner          # depthwise conv
                   + d_inner * (dt_rank + 2 * state_dim)   # x_proj
                   + dt_rank * d_inner             # dt_proj
                   + d_inner * d_model)            # out_proj
    # recurrent state: h (d_inner, N) and the conv window, read + written
    state_elems = 2 * b * (d_inner * state_dim + (conv_width - 1) * d_inner)
    ddr_s = dtype_bytes * (param_elems + state_elems) \
        / (max(cfg.num_cus, 1) * platform.hbm_bw)
    # compute: one MAC per streamed weight per batch row (GEMVs) plus ~6
    # elementwise ops per state element (exp, mul, add of the recurrence)
    flops = 2.0 * b * param_elems + 6.0 * b * d_inner * state_dim
    engine_flops_s = (platform.atom_flops * platform.compute_clock_hz
                      / platform.atom_cycles)
    compute_s = flops / (max(cfg.num_cus * cfg.aies_per_cu, 1)
                         * engine_flops_s)
    return max(compute_s, ddr_s) + LAUNCH_OVERHEAD_S


# ---------------------------------------------------------------------------
# design points: FILCO + the paper's baselines on VCK190
# ---------------------------------------------------------------------------

ONCHIP_ELEMS = (VCK190.onchip_bytes // 4)          # fp32 elements on chip


def filco_vck190(num_cus: int = 8, num_fmus: int = 16) -> AccelConfig:
    return AccelConfig(
        name="FILCO", num_cus=num_cus, aies_per_cu=48, num_fmus=num_fmus,
        onchip_elems=ONCHIP_ELEMS, fp=True, fmv=True, fmf=True)


def filco_ablation(fp=True, fmf=False, fmv=False) -> AccelConfig:
    """FILCO with feature subsets (Fig. 10 ablation)."""
    tag = "FILCO(" + ",".join(
        s for s, on in (("FP", fp), ("FMF", fmf), ("FMV", fmv)) if on) + ")"
    # without FMF the buffers keep the static monolithic split; with FMF the
    # arena re-splits per layer (transfers quantize to the chosen tiles
    # unless FMV makes them exact)
    static_bufs = None if fmf else (1024, 1024)
    return AccelConfig(
        name=tag, num_cus=8, aies_per_cu=48, num_fmus=16,
        onchip_elems=ONCHIP_ELEMS, fp=fp, fmv=fmv, fmf=fmf,
        buf_a=static_bufs, buf_b=static_bufs, buf_c=static_bufs,
        tile=None if fp else (1024, 1024, 1024))


def charm_monolithic() -> List[AccelConfig]:
    """CHARM-1: one monolithic accelerator, all resources, fixed big tiles."""
    return [AccelConfig(
        name="CHARM-1", num_cus=8, aies_per_cu=48, num_fmus=16,
        onchip_elems=ONCHIP_ELEMS,
        buf_a=(1024, 1024), buf_b=(1024, 1024), buf_c=(1024, 1024),
        tile=(1024, 1024, 1024))]


def charm_two() -> List[AccelConfig]:
    """CHARM-2: a big + a small statically partitioned accelerator."""
    return [
        AccelConfig(name="CHARM-2/big", num_cus=6, aies_per_cu=48,
                    num_fmus=12, onchip_elems=ONCHIP_ELEMS * 3 // 4,
                    buf_a=(768, 768), buf_b=(768, 768), buf_c=(768, 768),
                    tile=(768, 768, 768)),
        AccelConfig(name="CHARM-2/small", num_cus=2, aies_per_cu=48,
                    num_fmus=4, onchip_elems=ONCHIP_ELEMS // 4,
                    buf_a=(256, 256), buf_b=(256, 256), buf_c=(256, 256),
                    tile=(256, 256, 256)),
    ]


def charm_three() -> List[AccelConfig]:
    return [
        AccelConfig(name="CHARM-3/big", num_cus=5, aies_per_cu=48,
                    num_fmus=10, onchip_elems=ONCHIP_ELEMS * 5 // 8,
                    buf_a=(768, 768), buf_b=(768, 768), buf_c=(768, 768),
                    tile=(768, 768, 768)),
        AccelConfig(name="CHARM-3/mid", num_cus=2, aies_per_cu=48,
                    num_fmus=4, onchip_elems=ONCHIP_ELEMS // 4,
                    buf_a=(256, 256), buf_b=(256, 256), buf_c=(256, 256),
                    tile=(256, 256, 256)),
        AccelConfig(name="CHARM-3/small", num_cus=1, aies_per_cu=48,
                    num_fmus=2, onchip_elems=ONCHIP_ELEMS // 8,
                    buf_a=(128, 128), buf_b=(128, 128), buf_c=(128, 128),
                    tile=(128, 128, 128)),
    ]


def rsn_overlay() -> List[AccelConfig]:
    """RSN: flexible operand->memory-unit mapping (FMF-like counts) but a
    static per-unit matrix shape and a fixed computation tile (§1, §5)."""
    return [AccelConfig(
        name="RSN", num_cus=8, aies_per_cu=48, num_fmus=16,
        onchip_elems=ONCHIP_ELEMS, mem_unit_shape=(256, 256),
        tile=(256, 256, 256))]


def best_accel_latency(accels: Sequence[AccelConfig],
                       platform: PlatformProfile,
                       m: int, k: int, n: int) -> LatencyBreakdown:
    """Latency on the best-fitting sub-accelerator of a composition
    (CHARM-2/3 route each layer to its best member)."""
    return min((layer_latency(a, platform, m, k, n) for a in accels),
               key=lambda lb: lb.total_s)
