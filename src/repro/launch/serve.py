"""Serving launcher.

Single-tenant continuous batching:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
      --requests 8

Multi-tenant fabric with real-time recomposition (traffic-driven: bursty
tenants steal CUs from idle ones; a lone busy tenant unifies the fabric).
Needs one CU (model-axis column) per tenant — on a CPU host fake enough
devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --fabric \
      --arch minitron-4b --arch qwen2.5-32b --reduced --requests 12
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.distribution import partitioning as part
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.serve import (AnalyticalPolicy, ComposedServer, ServeConfig,
                         ServeEngine, TenantSpec)


def run_fabric(args) -> int:
    """Traffic-driven multi-tenant serving on one recomposable fabric."""
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else
            jax.make_mesh((1, jax.device_count()), ("data", "model")))
    serve = ServeConfig(max_slots=args.max_slots, max_len=args.max_len,
                        eos_id=-1)
    tenants = [TenantSpec(f"tenant{i}-{arch}", arch, reduced=args.reduced,
                          serve=serve, seed=i)
               for i, arch in enumerate(args.arch)]
    server = ComposedServer(mesh, tenants, policy=AnalyticalPolicy(),
                            decide_every=args.decide_every)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    # bursty open-loop traffic: each tenant gets its requests in one burst
    # at a random step, so load keeps shifting under the policy's feet
    bursts = sorted((int(rng.integers(0, 4 * args.requests)), t.name)
                    for t in tenants for _ in range(args.requests))
    steps = 0
    while bursts or server.pending():
        while bursts and bursts[0][0] <= steps:
            _, name = bursts.pop(0)
            vocab = server.cfgs[name].vocab_size
            plen = int(rng.integers(4, 24))
            server.submit(name, rng.integers(1, vocab, size=plen),
                          max_new_tokens=args.max_new_tokens)
        server.step()
        steps += 1
        if steps > 10_000:
            break
    dt = time.monotonic() - t0
    stats = server.stats()
    print(json.dumps({
        "tenants": [t.name for t in tenants], "decode_steps": steps,
        "wall_s": round(dt, 2), **stats,
        "events": [{"step": e.step, "reason": e.reason,
                    "sizes": e.sizes_after,
                    "seconds": round(e.seconds, 4),
                    "post_step_seconds": {
                        t: round(s, 4)
                        for t, s in e.post_step_seconds.items()}}
                   for e in server.events],
    }, indent=1))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, action="append",
                    required=True,
                    help="repeat for multiple tenants with --fabric")
    ap.add_argument("--fabric", action="store_true",
                    help="multi-tenant ComposedServer with live recomposition")
    ap.add_argument("--decide-every", type=int, default=4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.fabric:
        return run_fabric(args)
    if len(args.arch) != 1:
        ap.error("multiple --arch requires --fabric")
    args.arch = args.arch[0]

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = part.strip(model.init(jax.random.key(args.seed)))
    mesh = None
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    engine = ServeEngine(model, params,
                         ServeConfig(max_slots=args.max_slots,
                                     max_len=args.max_len, eos_id=-1),
                         mesh=mesh)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    rids = []
    for _ in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, size=plen)
        rids.append(engine.submit(prompt, max_new_tokens=args.max_new_tokens))
    steps = 0
    emitted = 0
    while engine._queue or engine._active:
        emitted += len(engine.step())
        steps += 1
        if steps > 10_000:
            break
    dt = time.monotonic() - t0
    print(json.dumps({
        "requests": args.requests, "decode_steps": steps,
        "tokens_emitted": emitted, "wall_s": round(dt, 2),
        "tokens_per_s": round(emitted / dt, 1),
        "arena_utilization": engine.arena.utilization(),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
