"""qwen1.5-110b — large dense with QKV bias [hf:Qwen/Qwen1.5 family].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
Largest dense arch in the pool; training uses factored optimizer state
(adafactor) to fit 256 v5e chips (DESIGN.md §6.4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    head_dim=128,
    attn_type="full",
    qkv_bias=True,
    act="silu",
    glu=True,
    optimizer="adafactor",
)

REDUCED = ModelConfig(
    name="qwen1.5-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    head_dim=16,
    attn_type="full",
    qkv_bias=True,
    act="silu",
    glu=True,
)
