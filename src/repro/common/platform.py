"""Platform profiles — the hardware constants the analytical model and the
roofline analysis are parameterized by.

FILCO's framework takes "platform information and DDR profiling results" as
input (paper §3.1, Fig. 6).  We keep that contract: every latency estimate in
``repro.core.analytical`` and every roofline term in ``repro.analysis`` reads
from a :class:`PlatformProfile`, never from hard-coded constants.

Two profiles ship:

* ``VCK190``  — the paper's evaluation board (AMD Versal ACAP, 150 MHz PL,
  1 GHz AIE).  Used by the paper-faithful benchmarks (fig8–fig11) so the
  reproduced numbers are commensurate with the paper's.
* ``TPU_V5E`` — the deployment target of this framework (per-chip numbers).
  Used by the dry-run roofline analysis and the TPU-side DSE.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PlatformProfile:
    name: str
    # -- compute ---------------------------------------------------------
    peak_flops: float          # peak FLOP/s per chip (bf16 for TPU, fp32 for AIE)
    atom_shape: tuple          # (m, k, n) of the atomic matmul the ISA issues
    atom_cycles: float         # pipelined cycles per atomic matmul
    compute_clock_hz: float    # clock of the compute array
    num_compute_units: int     # AIEs per device / MXU passes available
    # -- memory ----------------------------------------------------------
    hbm_bytes: int             # off-chip (DDR / HBM) capacity per chip
    hbm_bw: float              # off-chip bandwidth, bytes/s per chip
    onchip_bytes: int          # on-chip SRAM (PL URAM+BRAM / VMEM) per chip
    onchip_bw: float           # on-chip stream bandwidth, bytes/s
    # -- interconnect ----------------------------------------------------
    ici_bw: float              # per-link inter-chip bandwidth, bytes/s (0 = N/A)
    ici_links: int             # links per chip participating in a collective
    # -- control ---------------------------------------------------------
    instr_bytes: int           # bytes per instruction word
    reconfig_cycles: float     # cycles to decode+apply one runtime instruction
    bitstream_reload_s: float  # full reconfiguration cost (bitstream / recompile)

    @property
    def atom_flops(self) -> float:
        m, k, n = self.atom_shape
        return 2.0 * m * k * n

    def matmul_atoms(self, m: int, k: int, n: int) -> int:
        """Number of atomic ops for an (m,k,n) matmul, ceil-padded per axis."""
        am, ak, an = self.atom_shape
        ceil = lambda x, a: -(-x // a)
        return ceil(m, am) * ceil(k, ak) * ceil(n, an)


def _ceil(x: int, a: int) -> int:
    return -(-x // a)


# ---------------------------------------------------------------------------
# AMD Versal VCK190 (paper's board).  AIE: 400 tiles @ 1 GHz, fp32 MM intrinsics
# issue one 2x8x8 MAC-block per cycle when fully pipelined (paper §2.2 packs a
# 2x8x8 tiled MM as the atomic operation).  PL at 150 MHz moves data between
# FMUs (URAM/BRAM) and the AIE array over AXI streams (paper §4: 150 MHz PL,
# 1 GHz AIE).  DDR4 bandwidth on the board is ~25.6 GB/s.
# ---------------------------------------------------------------------------
VCK190 = PlatformProfile(
    name="vck190",
    peak_flops=400 * (2 * 8 * 8 * 2) * 1.0e9,   # 400 AIEs x 256 FLOP/atom x 1 GHz
    atom_shape=(2, 8, 8),
    atom_cycles=1.0,
    compute_clock_hz=1.0e9,
    num_compute_units=400,
    hbm_bytes=8 << 30,
    hbm_bw=25.6e9,
    onchip_bytes=(130 << 20) // 8,               # ~16 MB URAM+BRAM usable
    onchip_bw=150e6 * 128 * 4,                   # 150 MHz x 128 B ports x 4 chans
    ici_bw=0.0,
    ici_links=0,
    instr_bytes=32,
    reconfig_cycles=8.0,                         # decode a few bytes of instr
    bitstream_reload_s=1.0,                      # full PDI reload ~seconds
)

# ---------------------------------------------------------------------------
# TPU v5e (deployment target).  197 TFLOP/s bf16, 16 GiB HBM @ 819 GB/s,
# ~50 GB/s per ICI link (hardware constants given by the assignment).  The MXU
# atom on v5e is a 128x128 systolic pass fed 8 sublanes at a time: we model the
# ISA atom as (8, 128, 128) — one VREG row-block against a loaded weight tile —
# which is the granularity our Pallas ``filco_mm`` kernel predicates on.
# "Bitstream reload" on TPU = an XLA recompile (measured O(10s) for big
# programs); "instruction decode" = scalar-prefetch SMEM read (O(10) cycles).
# ---------------------------------------------------------------------------
TPU_V5E = PlatformProfile(
    name="tpu_v5e",
    peak_flops=197e12,
    atom_shape=(8, 128, 128),
    atom_cycles=8.0,                             # 8 rows through the MXU
    compute_clock_hz=0.94e9,
    num_compute_units=4,                         # MXUs per chip
    hbm_bytes=16 << 30,
    hbm_bw=819e9,
    onchip_bytes=128 << 20,                      # VMEM
    onchip_bw=22e12,                             # VMEM bandwidth (approx)
    ici_bw=50e9,
    ici_links=4,
    instr_bytes=32,
    reconfig_cycles=16.0,
    bitstream_reload_s=10.0,
)

PROFILES = {p.name: p for p in (VCK190, TPU_V5E)}


def get_profile(name: str) -> PlatformProfile:
    return PROFILES[name]
