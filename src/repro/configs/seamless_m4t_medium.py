"""seamless-m4t-medium — encoder-decoder multimodal backbone [arXiv:2308.11596].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  We model the text/unit
transformer backbone: 12 bidirectional encoder layers + 12 causal decoder
layers with cross-attention.  The audio frontend is a STUB per assignment:
``input_specs()`` provides precomputed frame embeddings (B, S_src, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,               # decoder layers
    encoder_layers=12,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    attn_type="full",
    frontend="frames",
    act="gelu",
    glu=False,
    norm="layernorm",
)

REDUCED = ModelConfig(
    name="seamless-reduced",
    family="encdec",
    num_layers=2,
    encoder_layers=2,
    cross_attention=True,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    attn_type="full",
    frontend="frames",
    act="gelu",
    glu=False,
    norm="layernorm",
)
