"""Real-time recomposition controller — the serving-side face of FILCO's
"reconfigured in real-time and flexibly composed into a unified or multiple
independent accelerators" (paper §1, §2.1).

A :class:`ComposedServer` owns the full device mesh.  Each tenant runs one
continuous-batching :class:`~repro.serve.engine.ServeEngine` on a
:class:`~repro.core.composer.MeshComposer` sub-accelerator.  Between decode
steps the controller samples per-tenant load (queue depth, owed decode work,
arena pressure) and asks a policy — by default the analytical model driving
the DSE Stage-2 search — for a new CU split.  When the predicted gain clears
the hysteresis threshold it *live-recomposes*: the affected tenants' params
and pooled decode caches are reshard onto their new sub-meshes while
unaffected tenants keep their exact devices (delta recomposition), so a
bursty tenant can steal CUs from an idle one mid-stream, and the fabric can
unify into one monolithic accelerator for a single large job.

Replication-based resharding keeps decode numerics bit-identical across any
grow/shrink/merge/unify sequence — the property tests/test_fabric.py pins.
The flip side: replicated decode does not get faster with more CUs yet, so
the policy's analytical speedup is aspirational until engines run under
serve_rules() tensor parallelism on their sub-mesh (the planned next step;
the controller, delta planner and migration protocol are TP-agnostic).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax

from repro.common.platform import TPU_V5E, PlatformProfile
from repro.configs import get_config, get_reduced
from repro.configs.base import ModelConfig
from repro.core.analytical import AccelConfig, layer_latency
from repro.core.composer import MeshComposer, SubAccelerator
from repro.distribution import partitioning as part
from repro.models import build_model
from repro.serve.engine import ServeConfig, ServeEngine


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant model co-resident on the fabric."""

    name: str
    arch: str                        # architecture registry id
    reduced: bool = True
    serve: ServeConfig = ServeConfig()
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TenantLoad:
    """Observed load signals the policy decides on."""

    pending_tokens: int              # decode steps of work owed
    queue_depth: int                 # requests awaiting admission
    active: int                      # live decode slots
    arena_utilization: float         # KV arena pressure, 0..1


@dataclasses.dataclass(frozen=True)
class RecompositionEvent:
    """One applied recomposition, for logs/benchmarks."""

    step: int
    sizes_before: Dict[str, int]
    sizes_after: Dict[str, int]
    moved: Tuple[str, ...]
    unchanged: Tuple[str, ...]
    parked: Tuple[str, ...]
    seconds: float                   # state migration (device_put) only
    reason: str
    # moved tenant -> wall time of its first step on the new composition;
    # this is where the XLA recompile stall lands, and it dominates the
    # migration time — filled in by ComposedServer.step()
    post_step_seconds: Dict[str, float] = dataclasses.field(
        default_factory=dict)


# ---------------------------------------------------------------------------
# policy: Stage-2-style split search on the analytical model
# ---------------------------------------------------------------------------

class AnalyticalPolicy:
    """Chooses a CU split by pricing each tenant's decode step on candidate
    sub-accelerator design points with the analytical latency model (the same
    machinery DSE Stage 2 schedules with, §3.1) and minimizing the predicted
    makespan of the owed work.

    Hysteresis: a new split is only worth a live recomposition when the
    predicted speedup clears ``min_gain`` — resharding has a real cost
    (device_put + one recompile per new composition).
    """

    def __init__(self, platform: PlatformProfile = TPU_V5E,
                 min_gain: float = 1.25):
        self.platform = platform
        self.min_gain = min_gain
        self._cost_cache: Dict[Tuple[str, int, int], float] = {}

    # -- per-tenant decode-step cost on a c-CU sub-accelerator -------------
    def step_cost(self, cfg: ModelConfig, batch: int, cus: int) -> float:
        if cus <= 0:
            return float("inf")
        # full and reduced configs share a name: key on the priced dims too
        key = (cfg.name, cfg.num_layers, cfg.d_model, max(batch, 1), cus)
        if key not in self._cost_cache:
            accel = AccelConfig(
                name=f"tpu-sub{cus}", num_cus=cus,
                aies_per_cu=self.platform.num_compute_units,
                onchip_elems=cus * (self.platform.onchip_bytes // 4),
                num_fmus=max(cus, 1), fp=True, fmv=True, fmf=True)
            d = cfg.d_model
            # dominant decode GEMMs per layer: attention out/in (d x d) and
            # the MLP pair (d x d_ff), batched over live slots
            lb_attn = layer_latency(accel, self.platform,
                                    max(batch, 1), d, d)
            lb_mlp = layer_latency(accel, self.platform,
                                   max(batch, 1), d, cfg.d_ff or 4 * d)
            self._cost_cache[key] = cfg.num_layers * (
                2 * lb_attn.total_s + 2 * lb_mlp.total_s)
        return self._cost_cache[key]

    # -- split search ------------------------------------------------------
    def decide(self, loads: Mapping[str, TenantLoad],
               cfgs: Mapping[str, ModelConfig],
               current: Mapping[str, int],
               num_cus: int) -> Tuple[Dict[str, int], str]:
        """Return (target sizes, reason).  Tenants with no load are parked
        (size 0); returning ``current`` means "leave the fabric alone"."""
        # arena pressure inflates demand: a hot arena means queued work the
        # pending-token count can't see yet
        demand = {t: ld.pending_tokens * (1.0 + ld.arena_utilization)
                  for t, ld in loads.items()}
        busy = [t for t, d in demand.items() if d > 0]
        if not busy:
            return dict(current), "idle"

        def makespan(sizes: Mapping[str, int]) -> float:
            return max(demand[t] * self.step_cost(
                cfgs[t], loads[t].active or 1, sizes.get(t, 0))
                for t in busy)

        best_sizes, best_cost = None, float("inf")
        for split in _candidate_splits(num_cus, busy, demand):
            sizes = dict(zip(busy, split))
            cost = makespan(sizes)
            if cost < best_cost:
                best_sizes, best_cost = sizes, cost
        assert best_sizes is not None

        cur_cost = makespan(current)
        if cur_cost == float("inf"):
            return best_sizes, "admit"          # a parked tenant got work
        if cur_cost / max(best_cost, 1e-12) >= self.min_gain:
            if len(busy) == 1:
                return best_sizes, "unify"
            return best_sizes, "rebalance"
        return dict(current), "hysteresis"


def _compositions(total: int, parts: int):
    """All ways to write ``total`` as ``parts`` positive integers."""
    if parts == 1:
        yield (total,)
        return
    for cuts in itertools.combinations(range(1, total), parts - 1):
        prev, out = 0, []
        for c in cuts:
            out.append(c - prev)
            prev = c
        out.append(total - prev)
        yield tuple(out)


# exhaustive Stage-2-style enumeration is C(num_cus-1, tenants-1): fine on a
# board-scale fabric, explosive on a pod.  Past this budget, fall back to a
# demand-proportional water-filling split (the argmax of the monotone
# makespan model in the common case, computed in O(cus x tenants)).
MAX_ENUMERATED_SPLITS = 20_000


def _candidate_splits(num_cus: int, busy: Sequence[str],
                      demand: Mapping[str, float]):
    if math.comb(num_cus - 1, len(busy) - 1) <= MAX_ENUMERATED_SPLITS:
        yield from _compositions(num_cus, len(busy))
        return
    total = sum(demand[t] for t in busy)
    shares = [max(1, int(num_cus * demand[t] / total)) for t in busy]
    spare = num_cus - sum(shares)
    order = sorted(range(len(busy)), key=lambda i: -demand[busy[i]])
    i = 0
    while spare != 0:                    # hand leftovers to (or claw back
        j = order[i % len(order)]        # from) the most-loaded tenants
        step = 1 if spare > 0 else (-1 if shares[j] > 1 else 0)
        shares[j] += step
        spare -= step
        i += 1
    yield tuple(shares)


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

class ComposedServer:
    """Multi-tenant serving on one composable fabric with live, delta
    recomposition between decode steps."""

    def __init__(self, mesh, tenants: Sequence[TenantSpec], *,
                 policy: Optional[AnalyticalPolicy] = None,
                 decide_every: int = 4, cu_axis: str = "model"):
        self.composer = MeshComposer(mesh, cu_axis=cu_axis)
        self.policy = policy
        self.decide_every = decide_every
        self.specs = {t.name: t for t in tenants}
        self.events: List[RecompositionEvent] = []
        self._stall_probe: Dict[str, RecompositionEvent] = {}
        self._step_no = 0
        self._tokens_emitted: Dict[str, int] = {t.name: 0 for t in tenants}

        # initial composition: equal shares, remainder to the first tenants
        n = len(tenants)
        if n > self.composer.num_cus:
            raise ValueError(
                f"{n} tenants need at least {n} CUs; the fabric has "
                f"{self.composer.num_cus} (on CPU, fake more host devices "
                f"with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        base, extra = divmod(self.composer.num_cus, n)
        sizes = {t.name: base + (1 if i < extra else 0)
                 for i, t in enumerate(tenants)}
        self.subs, _ = self.composer.recompose({}, sizes)

        self.cfgs: Dict[str, ModelConfig] = {}
        self.engines: Dict[str, ServeEngine] = {}
        for spec in tenants:
            cfg = (get_reduced(spec.arch) if spec.reduced
                   else get_config(spec.arch))
            model = build_model(cfg)
            params = part.strip(model.init(jax.random.key(spec.seed)))
            self.cfgs[spec.name] = cfg
            self.engines[spec.name] = ServeEngine(
                model, params, spec.serve, mesh=self.subs[spec.name])

    # ------------------------------------------------------------------
    def submit(self, tenant: str, tokens, max_new_tokens: int = 16) -> int:
        return self.engines[tenant].submit(tokens, max_new_tokens)

    def sizes(self) -> Dict[str, int]:
        return {t: len(self.subs[t].cu_ids) if t in self.subs else 0
                for t in self.engines}

    def loads(self) -> Dict[str, TenantLoad]:
        return {t: TenantLoad(eng.pending_tokens(), eng.queue_depth,
                              eng.active_count, eng.arena.utilization())
                for t, eng in self.engines.items()}

    # ------------------------------------------------------------------
    def step(self) -> Dict[str, List[Tuple[int, int]]]:
        """One fabric iteration: step every composed (non-parked) tenant,
        then maybe recompose.  Returns per-tenant emitted (rid, token)."""
        emitted = {}
        for t, eng in self.engines.items():
            if t not in self.subs:
                continue                      # parked: no CUs this interval
            probe = self._stall_probe.pop(t, None)
            t0 = time.monotonic() if probe is not None else 0.0
            out = eng.step()
            if probe is not None:
                probe.post_step_seconds[t] = time.monotonic() - t0
            self._tokens_emitted[t] += len(out)
            if out:
                emitted[t] = out
        self._step_no += 1
        if (self.policy is not None and self.decide_every > 0
                and self._step_no % self.decide_every == 0):
            self.autoscale()
        return emitted

    def autoscale(self) -> Optional[RecompositionEvent]:
        """Consult the policy; apply the recomposition it asks for."""
        target, reason = self.policy.decide(
            self.loads(), self.cfgs, self.sizes(), self.composer.num_cus)
        target = {t: s for t, s in target.items() if s > 0}
        if target == {t: s for t, s in self.sizes().items() if s > 0}:
            return None
        return self.recompose(target, reason=reason)

    def recompose(self, target_sizes: Mapping[str, int], *,
                  reason: str = "manual") -> RecompositionEvent:
        """Live recomposition: grow/shrink/admit/park tenants.  Only moved
        tenants pay a state migration; unchanged ones keep their devices."""
        before = self.sizes()
        t0 = time.monotonic()
        new_subs, delta = self.composer.recompose(self.subs, target_sizes)
        for t in delta.moved + delta.admitted:
            eng = self.engines[t]
            eng.reshard_to(new_subs[t])
            jax.block_until_ready((eng.params, eng.cache))
        self.subs = new_subs
        seconds = time.monotonic() - t0
        event = RecompositionEvent(
            step=self._step_no, sizes_before=before, sizes_after=self.sizes(),
            moved=delta.moved + delta.admitted, unchanged=delta.unchanged,
            parked=delta.evicted, seconds=seconds, reason=reason)
        for t in event.moved:
            self._stall_probe[t] = event
        self.events.append(event)
        return event

    def unify(self, tenant: str, *, reason: str = "unify"
              ) -> RecompositionEvent:
        """The monolithic composition: the whole fabric for one tenant."""
        return self.recompose({tenant: self.composer.num_cus}, reason=reason)

    # ------------------------------------------------------------------
    def pending(self) -> int:
        return sum(ld.pending_tokens for ld in self.loads().values())

    def drain(self, max_steps: int = 10_000) -> Dict[str, Dict[int, List[int]]]:
        """Step until every tenant's queue and slots are empty; returns
        per-tenant {rid: tokens} for all requests seen so far."""
        for _ in range(max_steps):
            busy = [t for t, eng in self.engines.items()
                    if eng.queue_depth or eng.active_count]
            if not busy:
                break
            if any(t not in self.subs for t in busy) and self.policy is None:
                # no policy to re-admit a parked tenant: give it CUs back
                self.recompose({t: 0 for t in self.engines} |
                               {t: self.composer.num_cus // max(len(busy), 1)
                                for t in busy}, reason="drain")
            self.step()
        return self.results()

    def results(self) -> Dict[str, Dict[int, List[int]]]:
        return {t: eng.snapshot() for t, eng in self.engines.items()}

    def stats(self) -> Dict[str, object]:
        return {
            "steps": self._step_no,
            "tokens_emitted": dict(self._tokens_emitted),
            "recompositions": len(self.events),
            "recompose_seconds": [round(e.seconds, 4) for e in self.events],
            "reshards_per_tenant": {t: eng.reshard_count
                                    for t, eng in self.engines.items()},
            "composition": {t: list(self.subs[t].cu_ids)
                            for t in self.subs},
        }
