"""Attention blocks: GQA/MQA (with QKV bias, QK-norm, sliding window) and MLA.

Each block exposes:
  init(rng, cfg)                              -> params (Annotated pytree)
  fwd(params, cfg, x, positions, ...)         -> y           (train/prefill)
  fwd_cached(params, cfg, x, cache, ...)      -> y, cache    (prefill w/ cache)
  step(params, cfg, x1, cache, ...)           -> y1, cache   (decode)

Cache layout (per layer): {"k": (B,T,Hkv,D), "v": (B,T,Hkv,D)} annotated with
kv_seq on the T dim so serving rules shard it over the model axis (split-K
decode).  MLA caches the *compressed* latent instead: {"ckv": (B,T,R),
"krope": (B,T,Dr)} — 1.7 MB/token -> 36 KB/token for deepseek-v2-lite.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.partitioning import Annotated
from repro.kernels.ragged_decode import ragged_decode_attention
from repro.models import layers as L


# ---------------------------------------------------------------------------
# standard GQA attention
# ---------------------------------------------------------------------------

def gqa_init(rng, cfg: ModelConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 8)
    p = {
        "wq": L.dense_init(ks[0], d, (hq, hd), ("embed", "heads", None)),
        "wk": L.dense_init(ks[1], d, (hkv, hd), ("embed", "kv_heads", None)),
        "wv": L.dense_init(ks[2], d, (hkv, hd), ("embed", "kv_heads", None)),
        "wo": L.dense_init(ks[3], hq * hd, d, ("heads_flat", "embed")),
    }
    # wo is stored flat (Hq*hd, d) and reshaped at use; annotate the flat dim
    p["wo"] = Annotated(p["wo"].value.reshape(hq, hd, d), ("heads", None, "embed"))
    if cfg.qkv_bias:
        p["bq"] = L.bias_init((hq, hd), ("heads", None))
        p["bk"] = L.bias_init((hkv, hd), ("kv_heads", None))
        p["bv"] = L.bias_init((hkv, hd), ("kv_heads", None))
    if cfg.qk_norm:
        p["q_norm"] = L.scale_init(hd, (None,))
        p["k_norm"] = L.scale_init(hd, (None,))
    return p


def _project_qkv(p, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_fwd(p, cfg: ModelConfig, x, positions, *, causal=True, is_global=None,
            attn_impl: str = "blockwise", block_size: int = 512,
            kv_len=None):
    """Full-sequence attention (train / encoder).  is_global: scalar bool for
    hybrid stacks whose scanned body switches window on/off per layer.
    kv_len: optional per-row (B,) valid lengths — a bidirectional stack over
    right-padded rows masks each row's own key padding so outputs are
    independent of the padded program shape (bucket-invariant encodes)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = cfg.window_size if cfg.attn_type == "sliding" else 0
    if attn_impl == "triangular" and causal and kv_len is None:
        o = L.triangular_attention(q, k, v, window=window,
                                   block_size=block_size, is_global=is_global,
                                   logit_cap=cfg.logit_softcap)
    else:
        o = L.blockwise_attention(q, k, v, causal=causal, window=window,
                                  block_size=block_size, is_global=is_global,
                                  logit_cap=cfg.logit_softcap, kv_len=kv_len)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": Annotated(jnp.zeros((batch, max_len, hkv, hd), dtype),
                       ("batch", "kv_seq", "kv_heads", None)),
        "v": Annotated(jnp.zeros((batch, max_len, hkv, hd), dtype),
                       ("batch", "kv_seq", "kv_heads", None)),
    }


def gqa_prefill(p, cfg: ModelConfig, x, positions, cache, *, is_global=None,
                attn_impl: str = "blockwise", block_size: int = 512):
    """Prefill: run causal attention and write K/V into the cache at [0, S)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = cfg.window_size if cfg.attn_type == "sliding" else 0
    if attn_impl == "triangular":
        o = L.triangular_attention(q, k, v, window=window, is_global=is_global,
                                   logit_cap=cfg.logit_softcap,
                                   block_size=block_size)
    else:
        o = L.blockwise_attention(q, k, v, causal=True, window=window,
                                  is_global=is_global,
                                  logit_cap=cfg.logit_softcap,
                                  block_size=block_size)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, 0, 0)),
    }
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)), new_cache


def gqa_step(p, cfg: ModelConfig, x1, cache, pos, *, is_global=None,
             use_kernels=False, kv_bound=None, live=None):
    """Decode one token.  x1: (B, 1, d); pos: int32 (B,) per-row positions
    (continuous batching) or scalar.

    use_kernels selects the ragged decode-attention path: the KV read is
    bounded to ``kv_bound`` rows (a static bound >= every live row's
    ``pos + 1``, threaded by the engine) and ``live`` marks empty slots.
    Live rows stay bit-identical to the padded read; the full-size cache is
    still written so retunes/migrations see the same state either way."""
    B = x1.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None]
    q, k, v = _project_qkv(p, cfg, x1, positions)
    ck = L.scatter_kv(cache["k"], k, pos)
    cv = L.scatter_kv(cache["v"], v, pos)
    window = cfg.window_size if cfg.attn_type == "sliding" else 0
    if use_kernels:
        kb = ck.shape[1] if kv_bound is None else kv_bound
        o = ragged_decode_attention(
            q, ck[:, :kb], cv[:, :kb], pos + 1, window=window,
            is_global=is_global, logit_cap=cfg.logit_softcap, live=live)
    else:
        o = L.decode_attention(q, ck, cv, pos + 1, window=window,
                               is_global=is_global,
                               logit_cap=cfg.logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x1.dtype))
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# cross-attention (enc-dec decoder).  KV come from the encoder output; during
# decoding they are precomputed once at prefill.
# ---------------------------------------------------------------------------

def cross_init(rng, cfg: ModelConfig):
    return gqa_init(rng, cfg)


def cross_fwd(p, cfg: ModelConfig, x, enc_out, enc_positions, src_len=None):
    """Cross-attention over encoder outputs (train / prefill).

    src_len: optional int32 scalar or (B,) valid source lengths.  When the
    encoder output is right-padded to a bucketed program shape (serving),
    positions >= src_len are masked out of the softmax so the decoder only
    attends real source frames — the full-sequence counterpart of
    ``cross_step``'s masked ``decode_attention`` read.  None keeps the
    unmasked training path (exact-length encoder outputs).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if src_len is None:
        o = L.blockwise_attention(q, k, v, causal=False)
    else:
        # masked source padding: scores are (B, Hq, Sq, Ssrc) — tiny for the
        # single-token decoder prompts the serving engine prefills
        B, Sq, Hq, D = q.shape
        Ss, Hkv = k.shape[1], k.shape[2]
        groups = Hq // Hkv
        kexp = jnp.repeat(k, groups, axis=2)
        vexp = jnp.repeat(v, groups, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kexp,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        lens = jnp.broadcast_to(jnp.asarray(src_len, jnp.int32), (B,))
        mask = jnp.arange(Ss)[None, None, None, :] < lens[:, None, None, None]
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(vexp.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, vexp)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def cross_kv(p, cfg: ModelConfig, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return k, v


def cross_step(p, cfg: ModelConfig, x1, ck, cv, src_len, *,
               use_kernels=False, src_bound=None, live=None):
    q = jnp.einsum("bsd,dhk->bshk", x1, p["wq"].astype(x1.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x1.dtype)
    if use_kernels:
        # bound the cross-KV read to the batch's true source lengths
        sb = ck.shape[1] if src_bound is None else src_bound
        o = ragged_decode_attention(q, ck[:, :sb], cv[:, :sb], src_len,
                                    live=live)
    else:
        o = L.decode_attention(q, ck, cv, src_len)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x1.dtype))


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434 §2.1).
#
# Projections:
#   c_kv   = x @ W_dkv                      (B,S,R)        latent KV
#   k_rope = rope(x @ W_kr)                 (B,S,Dr)       shared across heads
#   k_nope = c_kv @ W_uk  -> (B,S,H,Dn);  v = c_kv @ W_uv -> (B,S,H,Dv)
#   q      = x @ W_q -> (B,S,H,Dn+Dr)   (lite model: full-rank q)
# Decode caches (c_kv, k_rope) only and uses the *absorbed* form:
#   score = q_nope @ W_uk^T @ c_kv + q_rope @ k_rope
#   out   = (attn @ c_kv) @ W_uv
# ---------------------------------------------------------------------------

def mla_init(rng, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank
    ks = jax.random.split(rng, 8)
    p = {
        "w_dkv": L.dense_init(ks[0], d, r, ("embed", "lora")),
        "w_kr": L.dense_init(ks[1], d, dr, ("embed", None)),
        "w_uk": L.dense_init(ks[2], r, (h, dn), ("lora", "heads", None)),
        "w_uv": L.dense_init(ks[3], r, (h, dv), ("lora", "heads", None)),
        "wo": Annotated(
            L.dense_init(ks[4], h * dv, d, (None, "embed")).value.reshape(h, dv, d),
            ("heads", None, "embed")),
        "kv_norm": L.scale_init(r, (None,)),
    }
    if m.q_lora_rank:
        p["w_dq"] = L.dense_init(ks[5], d, m.q_lora_rank, ("embed", "lora"))
        p["w_uq"] = L.dense_init(ks[6], m.q_lora_rank, (h, dn + dr), ("lora", "heads", None))
        p["q_norm"] = L.scale_init(m.q_lora_rank, (None,))
    else:
        p["w_q"] = L.dense_init(ks[5], d, (h, dn + dr), ("embed", "heads", None))
    return p


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    if m.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype))
        cq = L.rms_norm(cq, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(p, cfg, x, positions):
    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    ckv = L.rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    kr = jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(x.dtype))
    kr = L.apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, kr


def mla_fwd(p, cfg: ModelConfig, x, positions, *, attn_impl: str = "blockwise",
            block_size: int = 512):
    """Prefill/train MLA: expand latents to per-head K/V and run flash attn."""
    m = cfg.mla
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv, kr = _mla_latents(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"].astype(x.dtype))
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        kr[:, :, None, :], (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v head dim up to qk dim for the shared flash kernel, slice after.
    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - m.v_head_dim)))
    if attn_impl == "triangular":
        o = L.triangular_attention(q, k, vpad, block_size=block_size)
    else:
        o = L.blockwise_attention(q, k, vpad, causal=True,
                                  block_size=block_size)
    o = o[..., : m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": Annotated(jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                         ("batch", "kv_seq", None)),
        "krope": Annotated(jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
                           ("batch", "kv_seq", None)),
    }


def mla_prefill(p, cfg: ModelConfig, x, positions, cache, *,
                attn_impl="blockwise", block_size: int = 512):
    ckv, kr = _mla_latents(p, cfg, x, positions)
    y = mla_fwd(p, cfg, x, positions, attn_impl=attn_impl,
                block_size=block_size)
    new_cache = {
        "ckv": jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
        "krope": jax.lax.dynamic_update_slice(
            cache["krope"], kr.astype(cache["krope"].dtype), (0, 0, 0)),
    }
    return y, new_cache


def mla_step(p, cfg: ModelConfig, x1, cache, pos, *, use_kernels=False,
             kv_bound=None):
    """Absorbed-matmul MLA decode: attends in the R-dim latent space.
    pos: int32 (B,) per-row positions or scalar.  With use_kernels, the
    latent read is bounded to ``kv_bound`` rows (bit-identical: the masked
    softmax ignores the dropped zero-score suffix)."""
    m = cfg.mla
    B = x1.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(p, cfg, x1, positions)          # (B,1,H,Dn/Dr)
    ckv1, kr1 = _mla_latents(p, cfg, x1, positions)
    cckv = L.scatter_kv(cache["ckv"], ckv1, pos)
    ckr = L.scatter_kv(cache["krope"], kr1, pos)
    att_ckv, att_kr = cckv, ckr
    if use_kernels and kv_bound is not None:
        att_ckv, att_kr = cckv[:, :kv_bound], ckr[:, :kv_bound]
    # absorb W_uk into q: (B,H,R)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x1.dtype))[:, 0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.qk_nope_head_dim + m.qk_rope_head_dim,
                                       jnp.float32))
    s = (jnp.einsum("bhr,btr->bht", q_abs.astype(jnp.float32),
                    att_ckv.astype(jnp.float32))
         + jnp.einsum("bhk,btk->bht", q_rope[:, 0].astype(jnp.float32),
                      att_kr.astype(jnp.float32))) * scale
    mask = jnp.arange(att_ckv.shape[1])[None, None, :] < (pos + 1)[:, None, None]
    s = jnp.where(mask, s, L.NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", w, att_ckv.astype(jnp.float32))  # (B,H,R)
    o = jnp.einsum("bhr,rhk->bhk", o_lat.astype(x1.dtype), p["w_uv"].astype(x1.dtype))
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(x1.dtype))[:, None]
    return y, {"ckv": cckv, "krope": ckr}
