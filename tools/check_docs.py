#!/usr/bin/env python
"""Docs drift check: fail if a file under docs/ references a code symbol or
path that no longer exists in the tree.

The check is deliberately simple (grep against `git grep -l`, per ISSUE):

* inline code spans (single backticks, outside fenced blocks) are scanned
  for symbol-shaped references — CamelCase names, snake_case names and
  dotted paths built from them; prose-y lowercase words, CLI flags and
  formula fragments are ignored;
* spans that look like repo paths (contain ``/`` or end in a known file
  extension) must exist on disk;
* every surviving symbol must appear verbatim somewhere under the source
  roots (src/ tests/ examples/ benchmarks/ tools/).

Run: python tools/check_docs.py          (CI runs exactly this)
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"
SOURCE_ROOTS = ("src", "tests", "examples", "benchmarks", "tools")
PATH_SUFFIXES = (".py", ".md", ".json", ".txt", ".yml", ".yaml", ".toml")

FENCE = re.compile(r"^```", re.M)
INLINE = re.compile(r"`([^`\n]+)`")
LEADING_SYM = re.compile(r"^[A-Za-z_][A-Za-z0-9_.]*")


def _camel(tok: str) -> bool:
    return any(c.isupper() for c in tok) and any(c.islower() for c in tok)


def _symbolish(tok: str) -> bool:
    """Worth checking: CamelCase or snake_case — not prose-y lowercase
    words like `decode` or `tokens` (workload ids, English)."""
    return _camel(tok) or ("_" in tok and not tok.startswith("_")
                           and not tok.endswith("_"))


def _inline_spans(text: str):
    """Inline code spans outside fenced blocks (fenced blocks hold ASCII
    diagrams and pseudo-formulas, not checkable symbols)."""
    outside, fenced = [], False
    for line in text.splitlines():
        if FENCE.match(line):
            fenced = not fenced
            continue
        if not fenced:
            outside.append(line)
    return INLINE.findall("\n".join(outside))


def _exists_in_source(needle: str) -> bool:
    out = subprocess.run(
        ["git", "grep", "-l", "--fixed-strings", needle, "--",
         *SOURCE_ROOTS],
        cwd=REPO, capture_output=True, text=True)
    return out.returncode == 0 and bool(out.stdout.strip())


def check_span(span: str):
    """Return None if the span checks out (or isn't checkable), else an
    error string."""
    span = span.strip()
    # repo paths: must exist on disk
    if "/" in span and " " not in span and not span.startswith("-"):
        leading = re.match(r"^[A-Za-z0-9_./-]+", span)
        if leading and (leading.group(0).endswith(PATH_SUFFIXES)
                        or "/" in leading.group(0)):
            p = leading.group(0).rstrip("/.")
            if not (REPO / p).exists():
                return f"path {p!r} does not exist"
            return None
    if " " not in span and span.endswith(PATH_SUFFIXES) \
            and not (REPO / span).exists() and not _exists_in_source(span):
        return f"file {span!r} does not exist"
    m = LEADING_SYM.match(span)
    if not m:
        return None
    sym = m.group(0).rstrip(".")
    parts = sym.split(".")
    checkable = [p for p in parts if _symbolish(p)]
    if not checkable:
        return None
    if _exists_in_source(sym):
        return None
    if any(_exists_in_source(p) for p in checkable):
        return None
    return f"symbol {sym!r} not found under {'/'.join(SOURCE_ROOTS)}"


def main() -> int:
    if not DOCS.is_dir():
        print("docs/ missing — nothing to check (FAIL: the docs tree is "
              "part of the repo contract)")
        return 1
    errors = []
    for md in sorted(DOCS.rglob("*.md")):
        seen = set()
        for span in _inline_spans(md.read_text()):
            if span in seen:
                continue
            seen.add(span)
            err = check_span(span)
            if err:
                errors.append(f"{md.relative_to(REPO)}: {err}")
    if errors:
        print("docs-check FAILED — stale references:")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs-check OK ({len(list(DOCS.rglob('*.md')))} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
