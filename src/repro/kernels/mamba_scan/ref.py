"""Pure-jnp oracles for mamba_scan.

``mamba_scan_ref`` materializes the full state (small shapes only);
``mamba_step_ref`` replicates the serving single-token chain in
``repro.models.ssm.mamba_step`` op-for-op, casts included, so live rows are
bit-identical to the unfused XLA path the engines run with kernels off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_step_ref(x1, conv, h, in_proj, conv_w, conv_b, x_proj, dt_proj,
                   dt_bias, a_log, d, out_proj, *, live=None):
    """x1: (B, 1, d_model); conv: (B, w-1, d_in); h: (B, d_in, N) fp32 ->
    (out (B, 1, d_model), new_conv, new_h).  Mirrors
    ``repro.models.ssm.mamba_step``; rows with ``live == False`` output
    zeros and carry their cache rows through unchanged."""
    f32 = jnp.float32
    dt_rank, n = dt_proj.shape[0], a_log.shape[1]
    xz = jnp.einsum("bsd,de->bse", x1, in_proj.astype(x1.dtype))
    x_part, z = jnp.split(xz, 2, axis=-1)                 # (B,1,Din)
    window = jnp.concatenate([conv.astype(x1.dtype), x_part], axis=1)
    xc = jnp.einsum("bwd,wd->bd", window.astype(f32),
                    conv_w.astype(f32)) + conv_b.astype(f32)
    x_conv = jax.nn.silu(xc)[:, None].astype(x1.dtype)    # (B,1,Din)
    dbc = jnp.einsum("bsd,dk->bsk", x_conv, x_proj.astype(x1.dtype))
    dt_raw, b_ssm, c_ssm = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_raw, dt_proj.astype(x1.dtype))
        .astype(f32) + dt_bias.astype(f32))[:, 0]         # (B,Din)
    a = -jnp.exp(a_log.astype(f32))
    deltaA = jnp.exp(dt[..., None] * a)                   # (B,Din,N)
    deltaBx = (dt * x_conv[:, 0].astype(f32))[..., None] * \
        b_ssm[:, 0].astype(f32)[:, None, :]
    h_new = deltaA * h + deltaBx
    y = jnp.einsum("bdn,bn->bd", h_new, c_ssm[:, 0].astype(f32))
    y = y + d.astype(f32) * x_conv[:, 0].astype(f32)
    y = (y * jax.nn.silu(z[:, 0].astype(f32)))[:, None].astype(x1.dtype)
    out = jnp.einsum("bsd,de->bse", y, out_proj.astype(x1.dtype))
    new_conv = window[:, 1:].astype(conv.dtype)
    if live is not None:
        lv = jnp.asarray(live)
        out = jnp.where(lv[:, None, None], out, jnp.zeros_like(out))
        new_conv = jnp.where(lv[:, None, None], new_conv, conv)
        h_new = jnp.where(lv[:, None, None], h_new, h)
    return out, new_conv, h_new


def mamba_scan_ref(x, dt, b, c, a_log, d):
    """x, dt: (B,S,D); b,c: (B,S,N); a_log: (D,N); d: (D,) -> (B,S,D)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dt32 = dt.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    deltaA = jnp.exp(dt32[..., None] * a)                       # (B,S,D,N)
    deltaBx = (dt32 * x32)[..., None] * b.astype(jnp.float32)[:, :, None, :]

    def step(h, inputs):
        da, dbx = inputs
        h = da * h + dbx
        return h, h

    B, S, D, N = deltaA.shape
    h0 = jnp.zeros((B, D, N), jnp.float32)
    _, hs = jax.lax.scan(step,
                         h0,
                         (deltaA.transpose(1, 0, 2, 3),
                          deltaBx.transpose(1, 0, 2, 3)))
    hs = hs.transpose(1, 0, 2, 3)                               # (B,S,D,N)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c.astype(jnp.float32))
    y = y + d.astype(jnp.float32) * x32
    return y.astype(x.dtype)
