"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

27L d_model=2048 16H d_ff=1408 vocab=102400; MLA kv_lora_rank=512
(q: full-rank in the lite model), 64 routed experts top-6 + 2 shared experts;
first layer uses a dense FFN (d_ff=10944), as in the released model.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    attn_type="full",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_d_ff=1408,
        num_shared_experts=2,
        shared_d_ff=1408,
        first_k_dense=1,
        first_dense_d_ff=10944,
        capacity_factor=1.25,
    ),
    act="silu",
    glu=True,
)

REDUCED = ModelConfig(
    name="deepseek-v2-lite-reduced",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    head_dim=16,
    attn_type="full",
    mla=MLAConfig(
        kv_lora_rank=32,
        q_lora_rank=0,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
    ),
    moe=MoEConfig(
        num_experts=4,
        top_k=2,
        expert_d_ff=64,
        num_shared_experts=1,
        shared_d_ff=64,
        first_k_dense=1,
        first_dense_d_ff=128,
        capacity_factor=2.0,   # E/top_k: drop-free for consistency tests
    ),
    act="silu",
    glu=True,
)
