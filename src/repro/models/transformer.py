"""Transformer stacks for the full architecture zoo.

One scanned layer body covers dense / MoE / SSM / hybrid / VLM decoders;
non-uniform layers (DeepSeek's first-k-dense, with a *different* FFN width)
live in an unscanned prologue so the scanned pytree stays stackable.
Per-layer behavioural differences with identical shapes (Hymba's 3 global-
attention layers) ride through the scan as boolean flag arrays.

All stacks scan over layers (bounded HLO, fast compile for 88-layer models)
and optionally remat the layer body (cfg.remat).

Caches are stacked (L, ...) pytrees threaded through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.partitioning import Annotated
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

PyTree = Any


# ---------------------------------------------------------------------------
# per-layer init / fwd
# ---------------------------------------------------------------------------

def _layer_init(rng, cfg: ModelConfig, *, dense_override_ff: int = 0,
                cross: bool = False):
    """One decoder layer.  dense_override_ff > 0 -> dense FFN of that width
    (prologue layers).  cross -> add cross-attention (enc-dec decoder)."""
    ks = jax.random.split(rng, 8)
    p: Dict[str, PyTree] = {"ln1": L.norm_init(cfg.norm, cfg.d_model)}
    if cfg.hybrid_parallel:
        p["attn"] = A.gqa_init(ks[0], cfg)
        p["ssm"] = S.mamba_init(ks[1], cfg)
        p["attn_out_norm"] = L.norm_init("rmsnorm", cfg.d_model)
        p["ssm_out_norm"] = L.norm_init("rmsnorm", cfg.d_model)
    elif cfg.ssm is not None:
        p["ssm"] = S.mamba_init(ks[1], cfg)
    elif cfg.mla is not None:
        p["attn"] = A.mla_init(ks[0], cfg)
    else:
        p["attn"] = A.gqa_init(ks[0], cfg)
    if cross:
        p["ln_cross"] = L.norm_init(cfg.norm, cfg.d_model)
        p["cross"] = A.cross_init(ks[2], cfg)
    # FFN / MoE
    if dense_override_ff:
        p["ln2"] = L.norm_init(cfg.norm, cfg.d_model)
        p["ffn"] = M.ffn_init(ks[3], cfg, dense_override_ff)
    elif cfg.moe is not None:
        p["ln2"] = L.norm_init(cfg.norm, cfg.d_model)
        p["moe"] = M.moe_init(ks[3], cfg)
    elif cfg.d_ff:
        p["ln2"] = L.norm_init(cfg.norm, cfg.d_model)
        p["ffn"] = M.ffn_init(ks[3], cfg, cfg.d_ff)
    return p


def _mixer_fwd(p, cfg: ModelConfig, h, positions, is_global, attn_impl,
               causal=True, ssm_impl="chunked", attn_block=512, kv_len=None):
    """The sequence mixer (attention / ssm / hybrid) on normed input h.
    kv_len: optional per-row valid lengths (right-padded bidirectional
    stacks mask their own key padding; see ``gqa_fwd``)."""
    if cfg.hybrid_parallel:
        a = A.gqa_fwd(p["attn"], cfg, h, positions, causal=causal,
                      is_global=is_global, attn_impl=attn_impl,
                      block_size=attn_block, kv_len=kv_len)
        s = S.mamba_fwd(p["ssm"], cfg, h, impl=ssm_impl)
        a = L.apply_norm("rmsnorm", p["attn_out_norm"], a, cfg.norm_eps)
        s = L.apply_norm("rmsnorm", p["ssm_out_norm"], s, cfg.norm_eps)
        return 0.5 * (a + s)
    if cfg.ssm is not None:
        return S.mamba_fwd(p["ssm"], cfg, h, impl=ssm_impl)
    if cfg.mla is not None:
        return A.mla_fwd(p["attn"], cfg, h, positions, attn_impl=attn_impl,
                         block_size=attn_block)
    return A.gqa_fwd(p["attn"], cfg, h, positions, causal=causal,
                     is_global=is_global, attn_impl=attn_impl,
                     block_size=attn_block, kv_len=kv_len)


def _layer_fwd(p, cfg: ModelConfig, x, positions, *, is_global=None,
               attn_impl="blockwise", enc_out=None, enc_positions=None,
               causal=True, moe_dispatch="einsum", ssm_impl="chunked",
               attn_block=512, kv_len=None):
    """Residual layer. Returns (x, aux_loss)."""
    h = L.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    x = x + _mixer_fwd(p, cfg, h, positions, is_global, attn_impl, causal,
                       ssm_impl=ssm_impl, attn_block=attn_block,
                       kv_len=kv_len)
    if "cross" in p:
        hc = L.apply_norm(cfg.norm, p["ln_cross"], x, cfg.norm_eps)
        x = x + A.cross_fwd(p["cross"], cfg, hc, enc_out, enc_positions)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h2 = L.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
        y, aux = M.moe_apply(p["moe"], cfg, h2, dispatch_impl=moe_dispatch)
        x = x + y
    elif "ffn" in p:
        h2 = L.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
        x = x + M.ffn_apply(p["ffn"], cfg, h2)
    return x, aux


# ---------------------------------------------------------------------------
# caches per layer
# ---------------------------------------------------------------------------

def _layer_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype, *,
                      cross_src: int = 0):
    c: Dict[str, PyTree] = {}
    if cfg.hybrid_parallel:
        c["attn"] = A.gqa_cache_init(cfg, batch, max_len, dtype)
        c["ssm"] = S.mamba_cache_init(cfg, batch, dtype)
    elif cfg.ssm is not None:
        c["ssm"] = S.mamba_cache_init(cfg, batch, dtype)
    elif cfg.mla is not None:
        c["attn"] = A.mla_cache_init(cfg, batch, max_len, dtype)
    else:
        c["attn"] = A.gqa_cache_init(cfg, batch, max_len, dtype)
    if cross_src:
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        c["cross_k"] = Annotated(jnp.zeros((batch, cross_src, hkv, hd), dtype),
                                 ("batch", None, "kv_heads", None))
        c["cross_v"] = Annotated(jnp.zeros((batch, cross_src, hkv, hd), dtype),
                                 ("batch", None, "kv_heads", None))
    return c


def _layer_prefill(p, cfg, x, positions, cache, *, is_global=None,
                   attn_impl="blockwise", enc_out=None, enc_positions=None,
                   src_len=None, moe_dispatch="einsum", attn_block=512):
    h = L.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if cfg.hybrid_parallel:
        a, new_cache["attn"] = A.gqa_prefill(p["attn"], cfg, h, positions,
                                             cache["attn"], is_global=is_global,
                                             attn_impl=attn_impl,
                                             block_size=attn_block)
        s, new_cache["ssm"] = S.mamba_prefill(p["ssm"], cfg, h, cache["ssm"])
        a = L.apply_norm("rmsnorm", p["attn_out_norm"], a, cfg.norm_eps)
        s = L.apply_norm("rmsnorm", p["ssm_out_norm"], s, cfg.norm_eps)
        x = x + 0.5 * (a + s)
    elif cfg.ssm is not None:
        y, new_cache["ssm"] = S.mamba_prefill(p["ssm"], cfg, h, cache["ssm"])
        x = x + y
    elif cfg.mla is not None:
        y, new_cache["attn"] = A.mla_prefill(p["attn"], cfg, h, positions,
                                             cache["attn"], attn_impl=attn_impl,
                                             block_size=attn_block)
        x = x + y
    else:
        y, new_cache["attn"] = A.gqa_prefill(p["attn"], cfg, h, positions,
                                             cache["attn"], is_global=is_global,
                                             attn_impl=attn_impl,
                                             block_size=attn_block)
        x = x + y
    if "cross" in p:
        hc = L.apply_norm(cfg.norm, p["ln_cross"], x, cfg.norm_eps)
        x = x + A.cross_fwd(p["cross"], cfg, hc, enc_out, enc_positions,
                            src_len=src_len)
        ck, cv = A.cross_kv(p["cross"], cfg, enc_out)
        new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
    if "moe" in p:
        h2 = L.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
        y, _ = M.moe_apply(p["moe"], cfg, h2, dispatch_impl=moe_dispatch)
        x = x + y
    elif "ffn" in p:
        h2 = L.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
        x = x + M.ffn_apply(p["ffn"], cfg, h2)
    return x, new_cache


def _layer_step(p, cfg, x1, cache, pos, *, is_global=None, src_len=None,
                moe_dispatch="einsum", use_kernels=False, kv_bound=None,
                src_bound=None, live=None):
    h = L.apply_norm(cfg.norm, p["ln1"], x1, cfg.norm_eps)
    new_cache = dict(cache)
    if cfg.hybrid_parallel:
        a, new_cache["attn"] = A.gqa_step(p["attn"], cfg, h, cache["attn"],
                                          pos, is_global=is_global,
                                          use_kernels=use_kernels,
                                          kv_bound=kv_bound, live=live)
        s, new_cache["ssm"] = S.mamba_step(p["ssm"], cfg, h, cache["ssm"],
                                           use_kernels=use_kernels, live=live)
        a = L.apply_norm("rmsnorm", p["attn_out_norm"], a, cfg.norm_eps)
        s = L.apply_norm("rmsnorm", p["ssm_out_norm"], s, cfg.norm_eps)
        x1 = x1 + 0.5 * (a + s)
    elif cfg.ssm is not None:
        y, new_cache["ssm"] = S.mamba_step(p["ssm"], cfg, h, cache["ssm"],
                                           use_kernels=use_kernels, live=live)
        x1 = x1 + y
    elif cfg.mla is not None:
        y, new_cache["attn"] = A.mla_step(p["attn"], cfg, h, cache["attn"],
                                          pos, use_kernels=use_kernels,
                                          kv_bound=kv_bound)
        x1 = x1 + y
    else:
        y, new_cache["attn"] = A.gqa_step(p["attn"], cfg, h, cache["attn"],
                                          pos, is_global=is_global,
                                          use_kernels=use_kernels,
                                          kv_bound=kv_bound, live=live)
        x1 = x1 + y
    if "cross" in p:
        hc = L.apply_norm(cfg.norm, p["ln_cross"], x1, cfg.norm_eps)
        x1 = x1 + A.cross_step(p["cross"], cfg, hc, cache["cross_k"],
                               cache["cross_v"], src_len,
                               use_kernels=use_kernels, src_bound=src_bound,
                               live=live)
    if "moe" in p:
        h2 = L.apply_norm(cfg.norm, p["ln2"], x1, cfg.norm_eps)
        y, _ = M.moe_apply(p["moe"], cfg, h2, dispatch_impl=moe_dispatch)
        x1 = x1 + y
    elif "ffn" in p:
        h2 = L.apply_norm(cfg.norm, p["ln2"], x1, cfg.norm_eps)
        x1 = x1 + M.ffn_apply(p["ffn"], cfg, h2)
    return x1, new_cache


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _stack_layers(layer_list):
    """List of identically-structured layer pytrees -> stacked pytree."""
    return jax.tree.map(
        lambda *xs: Annotated(jnp.stack([x.value for x in xs]), xs[0].logical),
        *layer_list, is_leaf=lambda x: isinstance(x, Annotated))


def _prologue_plan(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_prologue, num_scanned)."""
    k = cfg.moe.first_k_dense if cfg.moe is not None else 0
    return k, cfg.num_layers - k


def _global_flags(cfg: ModelConfig, start: int, count: int):
    flags = [li in cfg.global_attn_layers for li in range(start, start + count)]
    return jnp.asarray(flags)


def decoder_init(rng, cfg: ModelConfig, *, cross: bool = False):
    n_pro, n_scan = _prologue_plan(cfg)
    ks = jax.random.split(rng, cfg.num_layers)
    prologue = [
        _layer_init(ks[i], cfg, cross=cross,
                    dense_override_ff=cfg.moe.first_dense_d_ff if cfg.moe else 0)
        for i in range(n_pro)
    ]
    scanned = _stack_layers([_layer_init(ks[n_pro + i], cfg, cross=cross)
                             for i in range(n_scan)])
    # annotate stacked leaves with the leading layer axis
    scanned = jax.tree.map(
        lambda a: Annotated(a.value, ("layers",) + tuple(a.logical)),
        scanned, is_leaf=lambda x: isinstance(x, Annotated))
    return {"prologue": prologue, "scanned": scanned}


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def decoder_fwd(params, cfg: ModelConfig, x, positions, *,
                attn_impl="blockwise", enc_out=None, enc_positions=None,
                causal=True, moe_dispatch="einsum", residual_spec=None,
                ssm_impl="chunked", attn_block=512):
    """Full-sequence decoder pass. Returns (x, total_aux).

    residual_spec: optional PartitionSpec pinned onto the residual stream at
    every layer boundary (sequence parallelism: the remat-saved per-layer
    residuals shard over the model axis; DESIGN.md §6).
    """
    n_pro, n_scan = _prologue_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    x = _constrain(x, residual_spec)
    for i, lp in enumerate(params["prologue"]):
        x, aux = _layer_fwd(lp, cfg, x, positions,
                            is_global=jnp.asarray(i in cfg.global_attn_layers),
                            attn_impl=attn_impl, enc_out=enc_out,
                            enc_positions=enc_positions, causal=causal,
                            moe_dispatch=moe_dispatch, ssm_impl=ssm_impl,
                            attn_block=attn_block)
        x = _constrain(x, residual_spec)
        aux_total = aux_total + aux

    flags = _global_flags(cfg, n_pro, n_scan)

    def body(carry, xs):
        h = carry
        lp, is_global = xs
        h, aux = _layer_fwd(lp, cfg, h, positions, is_global=is_global,
                            attn_impl=attn_impl, enc_out=enc_out,
                            enc_positions=enc_positions, causal=causal,
                            moe_dispatch=moe_dispatch, ssm_impl=ssm_impl,
                            attn_block=attn_block)
        return _constrain(h, residual_spec), aux

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = jax.lax.scan(body, x, (params["scanned"], flags))
    return x, aux_total + jnp.sum(auxs)


def decoder_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype, *,
                       cross_src: int = 0):
    n_pro, n_scan = _prologue_plan(cfg)
    pro = [_layer_cache_init(cfg, batch, max_len, dtype, cross_src=cross_src)
           for _ in range(n_pro)]
    one = _layer_cache_init(cfg, batch, max_len, dtype, cross_src=cross_src)
    scanned = jax.tree.map(
        lambda a: Annotated(
            jnp.zeros((n_scan,) + a.value.shape, a.value.dtype),
            ("layers",) + tuple(a.logical)),
        one, is_leaf=lambda x: isinstance(x, Annotated))
    # per-row positions: slots at different depths (continuous batching)
    return {"prologue": pro, "scanned": scanned,
            "pos": Annotated(jnp.zeros((batch,), jnp.int32), ("batch",))}


def cache_slot_axes(cache) -> PyTree:
    """Explicit batch-slot axis index per cache leaf, -1 for leaves without
    one (scalar bookkeeping).

    Scanned stacks carry the layer axis leading, so their slot axis is 1;
    every other leaf (prologue layers, per-row ``pos`` and ``src_len``,
    cross-attention KV) is slot-leading.  Serving code writes single-request prefill results into
    the pooled cache along these axes — positional, never inferred from shape
    mismatch, so a 1-slot pool updates exactly like an N-slot one.
    """
    def axis(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return -1
        head = path[0]
        name = getattr(head, "key", None)
        return 1 if name == "scanned" else 0

    return jax.tree_util.tree_map_with_path(axis, cache)


def decoder_prefill(params, cfg: ModelConfig, x, positions, cache, *,
                    attn_impl="blockwise", enc_out=None, enc_positions=None,
                    src_len=None, moe_dispatch="einsum", residual_spec=None,
                    true_len=None, attn_block=512):
    """src_len: optional valid source lengths for the cross-attention mask
    when ``enc_out`` is right-padded (serving's bucketed encode programs);
    None attends the full encoder output (training, exact lengths)."""
    n_pro, n_scan = _prologue_plan(cfg)
    new_pro = []
    x = _constrain(x, residual_spec)
    for i, (lp, lc) in enumerate(zip(params["prologue"], cache["prologue"])):
        x, nc = _layer_prefill(lp, cfg, x, positions, lc,
                               is_global=jnp.asarray(i in cfg.global_attn_layers),
                               attn_impl=attn_impl, enc_out=enc_out,
                               enc_positions=enc_positions, src_len=src_len,
                               moe_dispatch=moe_dispatch,
                               attn_block=attn_block)
        x = _constrain(x, residual_spec)
        new_pro.append(nc)
    flags = _global_flags(cfg, n_pro, n_scan)

    def body(h, xs):
        lp, lc, is_global = xs
        h, nc = _layer_prefill(lp, cfg, h, positions, lc, is_global=is_global,
                               attn_impl=attn_impl, enc_out=enc_out,
                               enc_positions=enc_positions, src_len=src_len,
                               moe_dispatch=moe_dispatch,
                               attn_block=attn_block)
        return _constrain(h, residual_spec), nc

    x, new_scanned = jax.lax.scan(body, x, (params["scanned"],
                                            cache["scanned"], flags))
    if true_len is None:
        pos = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    else:
        pos = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32),
                               (x.shape[0],))
    new_cache = {"prologue": new_pro, "scanned": new_scanned, "pos": pos}
    return x, new_cache


def decoder_step(params, cfg: ModelConfig, x1, cache, *, src_len=None,
                 moe_dispatch="einsum", use_kernels=False, kv_bound=None,
                 src_bound=None, live=None):
    """use_kernels/kv_bound/src_bound/live: ragged decode hot path — the
    serving engine threads a static KV bound covering every live row and a
    per-row live mask; attention reads only the bounded prefix (bit-identical
    for live rows) and kernels skip dead slots entirely."""
    n_pro, n_scan = _prologue_plan(cfg)
    pos = cache["pos"]
    new_pro = []
    for i, (lp, lc) in enumerate(zip(params["prologue"], cache["prologue"])):
        x1, nc = _layer_step(lp, cfg, x1, lc, pos,
                             is_global=jnp.asarray(i in cfg.global_attn_layers),
                             src_len=src_len, moe_dispatch=moe_dispatch,
                             use_kernels=use_kernels, kv_bound=kv_bound,
                             src_bound=src_bound, live=live)
        new_pro.append(nc)
    flags = _global_flags(cfg, n_pro, n_scan)

    def body(h, xs):
        lp, lc, is_global = xs
        h, nc = _layer_step(lp, cfg, h, lc, pos, is_global=is_global,
                            src_len=src_len, moe_dispatch=moe_dispatch,
                            use_kernels=use_kernels, kv_bound=kv_bound,
                            src_bound=src_bound, live=live)
        return h, nc

    x1, new_scanned = jax.lax.scan(body, x1, (params["scanned"],
                                              cache["scanned"], flags))
    new_cache = {"prologue": new_pro, "scanned": new_scanned, "pos": pos + 1}
    return x1, new_cache


# ---------------------------------------------------------------------------
# encoder (bidirectional, for enc-dec)
# ---------------------------------------------------------------------------

def encoder_init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, cfg.encoder_layers)
    scanned = _stack_layers([_layer_init(ks[i], cfg) for i in range(cfg.encoder_layers)])
    scanned = jax.tree.map(
        lambda a: Annotated(a.value, ("layers",) + tuple(a.logical)),
        scanned, is_leaf=lambda x: isinstance(x, Annotated))
    return {"scanned": scanned, "final_norm": L.norm_init(cfg.norm, cfg.d_model)}


def encoder_fwd(params, cfg: ModelConfig, x, positions, *,
                attn_impl="blockwise", kv_len=None):
    """Bidirectional encoder stack.  kv_len: optional per-row (B,) valid
    source lengths — when the batch is right-padded (serving's bucketed
    encode programs), each row's attention masks its own key padding, making
    the valid rows of the output independent of the padded program shape
    (bucket-invariant encodes).  None keeps the unmasked exact-length path
    (training)."""
    def body(h, lp):
        h, _ = _layer_fwd(lp, cfg, h, positions, causal=False,
                          attn_impl=attn_impl, kv_len=kv_len)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["scanned"])
    return L.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def chunked_softmax_xent(x, w_head, labels, mask, *, chunk: int = 512,
                         logit_softcap: float = 0.0):
    """Cross-entropy over huge vocabularies without materializing (B,S,V).

    x: (B,S,d); w_head: (d,V); labels,mask: (B,S).  lax.scan over sequence
    chunks; per chunk only (B,chunk,V) logits exist.
    """
    B, S, d = x.shape
    nchunk = -(-S // chunk)
    pad = nchunk * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xc = x.reshape(B, nchunk, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nchunk, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nchunk, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        xb, lb, mb = xs
        logits = jnp.einsum("bcd,dv->bcv", xb, w_head.astype(xb.dtype))
        logits = logits.astype(jnp.float32)
        if logit_softcap > 0.0:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot contraction, NOT take_along_axis: a gather
        # on the vocab dim defeats the vocab sharding and makes XLA
        # replicate full-vocab fp32 logits in the backward (4.2 GiB/device
        # per chunk for a 256k vocab).  The one-hot einsum partitions.
        oh = jax.nn.one_hot(lb, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.sum(logits * oh, axis=-1)
        nll = (lse - gold) * mb
        return (tot + jnp.sum(nll), cnt + jnp.sum(mb)), None

    # checkpoint: the backward recomputes the (B,chunk,V) logits per chunk
    # instead of saving them (33 GiB/device for a 256k vocab otherwise).
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
