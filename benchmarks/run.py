# One function per paper table/figure. Prints ``name,metric,derived`` CSV.
"""Benchmark harness (deliverable (d)): one module per paper artifact.

  fig8  — single-kernel efficiency, flexible vs static (paper Fig. 8)
  fig9  — diverse-MM throughput grid vs CHARM/RSN (paper Fig. 9)
  fig10 — BERT-32..512 end-to-end + feature ablation (paper Fig. 10)
  fig11 — DSE search time, exact vs GA (paper Fig. 11)
  roofline — per (arch x cell x mesh) roofline terms from the dry-run grid
  serve_fabric — multi-tenant recomposition serving; also writes
                 BENCH_serve_fabric.json (per-tenant throughput,
                 recompositions, time-to-recompose)

Run: PYTHONPATH=src python -m benchmarks.run [fig8 fig9 ... serve_fabric]
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig8_kernel_efficiency, fig9_diverse_mm,
                            fig10_bert_e2e, fig11_dse, roofline_table,
                            serve_fabric)

    which = set(sys.argv[1:]) or {"fig8", "fig9", "fig10", "fig11",
                                  "roofline", "serve_fabric"}
    t00 = time.monotonic()
    for name, mod in [("fig8", fig8_kernel_efficiency),
                      ("fig9", fig9_diverse_mm),
                      ("fig10", fig10_bert_e2e),
                      ("fig11", fig11_dse),
                      ("roofline", roofline_table),
                      ("serve_fabric", serve_fabric)]:
        if name not in which:
            continue
        t0 = time.monotonic()
        print(f"# === {name} ===", flush=True)
        mod.main()
        print(f"# {name} took {time.monotonic() - t0:.1f}s", flush=True)
    print(f"# total {time.monotonic() - t00:.1f}s")


if __name__ == '__main__':
    main()
