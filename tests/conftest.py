import os

# Tests must see the real (single) CPU device — only the dry-run fakes 512.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis: use the real engine when installed (CI installs the pinned dev
# requirements), otherwise register the deterministic shim so the five
# property-test modules still collect and pass in air-gapped containers.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub
    _hypothesis_stub.install()

from hypothesis import settings as _hsettings  # noqa: E402

# CI profile: derandomized, few examples, no deadline — keeps tier-1 in
# minutes.  Selected by HYPOTHESIS_PROFILE, or automatically when CI is set
# (GitHub Actions exports CI=true).
_hsettings.register_profile("ci", max_examples=10, deadline=None,
                            derandomize=True)
_hsettings.register_profile("dev", max_examples=25, deadline=None)
_hsettings.load_profile(os.environ.get(
    "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))


def pytest_collection_modifyitems(config, items):
    """Under CI/FAST, skip @pytest.mark.slow cases so the exact tier-1
    command (`pytest -x -q`) fits the workflow's timeout budget."""
    if not (os.environ.get("CI") or os.environ.get("FAST")):
        return
    skip = pytest.mark.skip(
        reason="slow case skipped under CI/FAST; run locally without CI=1")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
