"""Fault tolerance: preemption handling, straggler detection, restart policy.

At thousand-node scale the assumptions are: (a) any step can be the last
(preemption / hardware fault), (b) slow hosts poison synchronous steps,
(c) restarts may come back with a different topology.  The mechanisms here:

  PreemptionGuard   — SIGTERM/flag-file -> graceful checkpoint-and-exit
  StragglerWatchdog — robust step-time statistics; flags steps exceeding
                      k x rolling median, counts consecutive events and
                      recommends CHECKPOINT_AND_RESHARD (the v5e playbook:
                      you cannot hot-swap a chip out of an ICI ring — you
                      checkpoint, drop the bad host, restart elastically)
  RestartPolicy     — bounded exponential backoff for the launcher loop

All host-side and unit-testable; the trainer wires them together and
checkpoint.restore() provides the elastic-reshard half of the story.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import statistics
import time
from typing import List, Optional

ACTION_NONE = "none"
ACTION_WARN = "warn"
ACTION_CHECKPOINT_AND_RESHARD = "checkpoint_and_reshard"


class PreemptionGuard:
    """Sets `requested` on SIGTERM (or when a sentinel file appears, for
    schedulers that cannot signal)."""

    def __init__(self, flag_file: Optional[str] = None,
                 install_signal: bool = True):
        self.requested = False
        self.flag_file = flag_file
        if install_signal:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # not in main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def check(self) -> bool:
        if self.flag_file and os.path.exists(self.flag_file):
            self.requested = True
        return self.requested


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    ratio: float


class StragglerWatchdog:
    """Rolling-median step-time monitor.

    threshold: duration / median ratio that flags a straggler.
    patience: consecutive flagged steps before recommending reshard
    (a single slow step is usually a retried DMA or GC; a *run* of them is a
    degraded host)."""

    def __init__(self, threshold: float = 2.0, window: int = 32,
                 patience: int = 3, warmup: int = 5):
        self.threshold = threshold
        self.window = window
        self.patience = patience
        self.warmup = warmup
        self.durations: List[float] = []
        self.events: List[StragglerEvent] = []
        self._consecutive = 0

    def observe(self, step: int, duration_s: float) -> str:
        self.durations.append(duration_s)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        if len(self.durations) < self.warmup:
            return ACTION_NONE
        med = statistics.median(self.durations)
        ratio = duration_s / max(med, 1e-9)
        if ratio > self.threshold:
            self._consecutive += 1
            self.events.append(StragglerEvent(step, duration_s, med, ratio))
            if self._consecutive >= self.patience:
                return ACTION_CHECKPOINT_AND_RESHARD
            return ACTION_WARN
        self._consecutive = 0
        return ACTION_NONE


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    base_backoff_s: float = 5.0
    max_backoff_s: float = 300.0
    restarts: int = 0

    def next_backoff(self) -> Optional[float]:
        if self.restarts >= self.max_restarts:
            return None
        b = min(self.base_backoff_s * (2 ** self.restarts), self.max_backoff_s)
        self.restarts += 1
        return b
