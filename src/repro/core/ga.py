"""Genetic-algorithm scheduler (paper §3.3, Fig. 7).

Chromosome = 2N genes: Encode[N] reals in [0,1] (scheduling priorities) and
Candidate[N] ints in [0, #Can-1] (mode selection).  Decoding is dependency-
aware: repeatedly append, among dependency-resolved layers, the one with the
*smallest* Encode value to the Schedule Order List (Fig. 7c), then run the
resource-constrained list scheduler along that order (Fig. 7d); fitness is
the makespan.  Crossover/mutation use the paper's random-selection strategy;
elitism keeps the best chromosome across generations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.schedule import (Schedule, ScheduleProblem, fast_makespan,
                                 list_schedule)


@dataclasses.dataclass
class GAConfig:
    population: int = 48
    generations: int = 200
    crossover_rate: float = 0.9
    mutation_rate: float = 0.08
    tournament: int = 3
    seed: int = 0
    time_limit_s: float = float("inf")
    patience: int = 50            # stop after this many stale generations


@dataclasses.dataclass
class GAResult:
    schedule: Schedule
    makespan: float
    generations_run: int
    history: List[float]
    wall_s: float


def decode_order(problem: ScheduleProblem, encode: np.ndarray) -> List[int]:
    """Dependency-aware decoding (paper Fig. 7(c))."""
    n = problem.num_layers
    indeg = [len(d) for d in problem.deps]
    succ = problem.successors()
    resolved = {i for i in range(n) if indeg[i] == 0}
    order: List[int] = []
    while resolved:
        nxt = min(resolved, key=lambda i: (encode[i], i))
        resolved.remove(nxt)
        order.append(nxt)
        for j in succ[nxt]:
            indeg[j] -= 1
            if indeg[j] == 0:
                resolved.add(j)
    assert len(order) == n
    return order


def _fitness(problem: ScheduleProblem, encode: np.ndarray,
             cand: np.ndarray) -> Tuple[float, Tuple[List[int], List[int]]]:
    """Fitness = count-based makespan (exact, see fast_makespan); the
    decoded (order, modes) is kept so the winner can be rebuilt with unit
    ids at the end."""
    order = decode_order(problem, encode)
    mc = cand.tolist()
    return fast_makespan(problem, order, mc), (order, mc)


def solve_ga(problem: ScheduleProblem, config: Optional[GAConfig] = None
             ) -> GAResult:
    cfg = config or GAConfig()
    rng = np.random.default_rng(cfg.seed)
    n = problem.num_layers
    ncand = np.asarray([len(m) for m in problem.modes])

    pop_e = rng.random((cfg.population, n))
    pop_c = (rng.random((cfg.population, n)) * ncand).astype(np.int64)
    fits = np.empty(cfg.population)
    scheds: List[Tuple[List[int], List[int]]] = [None] * cfg.population  # type: ignore
    for p in range(cfg.population):
        fits[p], scheds[p] = _fitness(problem, pop_e[p], pop_c[p])

    best_i = int(np.argmin(fits))
    best_fit, best_sched = float(fits[best_i]), scheds[best_i]
    history = [best_fit]
    t0 = time.monotonic()
    stale = 0
    gen = 0
    for gen in range(1, cfg.generations + 1):
        if time.monotonic() - t0 > cfg.time_limit_s or stale >= cfg.patience:
            break
        new_e = np.empty_like(pop_e)
        new_c = np.empty_like(pop_c)
        for p in range(cfg.population):
            # tournament parent selection
            ia = rng.integers(cfg.population, size=cfg.tournament)
            ib = rng.integers(cfg.population, size=cfg.tournament)
            pa = ia[np.argmin(fits[ia])]
            pb = ib[np.argmin(fits[ib])]
            e, c = pop_e[pa].copy(), pop_c[pa].copy()
            if rng.random() < cfg.crossover_rate:
                mask = rng.random(n) < 0.5       # uniform random selection
                e[mask] = pop_e[pb][mask]
                c[mask] = pop_c[pb][mask]
            mut = rng.random(n) < cfg.mutation_rate
            e[mut] = rng.random(int(mut.sum()))
            mutc = rng.random(n) < cfg.mutation_rate
            c[mutc] = (rng.random(int(mutc.sum())) * ncand[mutc]).astype(np.int64)
            new_e[p], new_c[p] = e, c
        # elitism: keep the best chromosome
        new_e[0], new_c[0] = pop_e[best_i % cfg.population], pop_c[best_i % cfg.population]
        pop_e, pop_c = new_e, new_c
        improved = False
        for p in range(cfg.population):
            fits[p], scheds[p] = _fitness(problem, pop_e[p], pop_c[p])
            if fits[p] < best_fit - 1e-12:
                best_fit, best_sched = float(fits[p]), scheds[p]
                best_i = p
                improved = True
        stale = 0 if improved else stale + 1
        history.append(best_fit)
    order, mc = best_sched
    # rebuild the winner with explicit unit ids; its (unit-based) makespan is
    # authoritative — float boundary cases can differ from the count-based
    # fitness by an event's epsilon, never structurally.
    final = list_schedule(problem, order, mc)
    return GAResult(final, final.makespan, gen, history,
                    time.monotonic() - t0)
