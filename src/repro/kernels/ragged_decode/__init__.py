from repro.kernels.ragged_decode.kernel import ragged_decode_kernel
from repro.kernels.ragged_decode.ops import ragged_decode_attention
from repro.kernels.ragged_decode.ref import ragged_decode_attention_ref

__all__ = ["ragged_decode_kernel", "ragged_decode_attention",
           "ragged_decode_attention_ref"]
