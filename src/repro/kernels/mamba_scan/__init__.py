from repro.kernels.mamba_scan.kernel import mamba_scan
from repro.kernels.mamba_scan.ops import selective_scan_fused
from repro.kernels.mamba_scan.ref import mamba_scan_ref

__all__ = ["mamba_scan", "selective_scan_fused", "mamba_scan_ref"]
