from repro.serve.compile_cache import ExecutableCache
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.fabric import (AnalyticalPolicy, ComposedServer,
                                RecompositionEvent, TenantLoad, TenantSpec,
                                serve_engine_rules)

__all__ = [
    "ExecutableCache",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "AnalyticalPolicy",
    "ComposedServer",
    "RecompositionEvent",
    "TenantLoad",
    "TenantSpec",
    "serve_engine_rules",
]
