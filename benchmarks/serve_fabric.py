"""Serving-fabric benchmark: traffic-driven multi-tenant recomposition.

Emits machine-readable ``BENCH_serve_fabric.json`` covering the three claims
the serving path makes:

* per-tenant throughput and per-step decode latency (p50/p95) under the
  policy-driven fabric with tensor-parallel engines and warm recomposition;
* the measured tokens/s-vs-CU-count scaling curve (strictly monotone across
  1 -> 2 -> 4 CUs is the acceptance bar: allocated CUs must buy throughput,
  otherwise the analytical policy's predicted gains are fiction).  CUs buy
  KV-cache capacity — the pooled cache shards over the sub-mesh, so slots
  scale with the grant while weights-bound decode keeps per-step latency
  ~flat (the curve reports both);
* warm-vs-cold recomposition stall: the first post-move decode step with
  the target composition's executables pre-compiled vs with a cold cache
  (where the XLA recompile lands);
* the ``mixed`` heterogeneous scenario: transformer decode + mamba SSM +
  encoder + seamless enc-dec tenants on one fabric under class-aware CU
  costing, with per-class throughput (tokens/s — including enc-dec decode
  tokens/s — or seqs/s for the encoder) and recomposition stalls;
* the ``two_stage_dse`` ablation: the same mixed fleet with
  under-provisioned slots, served by the two-stage policy (per-tenant
  design-point Stage 1 + split search Stage 2) vs ``--split-only`` (raw CU
  splits) — predicted and measured makespan/throughput side by side;
* the ``dp_replicas`` record: steady-state tokens/s on one fixed 4-CU
  grant with the Stage-1-chosen design (which must pick ``dp > 1`` — the
  engine batch is slot-capped, so extra CUs only pay as data-parallel
  replica tiles) vs the same search pinned to a single engine;
* the ``ragged_kernels`` record: the same mixed fleet served with the
  ragged decode-kernel path on (``ServeConfig.use_kernels``, the default)
  vs off (``REPRO_USE_KERNELS=0`` in the child environment) — identical
  traffic and seed, bit-identical token streams, so the per-tenant decode
  p50/p95 and tokens/s delta is pure step cost (interleaved best-of-3
  reps per arm).  Kernel-on decode p50 must sit strictly below kernel-off
  for the attention-bearing tenants (the ragged path slices the KV/source
  reads to the live bound).

* the ``slo`` record: per-tenant TTFT / per-token latency percentiles and
  the predicted-vs-measured step-cost error, read from the mixed run's
  merged metrics registry (repro.obs);
* the ``slo_attainment`` record: the identical seeded flash-crowd arrival
  schedule served by paged KV on an oversubscribed arena with the
  SLO-aware preemptive scheduler vs dense slot-granular reservations with
  preemption off — bit-identical token streams (scheduling is placement,
  never content), per-tenant p99 TTFT attainment side by side, with the
  paged+preemptive arm required not to lose;
* the ``telemetry_overhead`` record: the same mixed traffic with the
  registry + tracer live vs ``--no-telemetry``, interleaved best-of-3 —
  the always-on instrumentation must cost < 5% of step p50.

Each scenario is the launcher itself (``repro.launch.serve``) run in a
subprocess because it fakes 8 host devices and the device count is locked
at first jax init.

Run: PYTHONPATH=src python -m benchmarks.serve_fabric
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

OUT_PATH = pathlib.Path("BENCH_serve_fabric.json")

_FABRIC = [sys.executable, "-m", "repro.launch.serve", "--fabric",
           "--arch", "minitron-4b", "--arch", "qwen2.5-32b",
           "--reduced", "--requests", "4", "--max-new-tokens", "12",
           "--seed", "0"]
# heterogeneous fleet: one tenant per workload class (transformer decode +
# mamba SSM + encoder embedding + seamless enc-dec) under class-aware CU
# costing
_MIXED = [sys.executable, "-m", "repro.launch.serve", "--fabric",
          "--scenario", "mixed", "--reduced", "--requests", "4",
          "--max-new-tokens", "12", "--seed", "0"]
# ragged-kernel legs: the same mixed fleet at a KV capacity that makes the
# padded path's capacity-shaped reads visible on a CPU host (max_len 512
# against <= ~36 live rows per slot; at the default 128 the reduced
# models' decode step is dispatch-bound and the ragged delta drowns in
# timer noise), with more requests so the per-tenant p50 settles
_KMIXED = [sys.executable, "-m", "repro.launch.serve", "--fabric",
           "--scenario", "mixed", "--reduced", "--requests", "6",
           "--max-new-tokens", "12", "--max-len", "512", "--seed", "0"]
_SCALING = [sys.executable, "-m", "repro.launch.serve", "--scaling-curve",
            "--scale-sizes", "1", "2", "4", "--scale-steps", "10",
            "--seed", "0"]
# two-stage DSE ablation: the same mixed fleet, under-provisioned slots
# (max_slots 2, 10 requests/tenant — queue depth 5x the slot pool) so
# Stage 1's design-point choices (slot count, TP degree, bucket ladder)
# have room to matter; --split-only disables Stage 1 (raw CU-split search,
# the pre-DSE policy)
_DSE_MIXED = [sys.executable, "-m", "repro.launch.serve", "--fabric",
              "--scenario", "mixed", "--reduced", "--requests", "10",
              "--max-slots", "2", "--max-new-tokens", "12", "--seed", "0"]
_DSE_SPLIT = _DSE_MIXED + ["--split-only"]
_DSE_REQUESTS = 10
# data-parallel replica tiling: Stage-1-chosen dp > 1 on a fixed 4-CU grant
# vs the same search pinned to one engine (dp_cap=1); the engine batch is
# slot-capped, so replicas are the only way the grant widens concurrency
_DP = [sys.executable, "-m", "repro.launch.serve", "--dp-bench",
       "--scale-steps", "10", "--seed", "0"]
# SLO attainment under a flash crowd: the same seeded open-loop arrival
# schedule served by (a) paged KV with the SLO-aware preemptive scheduler
# vs (b) dense slot-granular reservations with preemption off
# (REPRO_PAGED_KV=0 + --no-preempt in the child).  Both arms run at the
# SAME HBM budget (kv_arena_frac scales dense and paged arenas alike);
# the dense arm reserves each request's len+max_new worst case up front,
# so the burst queues behind stranded capacity, while the paged arm
# admits by live page coverage and preempts its way out of overgrowth
_SLO_TRAFFIC = [sys.executable, "-m", "repro.launch.serve", "--fabric",
                "--scenario", "flash-crowd", "--reduced", "--requests", "6",
                "--max-slots", "6", "--max-new-tokens", "16",
                "--kv-frac", "0.2", "--kv-page-rows", "8",
                "--slo-tenant", "decode",
                # targets sized for a host-CPU fabric where warm compiles
                # dominate TTFT: the paged arm admits the whole flash crowd
                # (observed p99 ~13s), the dense arm queues part of it
                # behind worst-case reservations (~26s) — 18s discriminates
                # with margin on both sides
                "--slo-ttft-p50-ms", "15000", "--slo-ttft-p99-ms", "18000",
                "--seed", "0"]


def _run(cmd, extra_env=None):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    env.update(extra_env or {})
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                         env=env)
    if out.returncode != 0:
        raise RuntimeError(f"scenario {cmd[3:]} failed:\n"
                           f"{out.stdout[-2000:]}\n{out.stderr[-4000:]}")
    # some scenarios print a human-readable verdict after the JSON record
    return json.JSONDecoder().raw_decode(
        out.stdout[out.stdout.index("{"):])[0]


def _stalls(stats):
    return [s for e in stats["events"]
            for s in e["post_step_seconds"].values()]


def _steady_units_per_s(stats):
    """Fleet-wide emitted units (tokens / completed embeddings) per
    STEADY-STATE wall second: total wall minus the ahead-of-time compile
    seconds the warm machinery spent building new design points' programs.
    AOT compiles are the one-time reconfiguration cost the shared
    executable cache amortizes (and ``--prewarm-async`` overlaps with
    serving); on a benchmark this small they would otherwise dominate the
    wall clock and measure XLA, not the fabric.  The same subtraction is
    applied to both ablation arms; the raw wall-clock rate is recorded
    alongside."""
    return (sum(stats["tokens_emitted"].values())
            / max(stats["wall_s"] - stats["warm_compile_seconds"], 1e-9))


def _raw_units_per_s(stats):
    return sum(stats["tokens_emitted"].values()) / max(stats["wall_s"], 1e-9)


def _predicted_units_per_s(stats):
    """Both arms' APPLIED per-tenant design points priced under the same
    Stage-1 analytical model on equal 2-CU grants at the scenario's queue
    depth — the model's view of how good each arm's engine configurations
    are, with the CU split factored out (both arms share the Stage-2
    split search; Stage 1's knobs are what the ablation isolates)."""
    from repro.configs import get_reduced
    from repro.core.dse import DesignPoint
    from repro.serve.dse import TenantDesignSpace
    from repro.serve.fabric import AnalyticalPolicy
    pol = AnalyticalPolicy()
    total = 0.0
    for t, wc in stats["workload_classes"].items():
        d = stats["design_points"][t]
        cfg = get_reduced(t.split("-", 1)[1])    # tenant name = class-arch
        buckets = tuple(d["buckets"]) if d["buckets"] else None
        space = TenantDesignSpace(
            wclass=wc, max_len=128, max_src=128 if wc == "encdec" else 0,
            base_slots=d["slots"], base_buckets=buckets or ())
        point = DesignPoint(cus=2, tp=min(d["tp"] or 2, 2),
                            slots=d["slots"], buckets=buckets,
                            dp=min(d.get("dp", 1), 2))
        cost = pol.stage1.cost_of(cfg, space, _DSE_REQUESTS, point,
                                  src_cap=128)
        total += 1.0 / cost
    return total


def _dse_arm(stats):
    return {
        "wall_s": stats["wall_s"],
        "decode_steps": stats["decode_steps"],
        "warm_compile_total_s": round(stats["warm_compile_seconds"], 2),
        "units_per_s_steady": round(_steady_units_per_s(stats), 2),
        "units_per_s_raw_wall": round(_raw_units_per_s(stats), 2),
        "predicted_units_per_s": round(_predicted_units_per_s(stats), 1),
        "per_class_throughput": stats["per_class_throughput"],
        "design_points": stats["design_points"],
        "retunes": stats["retunes"],
        "recompositions": stats["recompositions"],
        "predicted_makespan_s": stats["predicted_makespan_s"],
    }


def _telemetry_overhead(ons, offs):
    """Fabric step p50 with the registry + tracer live vs ``--no-telemetry``
    on identical traffic, interleaved best-of-N (min p50 per arm, the
    ragged_kernels discipline).  The timing is the launcher's
    ``harness_step_ms`` — host perf_counter around ``server.step()``,
    measured identically in both arms, since the off arm records no
    registry histograms of its own.  Always-on instrumentation is
    admissible while the overhead stays under 5%."""
    on = min(r["harness_step_ms"]["p50"] for r in ons)
    off = min(r["harness_step_ms"]["p50"] for r in offs)
    ratio = on / max(off, 1e-9)
    return {
        "scenario": "mixed --max-len 512 --requests 6",
        "reps": len(ons),
        "step_p50_ms_on": on,
        "step_p50_ms_off": off,
        "overhead_ratio": round(ratio, 4),
        "overhead_under_5pct": ratio < 1.05,
    }


def _ragged_kernels(ons, offs):
    """Kernel-on vs kernel-off decode latency + throughput on identical
    mixed traffic, interleaved best-of-N reps per arm (the dp_replicas
    discipline: each arm's best rep strips CPU-host scheduler noise
    without hiding real cost).  The verdict tenants are the
    attention-bearing classes (transformer decode + enc-dec): their ragged
    path reads a statically sliced KV/source cache, so the step must get
    cheaper.  The SSM tenant's fused step is reported but not gated — on a
    CPU host its oracle dispatch runs the same math as the inline chain."""
    def best(runs, t, q):
        return min(r["decode_step_ms"][t][q] for r in runs)

    def best_tps(runs):
        return max(round(sum(r["tokens_emitted"].values()) / r["wall_s"], 2)
                   for r in runs)

    shared = sorted(set.intersection(
        *[set(r["decode_step_ms"]) for r in ons + offs]))
    per_tenant = {}
    for t in shared:
        p50_on, p50_off = best(ons, t, "p50"), best(offs, t, "p50")
        per_tenant[t] = {
            "class": ons[0]["workload_classes"][t],
            "p50_ms_on": p50_on, "p95_ms_on": best(ons, t, "p95"),
            "p50_ms_off": p50_off, "p95_ms_off": best(offs, t, "p95"),
            "p50_speedup": round(p50_off / max(p50_on, 1e-9), 3),
        }
    gated = [t for t in shared
             if ons[0]["workload_classes"][t] in ("decode", "encdec")]
    return {
        "scenario": "mixed --max-len 512 --requests 6",
        "reps": len(ons),
        "per_tenant": per_tenant,
        "tokens_per_s_on": best_tps(ons),
        "tokens_per_s_off": best_tps(offs),
        "verdict_tenants": gated,
        "kernels_win_p50": bool(gated) and all(
            per_tenant[t]["p50_ms_on"] < per_tenant[t]["p50_ms_off"]
            for t in gated),
    }


def _slo_attainment(paged, base):
    """Paged + SLO-preemptive vs slot-granular non-preempting on the
    identical flash-crowd schedule.  Streams must be digest-identical
    (scheduling is a pure placement decision — pinned by
    tests/test_preempt_chaos.py and --slo-smoke); the headline is
    p99 TTFT attainment for the SLO-tracked burst tenant (``--slo-tenant
    decode`` — the flash crowd lands on it), where the paged+preemptive
    arm must not lose to the baseline that simply queues the burst behind
    worst-case reservations."""
    pt = paged["slo_attainment"]["tenants"]
    bt = base["slo_attainment"]["tenants"]
    tenants = {}
    fleet = {"paged": [0.0, 0], "baseline": [0.0, 0]}   # [met, samples]
    for t in sorted(set(pt) & set(bt)):
        pa = pt[t]["ttft"]["p99"]["attainment"]
        ba = bt[t]["ttft"]["p99"]["attainment"]
        n = pt[t]["ttft"]["n"]
        fleet["paged"][0] += pa * n
        fleet["paged"][1] += n
        fleet["baseline"][0] += ba * bt[t]["ttft"]["n"]
        fleet["baseline"][1] += bt[t]["ttft"]["n"]
        tenants[t] = {
            "class": pt[t]["class"],
            "preemptions": pt[t]["preemptions"],
            "ttft_p99_target_ms": pt[t]["ttft"]["p99"]["target_ms"],
            "ttft_p99_attainment_paged": pa,
            "ttft_p99_attainment_baseline": ba,
            "ttft_p99_observed_ms_paged": pt[t]["ttft"]["p99"]["observed_ms"],
            "ttft_p99_observed_ms_baseline":
                bt[t]["ttft"]["p99"]["observed_ms"],
            "ttft_p50_attainment_paged": pt[t]["ttft"]["p50"]["attainment"],
            "ttft_p50_attainment_baseline": bt[t]["ttft"]["p50"]["attainment"],
            "samples": n,
        }
    # fleet-level verdict (requests meeting target / requests, across all
    # SLO-tracked tenants): per-tenant rows are 6-sample fractions where
    # host-timing noise flips single requests; the aggregate is where the
    # structural admission advantage has to show
    agg = {k: round(m / max(n, 1), 4) for k, (m, n) in fleet.items()}
    return {
        "scenario": ("flash-crowd --requests 6 --max-slots 6 --kv-frac 0.2 "
                     "--slo-tenant decode (equal HBM budget both arms; "
                     "SLO scoped to the burst tenant)"),
        "tenants": tenants,
        "ttft_p99_attainment_fleet_paged": agg["paged"],
        "ttft_p99_attainment_fleet_baseline": agg["baseline"],
        "slo_preemptions_paged": paged["slo_attainment"]["slo_preemptions"],
        "slo_preemptions_baseline": base["slo_attainment"]["slo_preemptions"],
        "streams_bitexact": paged["streams_digest"] == base["streams_digest"],
        # acceptance: the flash crowd forced at least one preemption
        # (capacity- or SLO-driven) and every stream still matched the
        # never-preempted baseline bit for bit
        "preempt_and_complete": (
            (sum(r["preemptions"] for r in tenants.values())
             + paged["slo_attainment"]["slo_preemptions"]) >= 1
            and paged["streams_digest"] == base["streams_digest"]),
        "paged_not_worse_p99_ttft": agg["paged"] + 1e-9 >= agg["baseline"],
    }


def main() -> None:
    warm = _run(_FABRIC)
    cold = _run(_FABRIC + ["--no-warm"])
    mixed = _run(_MIXED)
    # ragged_kernels legs: identical traffic and seed, kernel path on
    # (use_kernels default) vs off (padded decode forced process-wide in
    # the child via REPRO_USE_KERNELS=0), interleaved best-of-5
    # telemetry_overhead rides the same loop: a third interleaved arm with
    # the registry/tracer disabled, so all three arms see the same slow
    # host-load drift (5 reps: with ~14 ms CPU steps a 3-rep min-p50
    # still flips on single-digit-percent drift windows)
    kern_on, kern_off, tel_off = [], [], []
    for _ in range(5):
        kern_on.append(_run(_KMIXED))
        kern_off.append(_run(_KMIXED, extra_env={"REPRO_USE_KERNELS": "0"}))
        tel_off.append(_run(_KMIXED + ["--no-telemetry"]))
    scaling = _run(_SCALING)
    dse_two = _run(_DSE_MIXED)
    dse_split = _run(_DSE_SPLIT)
    dp = _run(_DP)
    slo_paged = _run(_SLO_TRAFFIC)
    slo_base = _run(_SLO_TRAFFIC + ["--no-preempt"],
                    extra_env={"REPRO_PAGED_KV": "0"})

    wall_s = warm["wall_s"]
    recompose_s = [e["seconds"] for e in warm["events"]]
    warm_stall = _stalls(warm)
    cold_stall = _stalls(cold)
    warm_compile_s = [e["warm_compile_seconds"] for e in warm["events"]]
    warm_max = max(warm_stall, default=0.0)
    cold_max = max(cold_stall, default=0.0)
    record = {
        "bench": "serve_fabric",
        "devices": 8,
        "tensor_parallel": True,
        "decode_steps": warm["decode_steps"],
        "wall_s": wall_s,
        "tokens_emitted": warm["tokens_emitted"],
        "tokens_per_s_per_tenant": {
            t: round(n / wall_s, 2)
            for t, n in warm["tokens_emitted"].items()},
        "decode_step_ms": warm["decode_step_ms"],
        "recompositions": warm["recompositions"],
        "recompose_reasons": [e["reason"] for e in warm["events"]],
        "time_to_recompose_s": {
            "migration_each": [round(s, 4) for s in recompose_s],
            "migration_mean": round(
                sum(recompose_s) / max(len(recompose_s), 1), 4),
            # ahead-of-time compiles performed BEFORE each switch committed
            # (off the post-move path; overlappable via --prewarm-async)
            "warm_compile_each": [round(s, 4) for s in warm_compile_s],
        },
        # the honest cost of a recomposition: the first post-move step.
        # cold = executable cache empty (the XLA recompile lands here);
        # warm = target composition pre-compiled before the switch.
        "recomposition_stall_s": {
            "warm_each": [round(s, 4) for s in warm_stall],
            "warm_max": round(warm_max, 4),
            "cold_each": [round(s, 4) for s in cold_stall],
            "cold_max": round(cold_max, 4),
            "cold_over_warm_max": round(cold_max / warm_max, 1)
            if warm_max else None,
        },
        # heterogeneous fleet: one tenant per workload class on one fabric,
        # class-aware costing (decode bandwidth / SSM state bandwidth /
        # encoder compute).  Throughput is tokens/s for decode+ssm tenants
        # and seqs/s (completed embeddings) for the encoder tenant.
        "mixed": {
            "tenants": mixed["tenants"],
            "workload_classes": mixed["workload_classes"],
            "decode_steps": mixed["decode_steps"],
            "wall_s": mixed["wall_s"],
            "per_class_throughput": mixed["per_class_throughput"],
            "recompositions": mixed["recompositions"],
            "recompose_reasons": [e["reason"] for e in mixed["events"]],
            "recomposition_stall_s": {
                "each": [round(s, 4) for s in _stalls(mixed)],
                "max": round(max(_stalls(mixed), default=0.0), 4),
            },
        },
        # two-stage DSE vs split-only on the mixed scenario: identical
        # traffic, under-provisioned slots.  "measured" compares fleet-wide
        # steady-state units/s (same work, AOT compile seconds subtracted
        # identically from both arms — see _steady_units_per_s); "predicted"
        # prices both arms' applied design points under the same Stage-1
        # analytical model on equal grants (higher is better on both).
        "two_stage_dse": {
            "scenario": "mixed --max-slots 2 --requests 10",
            "split_only": _dse_arm(dse_split),
            "two_stage": _dse_arm(dse_two),
            "measured_speedup_steady": round(
                _steady_units_per_s(dse_two)
                / max(_steady_units_per_s(dse_split), 1e-9), 3),
            "predicted_speedup": round(
                _predicted_units_per_s(dse_two)
                / max(_predicted_units_per_s(dse_split), 1e-9), 3),
            "two_stage_wins_measured":
                _steady_units_per_s(dse_two)
                >= _steady_units_per_s(dse_split),
            "two_stage_wins_predicted":
                _predicted_units_per_s(dse_two)
                >= _predicted_units_per_s(dse_split),
        },
        # serving SLO percentiles from the mixed run's merged metrics
        # registry: per-tenant TTFT and per-token latency (p50/p99 ms,
        # exact counts) plus the predicted-vs-measured step-cost error the
        # prediction ledger accumulated across the run's design commits
        "slo": mixed["slo"],
        # paged KV + SLO-aware preemptive scheduling vs the slot-granular
        # non-preempting baseline under the identical flash-crowd arrival
        # schedule: bit-identical streams, per-tenant p99 TTFT attainment
        "slo_attainment": _slo_attainment(slo_paged, slo_base),
        # always-on-cheap check: the same mixed traffic with the registry
        # and tracer live vs --no-telemetry, interleaved best-of-3; the
        # step p50 overhead must stay under 5%
        "telemetry_overhead": _telemetry_overhead(kern_on, tel_off),
        # ragged Pallas decode kernels on vs off on the mixed fleet:
        # identical traffic (streams are bit-identical — pinned by
        # tests/test_ragged_decode.py), so the p50/p95 split is pure
        # per-step cost.  Kernel-on p50 must sit strictly below kernel-off
        # for the attention-bearing tenants.
        "ragged_kernels": _ragged_kernels(kern_on, kern_off),
        # data-parallel replica tiling on one fixed grant: tokens/s with the
        # Stage-1-chosen dp (> 1; the engine batch is slot-capped, so extra
        # CUs only pay as replicas) vs the same grant forced to one engine
        "dp_replicas": {
            "model": dp["bench_model"],
            "grant_cus": dp["grant_cus"],
            "slot_cap": dp["slot_cap"],
            "chosen_point": dp["chosen"],
            "forced_point": dp["forced"],
            "tokens_per_s_dp": dp["tokens_per_s_dp"],
            "tokens_per_s_dp1": dp["tokens_per_s_dp1"],
            "speedup": dp["speedup"],
            "dp_wins": dp["ok"],
        },
        # measured counterpart of the policy's analytical speedup: decode
        # tokens/s as the same tenant's sub-mesh grows
        "scaling_curve": {
            "model": scaling["bench_model"],
            "slots_by_cus": scaling["slots_by_cus"],
            "tokens_per_s_by_cus": scaling["tokens_per_s_by_cus"],
            "step_ms_by_cus": scaling["step_ms_by_cus"],
            "monotone_1_2_4": scaling["monotone"],
        },
    }
    OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
    for key in ("decode_steps", "recompositions", "wall_s"):
        print(f"serve_fabric,{key},{record[key]}")
    for t, tps in record["tokens_per_s_per_tenant"].items():
        print(f"serve_fabric,tokens_per_s[{t}],{tps}")
    for t, tp in record["mixed"]["per_class_throughput"].items():
        print(f"serve_fabric,mixed_{tp['unit']}[{t}],{tp['value']}")
    print(f"serve_fabric,mixed_recompositions,"
          f"{record['mixed']['recompositions']}")
    dse = record["two_stage_dse"]
    print(f"serve_fabric,dse_split_only_units_per_s_steady,"
          f"{dse['split_only']['units_per_s_steady']}")
    print(f"serve_fabric,dse_two_stage_units_per_s_steady,"
          f"{dse['two_stage']['units_per_s_steady']}")
    print(f"serve_fabric,dse_measured_speedup_steady,"
          f"{dse['measured_speedup_steady']}")
    print(f"serve_fabric,dse_predicted_speedup,{dse['predicted_speedup']}")
    print(f"serve_fabric,dse_two_stage_wins_measured,"
          f"{dse['two_stage_wins_measured']}")
    print(f"serve_fabric,dse_two_stage_wins_predicted,"
          f"{dse['two_stage_wins_predicted']}")
    rk = record["ragged_kernels"]
    for t, row in rk["per_tenant"].items():
        print(f"serve_fabric,kernels_p50_ms_on[{t}],{row['p50_ms_on']}")
        print(f"serve_fabric,kernels_p50_ms_off[{t}],{row['p50_ms_off']}")
    print(f"serve_fabric,kernels_tokens_per_s_on,{rk['tokens_per_s_on']}")
    print(f"serve_fabric,kernels_tokens_per_s_off,{rk['tokens_per_s_off']}")
    print(f"serve_fabric,kernels_win_p50,{rk['kernels_win_p50']}")
    tel = record["telemetry_overhead"]
    print(f"serve_fabric,telemetry_step_p50_ms_on,{tel['step_p50_ms_on']}")
    print(f"serve_fabric,telemetry_step_p50_ms_off,{tel['step_p50_ms_off']}")
    print(f"serve_fabric,telemetry_overhead_ratio,{tel['overhead_ratio']}")
    print(f"serve_fabric,telemetry_overhead_under_5pct,"
          f"{tel['overhead_under_5pct']}")
    sa = record["slo_attainment"]
    for t, row in sa["tenants"].items():
        print(f"serve_fabric,slo_ttft_p99_att_paged[{t}],"
              f"{row['ttft_p99_attainment_paged']}")
        print(f"serve_fabric,slo_ttft_p99_att_baseline[{t}],"
              f"{row['ttft_p99_attainment_baseline']}")
    print(f"serve_fabric,slo_ttft_p99_att_fleet_paged,"
          f"{sa['ttft_p99_attainment_fleet_paged']}")
    print(f"serve_fabric,slo_ttft_p99_att_fleet_baseline,"
          f"{sa['ttft_p99_attainment_fleet_baseline']}")
    print(f"serve_fabric,slo_preemptions_paged,"
          f"{sa['slo_preemptions_paged']}")
    print(f"serve_fabric,slo_streams_bitexact,{sa['streams_bitexact']}")
    print(f"serve_fabric,slo_preempt_and_complete,"
          f"{sa['preempt_and_complete']}")
    print(f"serve_fabric,slo_paged_not_worse_p99_ttft,"
          f"{sa['paged_not_worse_p99_ttft']}")
    pvm = record["slo"]["predicted_vs_measured"]
    print(f"serve_fabric,pvm_entries,{pvm['entries_with_both']}")
    print(f"serve_fabric,pvm_mean_abs_log2_error,"
          f"{pvm.get('mean_abs_log2_error')}")
    dpr = record["dp_replicas"]
    print(f"serve_fabric,dp_chosen,{dpr['chosen_point']['dp']}")
    print(f"serve_fabric,dp_tokens_per_s,{dpr['tokens_per_s_dp']}")
    print(f"serve_fabric,dp1_tokens_per_s,{dpr['tokens_per_s_dp1']}")
    print(f"serve_fabric,dp_speedup,{dpr['speedup']}")
    print(f"serve_fabric,dp_wins,{dpr['dp_wins']}")
    for cus, tps in record["scaling_curve"]["tokens_per_s_by_cus"].items():
        print(f"serve_fabric,scaling_tokens_per_s[{cus}cu],{tps}")
    print(f"serve_fabric,scaling_monotone,"
          f"{record['scaling_curve']['monotone_1_2_4']}")
    print(f"serve_fabric,migration_mean_s,"
          f"{record['time_to_recompose_s']['migration_mean']}")
    print(f"serve_fabric,stall_warm_max_s,"
          f"{record['recomposition_stall_s']['warm_max']}")
    print(f"serve_fabric,stall_cold_max_s,"
          f"{record['recomposition_stall_s']['cold_max']}")
    print(f"# wrote {OUT_PATH.resolve()}")


if __name__ == "__main__":
    main()
