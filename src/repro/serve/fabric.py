"""Real-time recomposition controller — the serving-side face of FILCO's
"reconfigured in real-time and flexibly composed into a unified or multiple
independent accelerators" (paper §1, §2.1).

A :class:`ComposedServer` owns the full device mesh.  Each tenant runs the
engine of its *workload class* (transformer decode / SSM recurrent decode /
encoder embedding / enc-dec encode→decode — :mod:`repro.workloads`) on a
:class:`~repro.core.composer.MeshComposer` sub-accelerator, tensor-parallel
over its sub-mesh's model axis (``serve_engine_rules``), so a tenant's
measured throughput actually tracks the CUs it holds.  Between decode steps
the controller samples per-tenant load (queue depth, owed work, arena
pressure) and asks a policy — by default the analytical model driving the
DSE Stage-2 search, pricing each tenant by its class's bound resource — for
a new CU split.  When the predicted gain clears the
hysteresis threshold it *live-recomposes*: the affected tenants' params and
pooled decode caches are reshard (sharded→sharded device_put) onto their new
sub-meshes while unaffected tenants keep their exact devices (delta
recomposition).

Reconfiguration cost is attacked on both ends, mirroring the paper's
real-time story: state migration is a ~10 ms device_put, and the dominant
post-recomposition XLA recompile (0.7-2.3 s measured cold) is hoisted off
the serving path by pre-compiling the target composition's decode/prefill
executables *before* the switch commits (``warm_compile``), optionally in a
background thread (``prewarm_async``) so compilation overlaps serving.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
import math
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.common.platform import TPU_V5E, PlatformProfile
from repro.configs import get_config, get_reduced
from repro.configs.base import ModelConfig
from repro.core.analytical import AccelConfig, layer_latency, ssm_step_latency
from repro.core.composer import MeshComposer
from repro.core.dse import DesignPoint
from repro.distribution import partitioning as part
from repro.models import build_model
from repro.models.ssm import dims as ssm_dims
from repro.serve.dse import Stage1Optimizer, TenantDesignSpace
from repro.workloads import (DECODE, ENCDEC, ENCODER, SSM, Engine,
                             ExecutableCache, ServeConfig, build_engine,
                             workload_class_of)


def serve_engine_rules() -> part.ShardingRules:
    """serve_rules() tuned for the decode engine's composed sub-meshes.

    Two deltas vs the static-analysis serving rules: the KV cache shards
    over kv *heads* rather than split-K sequence (a dynamic-position scatter
    into a sequence-sharded cache forces SPMD to rematerialize the whole
    cache every step), and head counts that don't divide a given sub-mesh
    fall back to replication per-leaf at reshard time (fit_spec), so the
    same rules serve a 1-CU and an 8-CU composition.
    """
    rules = dict(part.serve_rules().rules)
    rules["kv_seq"] = None
    rules["kv_heads"] = "model"
    return part.ShardingRules(rules=rules)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant model co-resident on the fabric."""

    name: str
    arch: str                        # architecture registry id
    reduced: bool = True
    serve: ServeConfig = ServeConfig()
    seed: int = 0
    # workload class: "auto" derives from the arch (attention-free SSM ->
    # "ssm", enc-dec with cross-attention -> "encdec", else "decode");
    # "encoder" is an explicit tenant choice — any arch can serve
    # prefill-only/embedding traffic
    workload: str = "auto"


@dataclasses.dataclass(frozen=True)
class TenantLoad:
    """Observed load signals the policy decides on."""

    pending_tokens: int              # decode steps of work owed
    queue_depth: int                 # requests awaiting admission
    active: int                      # live decode slots
    arena_utilization: float         # KV arena pressure, 0..1


@dataclasses.dataclass(frozen=True)
class RecompositionEvent:
    """One applied recomposition, for logs/benchmarks."""

    step: int
    sizes_before: Dict[str, int]
    sizes_after: Dict[str, int]
    moved: Tuple[str, ...]
    unchanged: Tuple[str, ...]
    parked: Tuple[str, ...]
    seconds: float                   # state migration (device_put) only
    reason: str
    # tenants whose CU set did not move but whose engine design point
    # (TP degree / slots / bucket ladder) was reconfigured live, and the
    # per-tenant knobs actually applied (DSE Stage-1 deltas)
    retuned: Tuple[str, ...] = ()
    design: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    # moved tenant -> wall time of its first step on the new composition;
    # with a cold executable cache this is where the XLA recompile stall
    # lands — filled in by ComposedServer.step()
    post_step_seconds: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # ahead-of-time compilation performed before the switch committed
    warm_compile_seconds: float = 0.0
    warm_builds: int = 0             # cold executables compiled while warming
    overlapped: bool = False         # warmed in the background thread


# ---------------------------------------------------------------------------
# policy: Stage-2-style split search on the analytical model
# ---------------------------------------------------------------------------

# tile of sequence tokens used to price encoder (full-sequence MM) work in
# its compute-bound regime; the per-token cost is normalized back out
ENC_COST_TILE = 128


def _composed_total_s(lb, cus: int) -> float:
    """Latency of an MM layer on a composed TPU sub-accelerator.

    ``layer_latency`` models the board, where every CU shares one DDR — its
    DDR/stream terms are flat in CU count.  On the TPU fabric each CU is a
    mesh column with its own HBM and VMEM, so bandwidth scales with the
    grant; all workload classes must be priced on that same assumption
    (``ssm_step_latency`` already divides by CUs) or the split search
    compares classes on inconsistent rooflines.  Compute is already divided
    by CUs inside ``layer_latency``."""
    c = max(cus, 1)
    return max(lb.compute_s, lb.ddr_s / c, lb.stream_s / c) + lb.launch_s


class AnalyticalPolicy:
    """The serving-side DSE Stage 2: chooses a *composition of design
    points* by pricing each tenant on candidate sub-accelerator grants with
    the analytical latency model (the same machinery the offline DSE
    schedules with, §3.1) and minimizing the predicted makespan of the owed
    work.

    Two-stage (default): for every candidate CU grant ``c`` the per-tenant
    Stage-1 optimizer (:class:`~repro.serve.dse.Stage1Optimizer`) first
    picks that tenant's best engine configuration — TP degree over the
    sub-mesh, slot count, bucket ladder — and ``decide`` searches splits
    over those Stage-1-optimal :class:`~repro.core.dse.DesignPoint` memos,
    returning per-tenant design points (CUs + knobs) for the fabric to
    apply live.  With ``two_stage=False`` (the split-only ablation, and the
    behavior when the fabric supplies no design spaces) the CU count is the
    whole design point — exactly the pre-DSE policy.

    Class-aware costing (the heterogeneous-workload point): each tenant is
    priced by its workload class's actual bound resource —

    * ``decode``  — bandwidth-bound batched GEMV per decode step (weights
      streamed every token);
    * ``ssm``     — state-bandwidth-bound recurrent update per step
      (``ssm_step_latency``: params + read/write of the O(1) state);
    * ``encoder`` — compute-bound full-sequence MMs per owed prompt token;
    * ``encdec``  — decode-side batched GEMVs (self-attn, cross-attn and
      MLP projections) plus the per-step cross-attention source-cache read,
      whose bytes scale with the tenant's source length (``src_len``).

    So a compute-starved encoder tenant and a bandwidth-starved decode
    tenant are priced on different rooflines, and the split search allocates
    CUs by where they actually buy throughput instead of a one-size
    decode-GEMM model.

    Hysteresis: a new split is only worth a live recomposition when the
    predicted speedup clears ``min_gain`` — resharding has a real cost
    (device_put + one warm compile per new composition).  After every
    ``decide`` the policy exposes ``runner_up``: the best candidate split it
    did NOT return (the hysteresis-rejected best, or the second-best when a
    switch was returned) — the fabric speculatively prewarms it during idle
    decide intervals.
    """

    def __init__(self, platform: PlatformProfile = TPU_V5E,
                 min_gain: float = 1.25, two_stage: bool = True):
        self.platform = platform
        self.min_gain = min_gain
        self._cost_cache: Dict[Tuple, float] = {}
        self.runner_up: Optional[Dict[str, DesignPoint]] = None
        # Stage 1 shares this policy's step_cost memo as its price table
        self.stage1: Optional[Stage1Optimizer] = (
            Stage1Optimizer(self.step_cost, platform) if two_stage else None)
        # last non-idle decision's predicted makespans (telemetry /
        # benchmark): {"best_s": ..., "current_s": ...}
        self.predicted: Optional[Dict[str, float]] = None

    # -- per-tenant per-step cost on a c-CU sub-accelerator ----------------
    def step_cost(self, cfg: ModelConfig, batch: int, cus: int,
                  wclass: str = DECODE, src_len: int = 0) -> float:
        """Predicted seconds per unit of owed work for one tenant on a
        ``cus``-CU sub-accelerator: per decode step for decode/ssm/encdec
        tenants, per owed prompt token for encoder tenants.

        src_len: enc-dec tenants' per-slot source length (frames read by
        every cross-attention step); ignored for other classes.
        """
        if cus <= 0:
            return float("inf")
        # the key carries the workload class: an SSM/encoder/encdec tenant
        # sharing a cfg.name with a transformer tenant must never read a
        # stale decode-GEMM price (and full/reduced configs share a name:
        # key on the priced dims too — d_ff and the KV dims are priced, so
        # they are in the key).  src_len prices the encdec cross-attention
        # read, so it is part of the key.
        key = (wclass, cfg.name, cfg.num_layers, cfg.d_model,
               cfg.d_ff, cfg.num_kv_heads, cfg.resolved_head_dim,
               max(batch, 1), cus, src_len if wclass == ENCDEC else 0)
        if key not in self._cost_cache:
            accel = AccelConfig(
                name=f"tpu-sub{cus}", num_cus=cus,
                aies_per_cu=self.platform.num_compute_units,
                onchip_elems=cus * (self.platform.onchip_bytes // 4),
                num_fmus=max(cus, 1), fp=True, fmv=True, fmf=True)
            d = cfg.d_model
            if wclass == SSM and cfg.ssm is not None:
                # recurrent decode: state + parameter bandwidth per step
                d_in, dt_rank, n, w = ssm_dims(cfg)
                cost = cfg.num_layers * ssm_step_latency(
                    accel, self.platform, max(batch, 1), d, d_in, n, w,
                    dt_rank)
            elif wclass == ENCODER:
                # prefill-only: compute-bound full-sequence MMs, priced per
                # owed prompt token (demand for encoder tenants is queued
                # prompt tokens, not decode steps)
                layers = cfg.encoder_layers or cfg.num_layers
                lb_attn = layer_latency(accel, self.platform,
                                        ENC_COST_TILE, d, d)
                lb_mlp = layer_latency(accel, self.platform,
                                       ENC_COST_TILE, d, cfg.d_ff or 4 * d)
                cost = layers * (2 * _composed_total_s(lb_attn, cus)
                                 + 2 * _composed_total_s(lb_mlp, cus)) \
                    / ENC_COST_TILE
            elif wclass == ENCDEC:
                # enc-dec decode step: the decoder-side batched GEMVs — one
                # extra (d x d) projection pair vs plain decode for the
                # cross-attention block — plus the per-step cross-attention
                # source-cache read: 2·kv_heads·head_dim·src_len K/V
                # elements per layer per live slot, pure HBM bandwidth on
                # the composed sub-accelerator (each CU owns its HBM slice,
                # so the read scales down with the grant like every other
                # bandwidth term)
                b = max(batch, 1)
                lb_attn = layer_latency(accel, self.platform, b, d, d)
                lb_mlp = layer_latency(accel, self.platform,
                                       b, d, cfg.d_ff or 4 * d)
                src = max(src_len, 1)
                kv_bytes = 4.0 * b * src * 2 * cfg.num_kv_heads \
                    * cfg.resolved_head_dim
                cross_read_s = kv_bytes / (max(cus, 1) * self.platform.hbm_bw)
                cost = cfg.num_layers * (
                    3 * _composed_total_s(lb_attn, cus)
                    + 2 * _composed_total_s(lb_mlp, cus)
                    + cross_read_s)
            else:
                # dominant decode GEMMs per layer: attention out/in (d x d)
                # and the MLP pair (d x d_ff), batched over live slots
                lb_attn = layer_latency(accel, self.platform,
                                        max(batch, 1), d, d)
                lb_mlp = layer_latency(accel, self.platform,
                                       max(batch, 1), d, cfg.d_ff or 4 * d)
                cost = cfg.num_layers * (
                    2 * _composed_total_s(lb_attn, cus)
                    + 2 * _composed_total_s(lb_mlp, cus))
            self._cost_cache[key] = cost
        return self._cost_cache[key]

    # -- the two-stage search ----------------------------------------------
    def decide(self, loads: Mapping[str, TenantLoad],
               cfgs: Mapping[str, ModelConfig],
               current: Mapping[str, object],
               num_cus: int,
               classes: Optional[Mapping[str, str]] = None,
               src_lens: Optional[Mapping[str, int]] = None,
               lengths: Optional[Mapping[str, Sequence[int]]] = None,
               spaces: Optional[Mapping[str, TenantDesignSpace]] = None,
               ) -> Tuple[Dict[str, DesignPoint], str]:
        """Return (per-tenant design points, reason).

        Each returned :class:`DesignPoint` carries the tenant's CU grant
        plus its Stage-1-optimal engine knobs (TP degree / slots / bucket
        ladder — ``None`` knobs mean "keep").  Tenants with no load are
        parked (cus 0); returning the ``current`` points means "leave the
        fabric alone".

        ``current`` maps tenant -> applied CU count (int) or applied
        DesignPoint.  ``classes`` maps tenant -> workload class; omitted
        tenants derive from their config (encoder tenancy can't be derived,
        so mixed fabrics pass it explicitly).  ``src_lens`` maps enc-dec
        tenants to their per-slot source capacity (prices the per-step
        cross-attention read).  ``lengths`` maps tenants to recently
        observed job/source lengths and ``spaces`` to their Stage-1 design
        spaces — both fabric-supplied; without a space a tenant is priced
        split-only (its CU count is the whole design point)."""
        classes = dict(classes or {})
        src_lens = dict(src_lens or {})
        lengths = dict(lengths or {})
        spaces = dict(spaces or {})
        for t in cfgs:
            classes.setdefault(t, workload_class_of(cfgs[t]))
        # arena pressure inflates demand: a hot arena means queued work the
        # pending-token count can't see yet
        demand = {t: ld.pending_tokens * (1.0 + ld.arena_utilization)
                  for t, ld in loads.items()}
        busy = [t for t, d in demand.items() if d > 0]

        def concurrency(t: str) -> int:
            return max(loads[t].active + loads[t].queue_depth, 1)

        def split_only_cost(t: str, c: int) -> float:
            if c <= 0:
                return float("inf")
            cost = self.step_cost(cfgs[t], loads[t].active or 1, c,
                                  classes[t], src_len=src_lens.get(t, 0))
            if self.stage1 is not None and spaces:
                # a space-less tenant in a two-stage decide must price in
                # Stage 1's units (seconds per TOKEN: one batched step
                # emits `active` tokens) or the makespan would compare
                # per-step against per-token costs and systematically
                # over-grant the space-less tenant
                cost /= max(loads[t].active, 1)
            return cost

        def stage1_point(t: str, c: int) -> DesignPoint:
            """Stage 1: the tenant's best design point on a c-CU grant."""
            sp = spaces.get(t)
            if self.stage1 is not None and sp is not None:
                return self.stage1.best(cfgs[t], sp, concurrency(t), c,
                                        lengths.get(t, ()),
                                        src_lens.get(t, 0))
            return DesignPoint(cus=max(c, 0), cost=split_only_cost(t, c))

        def as_point(t: str, v) -> DesignPoint:
            """Normalize a ``current`` entry and (re-)price it under the
            current load — the hysteresis baseline."""
            if not isinstance(v, DesignPoint):
                return stage1_point(t, int(v))
            sp = spaces.get(t)
            if self.stage1 is not None and sp is not None and v.cus > 0:
                cost = self.stage1.cost_of(cfgs[t], sp, concurrency(t), v,
                                           lengths.get(t, ()),
                                           src_lens.get(t, 0))
            else:
                cost = split_only_cost(t, v.cus)
            return dataclasses.replace(v, cost=cost)

        cur_points = {t: as_point(t, v) for t, v in current.items()}
        if not busy:
            self.runner_up = None
            self.predicted = None
            return dict(cur_points), "idle"

        # Stage-1 memo: one design-point search per (busy tenant, grant)
        memo: Dict[Tuple[str, int], DesignPoint] = {}

        def point(t: str, c: int) -> DesignPoint:
            if (t, c) not in memo:
                memo[(t, c)] = stage1_point(t, c)
            return memo[(t, c)]

        def makespan(points: Mapping[str, DesignPoint]) -> float:
            worst = 0.0
            for t in busy:
                p = points.get(t)
                cost = p.cost if p is not None else float("inf")
                worst = max(worst, demand[t] * cost)
            return worst

        # Stage 2: split search over Stage-1-optimal design points
        best_pts, best_cost = None, float("inf")
        second_pts, second_cost = None, float("inf")
        for split in _candidate_splits(num_cus, busy, demand):
            pts = {t: point(t, c) for t, c in zip(busy, split)}
            cost = makespan(pts)
            if cost < best_cost:
                second_pts, second_cost = best_pts, best_cost
                best_pts, best_cost = pts, cost
            elif cost < second_cost:
                second_pts, second_cost = pts, cost
        assert best_pts is not None

        cur_cost = makespan(cur_points)
        # JSON-safe telemetry: an admit tick's current makespan is infinite
        # (a parked tenant owes work) — record None, not float('inf')
        self.predicted = {
            "best_s": best_cost,
            "current_s": cur_cost if cur_cost != float("inf") else None}
        if cur_cost == float("inf"):
            self.runner_up = second_pts
            return best_pts, "admit"            # a parked tenant got work
        if cur_cost / max(best_cost, 1e-12) >= self.min_gain:
            self.runner_up = second_pts
            if self._sizes(best_pts) == self._sizes(cur_points):
                # same split, better per-tenant configs: a pure Stage-1
                # delta (slots / TP / ladder) applied with no CU move
                return best_pts, "retune"
            if len(busy) == 1:
                return best_pts, "unify"
            return best_pts, "rebalance"
        # staying put: the best candidate is what we'd switch to next —
        # that's the design worth prewarming while the fabric idles
        self.runner_up = (best_pts
                          if self._sizes(best_pts) != self._sizes(cur_points)
                          else second_pts)
        return dict(cur_points), "hysteresis"

    @staticmethod
    def _sizes(points: Mapping[str, DesignPoint]) -> Dict[str, int]:
        return {t: p.cus for t, p in points.items() if p.cus > 0}


def _compositions(total: int, parts: int):
    """All ways to write ``total`` as ``parts`` positive integers."""
    if parts == 1:
        yield (total,)
        return
    for cuts in itertools.combinations(range(1, total), parts - 1):
        prev, out = 0, []
        for c in cuts:
            out.append(c - prev)
            prev = c
        out.append(total - prev)
        yield tuple(out)


# exhaustive Stage-2-style enumeration is C(num_cus-1, tenants-1): fine on a
# board-scale fabric, explosive on a pod.  Past this budget, fall back to a
# demand-proportional water-filling split (the argmax of the monotone
# makespan model in the common case, computed in O(cus x tenants)).
MAX_ENUMERATED_SPLITS = 20_000


def _candidate_splits(num_cus: int, busy: Sequence[str],
                      demand: Mapping[str, float]):
    if math.comb(num_cus - 1, len(busy) - 1) <= MAX_ENUMERATED_SPLITS:
        yield from _compositions(num_cus, len(busy))
        return
    total = sum(demand[t] for t in busy)
    shares = [max(1, int(num_cus * demand[t] / total)) for t in busy]
    spare = num_cus - sum(shares)
    order = sorted(range(len(busy)), key=lambda i: -demand[busy[i]])
    i = 0
    while spare != 0:                    # hand leftovers to (or claw back
        j = order[i % len(order)]        # from) the most-loaded tenants
        step = 1 if spare > 0 else (-1 if shares[j] > 1 else 0)
        shares[j] += step
        spare -= step
        i += 1
    yield tuple(shares)


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

class ComposedServer:
    """Multi-tenant serving on one composable fabric with live, delta
    recomposition between decode steps.

    Tenants are a *mixed fleet*: each runs the engine of its workload class
    (transformer decode / SSM recurrent decode / encoder embedding /
    enc-dec encode→decode — see ``repro.workloads``), and the policy prices
    each class by its bound resource.  All engines share one fabric-level AOT executable cache
    keyed by (config fingerprint, mesh fingerprint, shapes), so same-config
    tenants reuse each other's warm programs instead of compiling per
    engine.

    With a two-stage :class:`AnalyticalPolicy` (the default) the fabric
    runs the paper's full DSE in the serving loop: each decide tick it
    snapshots per-tenant design spaces and observed job lengths, the policy
    returns Stage-1-optimal design points per tenant (CUs + TP degree +
    slots + bucket ladder), and ``recompose`` applies the deltas live —
    CU moves via ``reshard_to``-style migration, knob changes via
    ``Engine.reconfigure`` (retunes), both re-entering the shared AOT cache
    under the new fingerprints so warm-compile covers the new programs.

    tp: shard each tenant's engine (params + pooled state) over its
        sub-mesh with ``serve_engine_rules`` so granted CUs buy measured
        tokens/s; off -> replicated engines (bit-identical resharding).
    warm: pre-compile a target composition's executables before committing
        a recomposition, so the first post-move step skips the XLA stall.
    prewarm_async: compile candidate compositions in a background thread
        while the old composition keeps serving; the switch commits on a
        later autoscale tick once the executables are ready.  Idle decide
        intervals additionally prewarm the policy's runner-up split
        speculatively, so the *next* plausible recomposition is warm too.
    """

    def __init__(self, mesh, tenants: Sequence[TenantSpec], *,
                 policy: Optional[AnalyticalPolicy] = None,
                 decide_every: int = 4, cu_axis: str = "model",
                 tp: bool = True, warm: bool = True,
                 prewarm_async: bool = False):
        self.composer = MeshComposer(mesh, cu_axis=cu_axis)
        self.policy = policy
        self.decide_every = decide_every
        self.rules = serve_engine_rules() if tp else None
        self.warm = warm
        self.prewarm_async = prewarm_async
        self.specs = {t.name: t for t in tenants}
        self.events: List[RecompositionEvent] = []
        self.step_seconds: Dict[str, List[float]] = {t.name: [] for t in tenants}
        self._stall_probe: Dict[str, RecompositionEvent] = {}
        self._step_no = 0
        self._tokens_emitted: Dict[str, int] = {t.name: 0 for t in tenants}
        self._executor: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._pending_prewarm: Optional[
            Tuple[Dict[str, DesignPoint], str, list]] = None
        # speculative runner-up prewarm bookkeeping
        self.speculative_prewarms = 0
        self._spec_warmed: set = set()
        self._spec_futures: List[concurrent.futures.Future] = []

        # initial composition: equal shares, remainder to the first tenants
        n = len(tenants)
        if n > self.composer.num_cus:
            raise ValueError(
                f"{n} tenants need at least {n} CUs; the fabric has "
                f"{self.composer.num_cus} (on CPU, fake more host devices "
                f"with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        base, extra = divmod(self.composer.num_cus, n)
        sizes = {t.name: base + (1 if i < extra else 0)
                 for i, t in enumerate(tenants)}
        self.subs, _ = self.composer.recompose({}, sizes)

        # fabric-level executable cache: shared across every tenant engine
        self.exec_cache = ExecutableCache(capacity=128)
        self.cfgs: Dict[str, ModelConfig] = {}
        self.classes: Dict[str, str] = {}
        self.src_lens: Dict[str, int] = {}
        self.engines: Dict[str, Engine] = {}
        for spec in tenants:
            cfg = (get_reduced(spec.arch) if spec.reduced
                   else get_config(spec.arch))
            model = build_model(cfg)
            params = model.init(jax.random.key(spec.seed))  # annotated: TP
            wclass = (workload_class_of(cfg) if spec.workload == "auto"
                      else spec.workload)
            self.cfgs[spec.name] = cfg
            self.classes[spec.name] = wclass
            if wclass == ENCDEC:
                # prices the per-step cross-attention source-cache read
                self.src_lens[spec.name] = (spec.serve.max_src_len
                                            or spec.serve.max_len)
            self.engines[spec.name] = build_engine(
                wclass, model, params, spec.serve,
                mesh=self.subs[spec.name], rules=self.rules,
                exec_cache=self.exec_cache)

    # ------------------------------------------------------------------
    def submit(self, tenant: str, tokens, max_new_tokens: int = 16,
               **kwargs) -> int:
        """Route one request to ``tenant``'s engine; returns its rid.
        Extra keywords pass through to the engine's submit (e.g. the
        enc-dec engine's forced-decoding ``prefix=``)."""
        return self.engines[tenant].submit(tokens, max_new_tokens, **kwargs)

    def sizes(self) -> Dict[str, int]:
        """Current composition: tenant -> CUs held (0 = parked)."""
        return {t: len(self.subs[t].cu_ids) if t in self.subs else 0
                for t in self.engines}

    def loads(self) -> Dict[str, TenantLoad]:
        """Per-tenant load signals sampled from the engines (the policy's
        ``decide`` inputs)."""
        return {t: TenantLoad(eng.pending_tokens(), eng.queue_depth,
                              eng.active_count, eng.arena_utilization())
                for t, eng in self.engines.items()}

    # ------------------------------------------------------------------
    def step(self) -> Dict[str, List[Tuple[int, int]]]:
        """One fabric iteration: step every composed (non-parked) tenant,
        then maybe recompose.  Returns per-tenant emitted (rid, token)."""
        emitted = {}
        for t, eng in self.engines.items():
            if t not in self.subs:
                continue                      # parked: no CUs this interval
            probe = self._stall_probe.pop(t, None)
            busy = eng.has_work
            q0 = eng.queue_depth
            t0 = time.monotonic()
            out = eng.step()
            if probe is not None:
                # pipelined dispatch returns before the step executes; the
                # probed post-move step must cover the whole step (compile
                # when cold + execution), not just the async dispatch
                eng.sync()
            dt = time.monotonic() - t0
            if probe is not None:
                probe.post_step_seconds[t] = dt
            elif busy and eng.queue_depth == q0:
                # decode percentiles only: idle no-op steps would deflate
                # them; admission steps (blocking prefill) and probed
                # full-sync steps would inflate them
                times = self.step_seconds[t]
                times.append(dt)
                if len(times) > 10_000:
                    del times[:5_000]
            self._tokens_emitted[t] += len(out)
            if out:
                emitted[t] = out
        self._step_no += 1
        if (self.policy is not None and self.decide_every > 0
                and self._step_no % self.decide_every == 0):
            self.autoscale()
        return emitted

    # ------------------------------------------------------------------
    # serving-side DSE plumbing (Stage-1 inputs, applied design points)
    # ------------------------------------------------------------------
    def _design_spaces(self) -> Optional[Dict[str, TenantDesignSpace]]:
        """Per-tenant Stage-1 search bounds, snapshotted from the engines
        each decide tick (None when the policy is split-only)."""
        if self.policy is None or self.policy.stage1 is None:
            return None
        out = {}
        for t, eng in self.engines.items():
            d = eng.design()
            arena = getattr(eng, "arena", None)
            per_slot = (arena.capacity // max(d["slots"], 1)
                        if arena is not None else 0)
            out[t] = TenantDesignSpace(
                wclass=self.classes[t],
                max_len=eng.cfg.max_len,
                max_src=getattr(eng, "_max_src", 0),
                base_slots=d["slots"],
                base_buckets=tuple(d["buckets"] or ()),
                base_tp=d["tp"],
                per_slot_elems=per_slot,
                tp_allowed=self.rules is not None)
        return out

    def _applied_points(self) -> Dict[str, DesignPoint]:
        """The live composition as applied design points (the policy's
        hysteresis baseline; parked tenants carry cus 0)."""
        out = {}
        for t, eng in self.engines.items():
            c = len(self.subs[t].cu_ids) if t in self.subs else 0
            d = eng.design()
            out[t] = DesignPoint(
                cus=c, tp=d["tp"], slots=d["slots"],
                buckets=tuple(d["buckets"]) if d["buckets"] else None)
        return out

    def _knob_delta(self, t: str, p: DesignPoint) -> Dict[str, object]:
        """Engine-knob overrides that actually change tenant ``t``'s
        configuration when design point ``p`` commits (None knobs keep; a
        slot shrink clamps at the live occupancy — streams are migrated,
        never evicted)."""
        eng = self.engines[t]
        d = eng.design()
        out: Dict[str, object] = {}
        if p.tp is not None:
            want = min(p.tp, p.cus)
            would = min(d["tp"], p.cus) if d["tp"] else p.cus
            if want != would:
                out["tp"] = p.tp
        if p.slots is not None:
            want_s = max(p.slots, eng.active_count)
            if want_s != d["slots"]:
                out["slots"] = want_s
        if p.buckets is not None and d["buckets"] is not None \
                and tuple(p.buckets) != tuple(d["buckets"]):
            out["buckets"] = tuple(p.buckets)
        return out

    def _no_change(self, points: Mapping[str, DesignPoint]) -> bool:
        """True when applying ``points`` would change nothing: same CU
        split AND no engine-knob delta on any composed tenant."""
        sizes = {t: p.cus for t, p in points.items() if p.cus > 0}
        if sizes != self._normalized(self.sizes()):
            return False
        return all(not self._knob_delta(t, p) for t, p in points.items()
                   if p.cus > 0)

    def autoscale(self) -> Optional[RecompositionEvent]:
        """Consult the policy; apply the recomposition it asks for.

        With ``prewarm_async`` the switch is two-phase: kick background
        compiles for the chosen composition (at its target design points),
        keep serving on the current one, and commit on a later tick once
        every executable is warm."""
        if self._pending_prewarm is not None:
            target, reason, futures = self._pending_prewarm
            if not all(f.done() for f in futures):
                return None               # still compiling in the background
            self._pending_prewarm = None
            for f in futures:
                f.result()                # surface background build errors
            if self._no_change(target):
                return None
            return self.recompose(target, reason=reason, overlapped=True)

        target, reason = self.policy.decide(
            self.loads(), self.cfgs, self._applied_points(),
            self.composer.num_cus, classes=self.classes,
            src_lens=self.src_lens,
            lengths={t: eng.recent_lengths()
                     for t, eng in self.engines.items()},
            spaces=self._design_spaces())
        target = {t: p for t, p in target.items() if p.cus > 0}
        if self._no_change(target):
            # idle decide interval: nothing committed — speculatively warm
            # the policy's runner-up design so the *next* plausible switch
            # is already compiled when its gain clears hysteresis
            self._speculative_prewarm()
            return None
        if self.warm and self.prewarm_async:
            futures = self._warm_design(target)
            self._pending_prewarm = (target, reason, futures)
            return None
        return self.recompose(target, reason=reason)

    def _warm_design(self, points: Mapping[str, DesignPoint]) -> list:
        """Submit background warm compiles for a candidate design — every
        tenant a CU move or a knob delta would touch, each warmed at its
        target design point's overrides.  Returns the futures."""
        new_subs, delta = self.composer.recompose(
            self.subs, {t: p.cus for t, p in points.items()})
        touched = set(delta.moved + delta.admitted)
        touched |= {t for t, p in points.items() if self._knob_delta(t, p)}
        return [self._pool().submit(
            lambda t=t: self.engines[t].warm_compile(
                new_subs[t], **self._knob_delta(t, points[t])))
            for t in sorted(touched)]

    def _speculative_prewarm(self) -> None:
        """Warm the runner-up candidate design in the background.

        Reuses the ``prewarm_async`` machinery (same single-worker pool, so
        speculative compiles never contend with a committed prewarm) and is
        gated on it: synchronous fabrics shouldn't burn serving time on
        compositions that may never commit.  Each distinct runner-up —
        keyed on the FULL design point (composition + per-tenant config) —
        is warmed once; ``warm_compile`` itself is idempotent on the shared
        executable cache."""
        # surface errors from (and drop) finished speculative compiles
        pending = []
        for f in self._spec_futures:
            if f.done():
                f.result()
            else:
                pending.append(f)
        self._spec_futures = pending
        ru = self.policy.runner_up if self.policy is not None else None
        if not (self.warm and self.prewarm_async and ru):
            return
        ru = {t: p for t, p in ru.items() if p.cus > 0}
        if not ru or self._no_change(ru):
            return
        key = tuple(sorted((t, p.cus, p.tp, p.slots,
                            tuple(p.buckets or ())) for t, p in ru.items()))
        if key in self._spec_warmed:
            return
        if len(self._spec_warmed) > 64:      # long-lived fabric: re-warm ok
            self._spec_warmed.clear()
        futures = self._warm_design(ru)
        if not futures:
            return
        self._spec_warmed.add(key)
        self.speculative_prewarms += 1
        self._spec_futures.extend(futures)

    @staticmethod
    def _normalized(sizes: Mapping[str, int]) -> Dict[str, int]:
        return {t: s for t, s in sizes.items() if s > 0}

    def _pool(self) -> concurrent.futures.ThreadPoolExecutor:
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="prewarm")
        return self._executor

    def recompose(self, target_sizes: Mapping[str, object], *,
                  reason: str = "manual",
                  overlapped: bool = False) -> RecompositionEvent:
        """Live recomposition: grow/shrink/admit/park tenants AND apply
        per-tenant design-point deltas (DSE Stage-1 knobs).

        ``target_sizes`` maps tenant -> CU count (int, the pre-DSE contract)
        or DesignPoint (CUs + TP degree + slots + bucket ladder).  Only
        moved tenants pay a state migration; unchanged ones keep their
        devices — but a tenant whose knobs changed with its CU set intact
        is *retuned* in place (``Engine.reconfigure``, draining nothing:
        live slots migrate inside the resize).  With warming on, the target
        composition's executables are compiled at the target design points
        before any state moves, so the post-move step is stall-free."""
        before = self.sizes()
        points = {t: (v if isinstance(v, DesignPoint)
                      else DesignPoint(cus=int(v)))
                  for t, v in target_sizes.items()}
        sizes = {t: p.cus for t, p in points.items()}
        new_subs, delta = self.composer.recompose(self.subs, sizes)
        knobs = {t: self._knob_delta(t, p) for t, p in points.items()
                 if p.cus > 0}
        moved = delta.moved + delta.admitted
        retuned = tuple(t for t in knobs
                        if knobs[t] and t not in moved)
        touched = moved + retuned
        warm_s, warm_builds = 0.0, 0
        if self.warm:
            w0 = time.monotonic()
            for t in touched:
                warm_builds += self.engines[t].warm_compile(
                    new_subs[t], **knobs.get(t, {}))
            warm_s = time.monotonic() - w0
        t0 = time.monotonic()
        applied: Dict[str, Dict] = {}
        for t in touched:
            eng = self.engines[t]
            out = eng.reconfigure(new_subs[t] if t in moved else None,
                                  **knobs.get(t, {}))
            if out:
                applied[t] = out
            eng.sync()
        self.subs = new_subs
        # the committed move changes device assignments, so a previously
        # prewarmed runner-up design now maps to different sub-meshes
        # (different mesh fingerprints): let it be warmed again
        self._spec_warmed.clear()
        seconds = time.monotonic() - t0
        event = RecompositionEvent(
            step=self._step_no, sizes_before=before, sizes_after=self.sizes(),
            moved=moved, unchanged=delta.unchanged,
            parked=delta.evicted, seconds=seconds, reason=reason,
            retuned=retuned, design=applied,
            warm_compile_seconds=warm_s, warm_builds=warm_builds,
            overlapped=overlapped)
        for t in touched:
            self._stall_probe[t] = event
        self.events.append(event)
        return event

    def unify(self, tenant: str, *, reason: str = "unify"
              ) -> RecompositionEvent:
        """The monolithic composition: the whole fabric for one tenant."""
        return self.recompose({tenant: self.composer.num_cus}, reason=reason)

    # ------------------------------------------------------------------
    def pending(self) -> int:
        """Total owed work units across tenants (decode steps / prompt
        tokens by class)."""
        return sum(ld.pending_tokens for ld in self.loads().values())

    def drain(self, max_steps: int = 10_000) -> Dict[str, Dict[int, List[int]]]:
        """Step until every tenant's queue, slots and in-flight dispatches
        are empty; returns per-tenant {rid: tokens} for all requests seen."""
        for _ in range(max_steps):
            busy = [t for t, eng in self.engines.items() if eng.has_work]
            if not busy:
                break
            if any(t not in self.subs for t in busy) and self.policy is None:
                # no policy to re-admit a parked tenant: give it CUs back
                self.recompose({t: 0 for t in self.engines} |
                               {t: self.composer.num_cus // max(len(busy), 1)
                                for t in busy}, reason="drain")
            self.step()
        return self.results()

    def results(self) -> Dict[str, Dict[int, List[int]]]:
        """Per-tenant ``snapshot()``: every request seen -> emitted units
        (tokens, or embedding components for encoder tenants)."""
        return {t: eng.snapshot() for t, eng in self.engines.items()}

    def decode_step_ms(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant decode step latency percentiles (milliseconds)."""
        out = {}
        for t, times in self.step_seconds.items():
            if not times:
                continue
            arr = np.asarray(times) * 1e3
            out[t] = {"p50": round(float(np.percentile(arr, 50)), 3),
                      "p95": round(float(np.percentile(arr, 95)), 3),
                      "n": len(times)}
        return out

    def stats(self) -> Dict[str, object]:
        """Fabric-wide telemetry: per-tenant emitted units and classes,
        recomposition timings (seconds), per-tenant migrations and cold
        builds, shared-cache hit counts, speculative prewarms, decode step
        latency percentiles (ms) and the current device composition."""
        return {
            "steps": self._step_no,
            "workload_classes": dict(self.classes),
            # per-tenant emitted units: tokens for decode/ssm tenants,
            # completed sequences (embeddings) for encoder tenants
            "tokens_emitted": dict(self._tokens_emitted),
            # applied design points (the serving DSE's Stage-1 knobs)
            "design_points": {
                t: {"cus": len(self.subs[t].cu_ids) if t in self.subs else 0,
                    "tp": d["tp"], "slots": d["slots"],
                    "buckets": list(d["buckets"]) if d["buckets"] else None}
                for t, d in ((t, eng.design())
                             for t, eng in self.engines.items())},
            "retunes": sum(len(e.retuned) for e in self.events),
            "recompositions": len(self.events),
            "recompose_seconds": [round(e.seconds, 4) for e in self.events],
            "warm_compile_seconds": [round(e.warm_compile_seconds, 4)
                                     for e in self.events],
            "reshards_per_tenant": {t: eng.reshard_count
                                    for t, eng in self.engines.items()},
            "compile_builds": {t: eng.compile_builds
                               for t, eng in self.engines.items()},
            "shared_exec_cache": {"builds": self.exec_cache.builds,
                                  "hits": self.exec_cache.hits},
            "speculative_prewarms": self.speculative_prewarms,
            "decode_step_ms": self.decode_step_ms(),
            "composition": {t: list(self.subs[t].cu_ids)
                            for t in self.subs},
        }
