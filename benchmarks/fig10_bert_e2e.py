"""Fig. 10 reproduction: end-to-end BERT-32..512 throughput with the FILCO
feature ablation — CHARM, RSN, FILCO(FP), FILCO(FP,FMF), FILCO(FP,FMF,FMV).

Each system runs the full two-stage DSE (Stage-1 mode tables on its design
point, Stage-2 GA schedule) so the numbers include cross-layer overlap on
composed CU groups, exactly like the paper's end-to-end flow.
"""
from __future__ import annotations

from repro.common.platform import VCK190
from repro.configs.paper_workloads import bert
from repro.core.analytical import (best_accel_latency, charm_monolithic,
                                   filco_ablation, filco_vck190, rsn_overlay)
from repro.core.dse import run_dse
from repro.core.ga import GAConfig

BERTS = [32, 64, 128, 256, 512]

ABLATIONS = [
    ("FILCO(FP)", filco_ablation(fp=True)),
    ("FILCO(FP,FMF)", filco_ablation(fp=True, fmf=True)),
    ("FILCO(FP,FMF,FMV)", filco_ablation(fp=True, fmf=True, fmv=True)),
]


def _dse_throughput(wl, accel, seed=0):
    res = run_dse(wl, accel, VCK190, solver="ga", max_modes=5,
                  ga_config=GAConfig(population=16, generations=25,
                                     seed=seed, patience=10))
    return wl.total_flops / res.makespan


def _routed_throughput(wl, accels):
    t = sum(best_accel_latency(accels, VCK190, l.m, l.k, l.n).total_s
            for l in wl.layers)
    return wl.total_flops / t


def run(check: bool = True, layers: int = 2):
    """layers=2 keeps the GA tractable on 1 CPU; shapes per layer are what
    drive the figure (every BERT layer is identical)."""
    rows = []
    for seq in BERTS:
        wl = bert(seq, layers=layers)
        row = {"bert": f"BERT-{seq}"}
        row["CHARM"] = _routed_throughput(wl, charm_monolithic()) / 1e9
        row["RSN"] = _routed_throughput(wl, rsn_overlay()) / 1e9
        for name, acc in ABLATIONS:
            row[name] = _dse_throughput(wl, acc) / 1e9
        rows.append(row)
    small, large = rows[0], rows[-1]
    summary = {
        "small_fmv_gain": small["FILCO(FP,FMF,FMV)"] / small["FILCO(FP)"],
        "small_vs_baselines":
            small["FILCO(FP,FMF,FMV)"] / max(small["CHARM"], small["RSN"]),
        "large_vs_baselines":
            large["FILCO(FP,FMF,FMV)"] / max(large["CHARM"], large["RSN"]),
    }
    if check:
        # small BERT: communication-bound; FMV's padding elimination is the
        # decisive feature (paper §4.3)
        assert summary["small_fmv_gain"] >= 1.2, summary
        assert summary["small_vs_baselines"] >= 1.3, summary
        # large BERT: everyone healthy, FILCO still ahead
        assert summary["large_vs_baselines"] >= 1.0, summary
        for row in rows:
            assert row["FILCO(FP,FMF,FMV)"] >= row["FILCO(FP,FMF)"] * 0.99
            assert row["FILCO(FP,FMF)"] >= row["FILCO(FP)"] * 0.99
    return {"rows": rows, "summary": summary}


def main():
    res = run()
    cols = ["CHARM", "RSN", "FILCO(FP)", "FILCO(FP,FMF)", "FILCO(FP,FMF,FMV)"]
    for r in res["rows"]:
        print(f"fig10,{r['bert']},," +
              ",".join(f"{c}={r[c]:.1f}GF/s" for c in cols))
    s = res["summary"]
    print(f"fig10_summary,small_fmv_gain={s['small_fmv_gain']:.2f}x,"
          f"small_vs_base={s['small_vs_baselines']:.2f}x,"
          f"large_vs_base={s['large_vs_baselines']:.2f}x")
    return res


if __name__ == "__main__":
    main()
