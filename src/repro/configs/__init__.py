"""Architecture registry: ``--arch <id>`` resolution.

``get_config(arch_id)`` / ``get_reduced(arch_id)`` return the full production
config and the CPU smoke-test config for each assigned architecture.
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import (
    ALL_CELLS,
    CELLS_BY_NAME,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    ShapeCell,
    cells_for,
)

# arch id -> module name
_MODULES: Dict[str, str] = {
    "hymba-1.5b": "hymba_1_5b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "arctic-480b": "arctic_480b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-34b": "granite_34b",
    "minitron-4b": "minitron_4b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen2.5-32b": "qwen2_5_32b",
    "chameleon-34b": "chameleon_34b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def _load(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _load(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _load(arch).REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ALL_CELLS", "CELLS_BY_NAME", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "ARCH_IDS", "ModelConfig", "MoEConfig", "MLAConfig",
    "SSMConfig", "ShapeCell", "cells_for", "get_config", "get_reduced",
    "all_configs",
]
