"""DSE tests: exact solver optimality (vs brute force, property-based), GA
validity + optimality gap, schedule validator, Stage-1 mode tables."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.platform import VCK190
from repro.configs.paper_workloads import MLP_S, bert
from repro.core.analytical import filco_vck190
from repro.core.dse import run_dse
from repro.core.ga import GAConfig, decode_order, solve_ga
from repro.core.milp import (build_milp, check_against_milp,
                             solve_brute_force, solve_exact)
from repro.core.modes import build_problem, enumerate_modes
from repro.core.schedule import (InvalidSchedule, Mode, Placement, Schedule,
                                 ScheduleProblem, list_schedule, validate)


def random_problem(rng, n_lo=3, n_hi=6, modes_hi=3):
    n = int(rng.integers(n_lo, n_hi))
    deps = tuple(tuple(int(j) for j in range(i) if rng.random() < 0.4)
                 for i in range(n))
    modes = tuple(
        tuple(Mode(fmus=int(rng.integers(3, 6)), cus=int(rng.integers(1, 4)),
                   latency=float(rng.uniform(1, 10)))
              for _ in range(int(rng.integers(1, modes_hi + 1))))
        for _ in range(n))
    return ScheduleProblem(deps, modes, f_max=8, c_max=4)


@pytest.mark.parametrize("seed", range(6))
def test_exact_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng)
    bf = solve_brute_force(prob)
    ex = solve_exact(prob, time_limit_s=30)
    assert ex.optimal
    assert abs(bf.makespan - ex.makespan) < 1e-9
    validate(prob, ex.schedule)
    assert check_against_milp(prob, ex.schedule)


@pytest.mark.parametrize("seed", range(4))
def test_ga_produces_valid_near_optimal_schedules(seed):
    rng = np.random.default_rng(100 + seed)
    prob = random_problem(rng, n_lo=5, n_hi=9, modes_hi=4)
    ga = solve_ga(prob, GAConfig(population=32, generations=60, seed=seed))
    validate(prob, ga.schedule)
    ex = solve_exact(prob, time_limit_s=20, incumbent=ga.schedule)
    # GA within 25% of optimum on small instances (paper: ~3% at scale)
    assert ga.makespan <= ex.makespan * 1.25 + 1e-9
    assert ga.makespan >= ex.makespan - 1e-9


def test_ga_decode_respects_dependencies():
    rng = np.random.default_rng(0)
    prob = random_problem(rng, n_lo=6, n_hi=10)
    enc = rng.random(prob.num_layers)
    order = decode_order(prob, enc)
    seen = set()
    for i in order:
        assert all(d in seen for d in prob.deps[i])
        seen.add(i)


def test_validator_catches_violations():
    prob = ScheduleProblem(
        deps=((), (0,)),
        modes=((Mode(3, 1, 5.0),), (Mode(3, 1, 5.0),)),
        f_max=8, c_max=4)
    ok = list_schedule(prob, [0, 1], [0, 0])
    validate(prob, ok)
    # dependency violation
    bad = Schedule((
        Placement(0, 0, 0.0, 5.0, (0, 1, 2), (0,)),
        Placement(1, 0, 2.0, 7.0, (3, 4, 5), (1,)),
    ))
    with pytest.raises(InvalidSchedule):
        validate(prob, bad)
    # unit overlap violation (same FMU, overlapping, independent layers)
    prob2 = ScheduleProblem(
        deps=((), ()),
        modes=((Mode(3, 1, 5.0),), (Mode(3, 1, 5.0),)),
        f_max=8, c_max=4)
    bad2 = Schedule((
        Placement(0, 0, 0.0, 5.0, (0, 1, 2), (0,)),
        Placement(1, 0, 1.0, 6.0, (2, 3, 4), (1,)),
    ))
    with pytest.raises(InvalidSchedule):
        validate(prob2, bad2)
    # wrong unit count (Eq. 5)
    bad3 = Schedule((
        Placement(0, 0, 0.0, 5.0, (0, 1), (0,)),
        Placement(1, 0, 5.0, 10.0, (3, 4, 5), (1,)),
    ))
    with pytest.raises(InvalidSchedule):
        validate(prob, bad3)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_list_schedule_always_valid(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng, n_lo=4, n_hi=10, modes_hi=4)
    order = prob.topo_order()
    mc = [int(rng.integers(0, len(m))) for m in prob.modes]
    sched = list_schedule(prob, order, mc)
    validate(prob, sched)
    assert sched.makespan >= prob.critical_path_lb() - 1e-9


def test_stage1_modes_are_pareto_and_feasible():
    wl = MLP_S
    accel = filco_vck190()
    modes = enumerate_modes(wl.layers[0], accel, VCK190, f_max=16, c_max=8)
    assert modes
    for m in modes:
        assert 3 <= m.fmus <= 16 and 1 <= m.cus <= 8 and m.latency > 0
    for i, a in enumerate(modes):
        for b in modes[i + 1:]:
            dominated = (a.fmus <= b.fmus and a.cus <= b.cus and
                         a.latency <= b.latency)
            assert not dominated, "stage-1 kept a dominated mode"


def test_dse_end_to_end_bert_layer():
    wl = bert(64, layers=1)
    res = run_dse(wl, filco_vck190(), solver="ga", max_modes=6,
                  ga_config=GAConfig(population=16, generations=20, seed=0))
    validate(res.problem, res.schedule)
    assert res.makespan > 0
    # plan covers every layer exactly once, in dependency order
    layers = sorted(p.layer for p in res.plan.layers)
    assert layers == list(range(len(wl.layers)))
    by_layer = {p.layer: p for p in res.plan.layers}
    for i, l in enumerate(wl.layers):
        for d in l.deps:
            assert by_layer[d].end <= by_layer[i].start + 1e-9


def test_milp_formulation_size():
    rng = np.random.default_rng(1)
    prob = random_problem(rng, n_lo=4, n_hi=5)
    f = build_milp(prob)
    n = prob.num_layers
    assert f.num_continuous == 2 * n + 1
    kinds = {c[0] for c in f.constraints}
    assert {"eq1", "eq2a", "eq2b", "eq3a", "eq3b", "eq5f", "eq5c",
            "eq6"} <= kinds | {"eq2a"}
