"""Serving-fabric benchmark: traffic-driven multi-tenant recomposition.

Emits machine-readable ``BENCH_serve_fabric.json`` covering the three claims
the serving path makes:

* per-tenant throughput and per-step decode latency (p50/p95) under the
  policy-driven fabric with tensor-parallel engines and warm recomposition;
* the measured tokens/s-vs-CU-count scaling curve (strictly monotone across
  1 -> 2 -> 4 CUs is the acceptance bar: allocated CUs must buy throughput,
  otherwise the analytical policy's predicted gains are fiction).  CUs buy
  KV-cache capacity — the pooled cache shards over the sub-mesh, so slots
  scale with the grant while weights-bound decode keeps per-step latency
  ~flat (the curve reports both);
* warm-vs-cold recomposition stall: the first post-move decode step with
  the target composition's executables pre-compiled vs with a cold cache
  (where the XLA recompile lands);
* the ``mixed`` heterogeneous scenario: transformer decode + mamba SSM +
  encoder + seamless enc-dec tenants on one fabric under class-aware CU
  costing, with per-class throughput (tokens/s — including enc-dec decode
  tokens/s — or seqs/s for the encoder) and recomposition stalls.

Each scenario is the launcher itself (``repro.launch.serve``) run in a
subprocess because it fakes 8 host devices and the device count is locked
at first jax init.

Run: PYTHONPATH=src python -m benchmarks.serve_fabric
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

OUT_PATH = pathlib.Path("BENCH_serve_fabric.json")

_FABRIC = [sys.executable, "-m", "repro.launch.serve", "--fabric",
           "--arch", "minitron-4b", "--arch", "qwen2.5-32b",
           "--reduced", "--requests", "4", "--max-new-tokens", "12",
           "--seed", "0"]
# heterogeneous fleet: one tenant per workload class (transformer decode +
# mamba SSM + encoder embedding + seamless enc-dec) under class-aware CU
# costing
_MIXED = [sys.executable, "-m", "repro.launch.serve", "--fabric",
          "--scenario", "mixed", "--reduced", "--requests", "4",
          "--max-new-tokens", "12", "--seed", "0"]
_SCALING = [sys.executable, "-m", "repro.launch.serve", "--scaling-curve",
            "--scale-sizes", "1", "2", "4", "--scale-steps", "10",
            "--seed", "0"]


def _run(cmd):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                         env=env)
    if out.returncode != 0:
        raise RuntimeError(f"scenario {cmd[3:]} failed:\n"
                           f"{out.stdout[-2000:]}\n{out.stderr[-4000:]}")
    return json.loads(out.stdout[out.stdout.index("{"):])


def _stalls(stats):
    return [s for e in stats["events"]
            for s in e["post_step_seconds"].values()]


def main() -> None:
    warm = _run(_FABRIC)
    cold = _run(_FABRIC + ["--no-warm"])
    mixed = _run(_MIXED)
    scaling = _run(_SCALING)

    wall_s = warm["wall_s"]
    recompose_s = [e["seconds"] for e in warm["events"]]
    warm_stall = _stalls(warm)
    cold_stall = _stalls(cold)
    warm_compile_s = [e["warm_compile_seconds"] for e in warm["events"]]
    warm_max = max(warm_stall, default=0.0)
    cold_max = max(cold_stall, default=0.0)
    record = {
        "bench": "serve_fabric",
        "devices": 8,
        "tensor_parallel": True,
        "decode_steps": warm["decode_steps"],
        "wall_s": wall_s,
        "tokens_emitted": warm["tokens_emitted"],
        "tokens_per_s_per_tenant": {
            t: round(n / wall_s, 2)
            for t, n in warm["tokens_emitted"].items()},
        "decode_step_ms": warm["decode_step_ms"],
        "recompositions": warm["recompositions"],
        "recompose_reasons": [e["reason"] for e in warm["events"]],
        "time_to_recompose_s": {
            "migration_each": [round(s, 4) for s in recompose_s],
            "migration_mean": round(
                sum(recompose_s) / max(len(recompose_s), 1), 4),
            # ahead-of-time compiles performed BEFORE each switch committed
            # (off the post-move path; overlappable via --prewarm-async)
            "warm_compile_each": [round(s, 4) for s in warm_compile_s],
        },
        # the honest cost of a recomposition: the first post-move step.
        # cold = executable cache empty (the XLA recompile lands here);
        # warm = target composition pre-compiled before the switch.
        "recomposition_stall_s": {
            "warm_each": [round(s, 4) for s in warm_stall],
            "warm_max": round(warm_max, 4),
            "cold_each": [round(s, 4) for s in cold_stall],
            "cold_max": round(cold_max, 4),
            "cold_over_warm_max": round(cold_max / warm_max, 1)
            if warm_max else None,
        },
        # heterogeneous fleet: one tenant per workload class on one fabric,
        # class-aware costing (decode bandwidth / SSM state bandwidth /
        # encoder compute).  Throughput is tokens/s for decode+ssm tenants
        # and seqs/s (completed embeddings) for the encoder tenant.
        "mixed": {
            "tenants": mixed["tenants"],
            "workload_classes": mixed["workload_classes"],
            "decode_steps": mixed["decode_steps"],
            "wall_s": mixed["wall_s"],
            "per_class_throughput": mixed["per_class_throughput"],
            "recompositions": mixed["recompositions"],
            "recompose_reasons": [e["reason"] for e in mixed["events"]],
            "recomposition_stall_s": {
                "each": [round(s, 4) for s in _stalls(mixed)],
                "max": round(max(_stalls(mixed), default=0.0), 4),
            },
        },
        # measured counterpart of the policy's analytical speedup: decode
        # tokens/s as the same tenant's sub-mesh grows
        "scaling_curve": {
            "model": scaling["bench_model"],
            "slots_by_cus": scaling["slots_by_cus"],
            "tokens_per_s_by_cus": scaling["tokens_per_s_by_cus"],
            "step_ms_by_cus": scaling["step_ms_by_cus"],
            "monotone_1_2_4": scaling["monotone"],
        },
    }
    OUT_PATH.write_text(json.dumps(record, indent=1) + "\n")
    for key in ("decode_steps", "recompositions", "wall_s"):
        print(f"serve_fabric,{key},{record[key]}")
    for t, tps in record["tokens_per_s_per_tenant"].items():
        print(f"serve_fabric,tokens_per_s[{t}],{tps}")
    for t, tp in record["mixed"]["per_class_throughput"].items():
        print(f"serve_fabric,mixed_{tp['unit']}[{t}],{tp['value']}")
    print(f"serve_fabric,mixed_recompositions,"
          f"{record['mixed']['recompositions']}")
    for cus, tps in record["scaling_curve"]["tokens_per_s_by_cus"].items():
        print(f"serve_fabric,scaling_tokens_per_s[{cus}cu],{tps}")
    print(f"serve_fabric,scaling_monotone,"
          f"{record['scaling_curve']['monotone_1_2_4']}")
    print(f"serve_fabric,migration_mean_s,"
          f"{record['time_to_recompose_s']['migration_mean']}")
    print(f"serve_fabric,stall_warm_max_s,"
          f"{record['recomposition_stall_s']['warm_max']}")
    print(f"serve_fabric,stall_cold_max_s,"
          f"{record['recomposition_stall_s']['cold_max']}")
    print(f"# wrote {OUT_PATH.resolve()}")


if __name__ == "__main__":
    main()
