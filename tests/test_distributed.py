"""Distributed-feature tests under an 8-host-device subprocess: sharded
training step, elastic checkpoint resharding, compressed cross-pod psum,
mesh composition.  Each scenario runs in its own subprocess because the
device count must be fixed before jax initializes."""
import json
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
import numpy as np
"""


def _run(body: str, timeout=900):
    out = subprocess.run([sys.executable, "-c",
                          _PRELUDE + textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = _run("""
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.distribution import partitioning as part
    from repro.optim import make_optimizer
    from repro.train.trainer import TrainConfig, make_train_step, \\
        setup_sharded_state
    from repro.launch.mesh import fit_spec

    cfg = get_reduced("qwen2.5-32b")
    model = build_model(cfg)
    opt = make_optimizer(cfg.optimizer)
    tc = TrainConfig(steps=4, lr=1e-3, warmup=1)
    step = make_train_step(model, opt, tc)
    batch = {
        "tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(4, 16)), jnp.int32),
    }
    batch["labels"] = batch["tokens"]

    # single-device reference
    params0 = part.strip(model.init(jax.random.key(0)))
    opt0 = opt.init(params0)
    p1, o1, m1 = step(params0, opt0, jnp.asarray(0), batch)

    # sharded on a (2 data, 4 model) mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = part.train_rules(sequence_parallel=False)
    params, opt_state, psh, osh = setup_sharded_state(
        model, opt, mesh, rules, jax.random.key(0))
    with mesh:
        p2, o2, m2 = jax.jit(step)(params, opt_state, jnp.asarray(0), batch)
    diff = max(float(jnp.abs(a.astype(jnp.float32) -
                             b.astype(jnp.float32)).max())
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                      "param_diff": diff}))
    """)
    assert abs(res["loss1"] - res["loss2"]) < 5e-2
    assert res["param_diff"] < 5e-2


def test_elastic_checkpoint_reshard():
    res = _run("""
    import tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import checkpoint as ck

    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    sharded = jax.device_put(
        tree, {"w": NamedSharding(mesh_a, P("data", "model"))})
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, sharded, extra={"mesh": [2, 4]})
        # restore onto a DIFFERENT mesh shape (elastic restart)
        mesh_b = jax.make_mesh((4, 2), ("data", "model"))
        got, extra = ck.restore(
            d, 1, tree,
            shardings={"w": NamedSharding(mesh_b, P("model", "data"))})
        ok = bool(jnp.all(got["w"] == tree["w"]))
        nshards = len(got["w"].sharding.device_set)
    print(json.dumps({"ok": ok, "shards": nshards,
                      "saved_mesh": extra["mesh"]}))
    """)
    assert res["ok"] and res["shards"] == 8


def test_compressed_psum_cross_pod():
    res = _run("""
    from functools import partial
    from repro.optim import compressed_psum, ErrorFeedback

    shard_map = getattr(jax, "shard_map", None)  # jax >= 0.5 spelling
    if shard_map is None:
        from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((8,), ("pod",))
    x = np.random.default_rng(1).normal(size=(8, 64)).astype(np.float32)

    @partial(shard_map, mesh=mesh,
             in_specs=jax.sharding.PartitionSpec("pod"),
             out_specs=jax.sharding.PartitionSpec("pod"))
    def reduce_compressed(xs):
        return compressed_psum(xs[0], "pod")[None]

    got = reduce_compressed(x)
    want = x.mean(0)
    err = float(np.abs(np.asarray(got)[0] - want).max())
    scale = float(np.abs(x).max()) / 127.0
    print(json.dumps({"err": err, "tol": 2 * scale}))
    """)
    assert res["err"] <= res["tol"]


def test_mesh_composer_partitions_devices():
    res = _run("""
    from repro.core.composer import MeshComposer, split_axis
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    comp = MeshComposer(mesh, cu_axis="model")
    subs = comp.compose([2, 1, 1], names=["big", "mid", "small"])
    sizes = [s.mesh.devices.size for s in subs]
    ids = [sorted(d.id for d in s.mesh.devices.flatten()) for s in subs]
    flat = sorted(i for grp in ids for i in grp)
    unified = comp.unified()
    print(json.dumps({"sizes": sizes, "disjoint": len(flat) == len(set(flat)),
                      "total": len(flat),
                      "unified": int(unified.mesh.devices.size)}))
    """)
    assert res["sizes"] == [4, 2, 2]
    assert res["disjoint"] and res["total"] == 8
    assert res["unified"] == 8


def test_multi_tenant_two_models_on_submeshes():
    res = _run("""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_reduced
    from repro.core.composer import MeshComposer
    from repro.distribution import partitioning as part
    from repro.models import build_model

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh, cu_axis="model")
    sub_a, sub_b = comp.compose([4, 4], names=["tenant-a", "tenant-b"])

    outs = {}
    for name, sub, arch in [("a", sub_a, "minitron-4b"),
                            ("b", sub_b, "qwen2.5-32b")]:
        cfg = get_reduced(arch)
        m = build_model(cfg)
        params = part.strip(m.init(jax.random.key(0)))
        toks = jnp.zeros((2, 8), jnp.int32)
        with sub.mesh:
            loss, _ = jax.jit(lambda p, t: m.loss(
                p, {"tokens": t, "labels": t}))(params, toks)
        outs[name] = float(loss)
    print(json.dumps(outs))
    """)
    assert all(v > 0 for v in res.values())
