"""FlexArena — FILCO's Flexible Memory Unit as a software-managed buffer pool
(paper §2.3 "Flexible On-chip Memory View" + §2.4 "Flexible On-chip Memory
Functionality").

An FMU is a 1-D-addressed buffer; an instruction reinterprets any region of
it as a 2-D operand *view* of arbitrary (rows, cols) and arbitrary *role*
(weight / activation / result).  Storage efficiency is therefore
size-limited, never shape-limited: a 256x256 and a 128x512 operand occupy
identical space (Fig. 4b), and a layer with one huge dimension can borrow
capacity from the other operands (Fig. 5).

Two layers of the framework use this:
  * host-side: the serving engine's KV/workspace allocator and the DSE's
    buffer-requirement model (`fits()` / `padding_overhead()`);
  * device-side: functional jnp ops (`store_view` / `load_view`) that
    pack / unpack 2-D operands into flat per-device arenas — the pattern the
    ``filco_mm`` kernel consumes (padded buffer + runtime dims).

Views can be aligned to the TPU (8, 128) tile so DMA'd windows stay
layout-friendly (the analogue of the paper's cyclic/block bank partitioning,
DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

ROLE_WEIGHT = "weight"
ROLE_ACT = "activation"
ROLE_RESULT = "result"
ROLES = (ROLE_WEIGHT, ROLE_ACT, ROLE_RESULT)


@dataclasses.dataclass(frozen=True)
class View:
    """A runtime 2-D window into a flat arena."""

    offset: int          # element offset into the arena
    rows: int
    cols: int
    role: str
    view_id: int

    @property
    def size(self) -> int:
        return self.rows * self.cols


class AllocationError(RuntimeError):
    pass


class FlexArena:
    """First-fit 1-D allocator with runtime-shaped views.

    capacity: elements.  align: element alignment for view starts (set to
    8*128 to keep views tile-aligned on TPU).
    """

    def __init__(self, capacity: int, *, align: int = 1):
        self.capacity = int(capacity)
        self.align = int(align)
        self._views: Dict[int, View] = {}
        self._next_id = 0

    # -- bookkeeping -----------------------------------------------------
    def _gaps(self) -> List[Tuple[int, int]]:
        """Free (start, length) gaps, sorted by start."""
        used = sorted((v.offset, v.offset + v.size) for v in self._views.values())
        gaps, cur = [], 0
        for s, e in used:
            if s > cur:
                gaps.append((cur, s - cur))
            cur = max(cur, e)
        if cur < self.capacity:
            gaps.append((cur, self.capacity - cur))
        return gaps

    @property
    def used(self) -> int:
        return sum(v.size for v in self._views.values())

    @property
    def free(self) -> int:
        return self.capacity - self.used

    def utilization(self) -> float:
        return self.used / self.capacity if self.capacity else 0.0

    def views(self) -> List[View]:
        return sorted(self._views.values(), key=lambda v: v.offset)

    # -- allocation ------------------------------------------------------
    def _align_up(self, x: int) -> int:
        a = self.align
        return -(-x // a) * a

    def alloc(self, rows: int, cols: int, role: str = ROLE_ACT) -> View:
        """Allocate a (rows, cols) view; shape is *metadata*, storage is
        rows*cols elements — no padding (the FMV property)."""
        assert role in ROLES, role
        need = rows * cols
        for start, length in self._gaps():
            astart = self._align_up(start)
            if astart + need <= start + length:
                v = View(astart, rows, cols, role, self._next_id)
                self._views[self._next_id] = v
                self._next_id += 1
                return v
        raise AllocationError(
            f"arena full: need {need}, free {self.free} (fragmented)")

    def free_view(self, view: View) -> None:
        self._views.pop(view.view_id, None)

    def reshape_view(self, view: View, rows: int, cols: int,
                     role: Optional[str] = None) -> View:
        """Reinterpret an existing allocation under a new 2-D shape/role —
        the runtime 'different buffer view based on instr' (Fig. 4a).  The
        new shape must not exceed the original allocation."""
        if rows * cols > view.size:
            raise AllocationError(
                f"view reshape {rows}x{cols} exceeds allocation {view.size}")
        nv = View(view.offset, rows, cols, role or view.role, view.view_id)
        self._views[view.view_id] = nv
        return nv

    def fits(self, shapes: List[Tuple[int, int]]) -> bool:
        """Would these operands fit together (FMF check, Fig. 5b)?  Order-
        insensitive because storage is 1-D: total elements vs capacity."""
        return sum(r * c for r, c in shapes) <= self.free

    # -- static-baseline accounting ---------------------------------------
    @staticmethod
    def static_padding_overhead(shape: Tuple[int, int],
                                buffer_shape: Tuple[int, int]) -> float:
        """Fraction of a *static* (CHARM/RSN-style) buffer wasted when
        storing `shape` padded into `buffer_shape` (tiled if larger)."""
        r, c = shape
        br, bc = buffer_shape
        tiles = (-(-r // br)) * (-(-c // bc))
        stored = tiles * br * bc
        return 1.0 - (r * c) / stored


# ---------------------------------------------------------------------------
# paged arena: fixed-size pages over the FlexArena substrate
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PageTable:
    """Per-owner page table: the ordered fixed-size pages backing one slot's
    cache.  ``rows`` is the logical row count the owner has asked for so far;
    the reserved storage is ``len(pages) * page_rows`` rows — caches grow
    page-at-a-time instead of reserving their worst case up front."""

    table_id: int
    rows: int
    cols: int
    role: str
    pages: List[View]

    @property
    def size(self) -> int:
        """Reserved elements (whole pages, not the logical ``rows``)."""
        return sum(p.size for p in self.pages)


class PagedArena:
    """Fixed-size-page allocator over a :class:`FlexArena` substrate.

    The FMU's shape-agnostic 1-D storage makes equal-size pages the natural
    admission currency: every page is a ``(page_rows, cols)`` view carved
    from the substrate, so allocation can never fragment (all holes are a
    whole number of pages) and a drained arena always re-packs to full
    capacity.  Owners hold :class:`PageTable`\\ s and ``grow`` them one page
    at a time; ``free_view`` returns every page to the substrate.

    The interface mirrors ``FlexArena`` (``alloc`` / ``free_view`` /
    ``used`` / ``free`` / ``utilization`` / ``fits``) so serving engines can
    swap it in as their admission arena without touching call sites.
    """

    def __init__(self, num_pages: int, page_rows: int, cols: int, *,
                 align: int = 1):
        if num_pages < 1 or page_rows < 1 or cols < 1:
            raise ValueError(
                f"PagedArena needs positive geometry, got "
                f"num_pages={num_pages} page_rows={page_rows} cols={cols}")
        self.num_pages = int(num_pages)
        self.page_rows = int(page_rows)
        self.cols = int(cols)
        self.page_elems = self.page_rows * self.cols
        self._substrate = FlexArena(self.num_pages * self.page_elems,
                                    align=align)
        self._tables: Dict[int, PageTable] = {}
        self._next_id = 0

    # -- accounting ------------------------------------------------------
    def pages_for(self, rows: int) -> int:
        """Pages needed to cover ``rows`` logical rows."""
        return -(-max(int(rows), 0) // self.page_rows)

    @property
    def used_pages(self) -> int:
        return sum(len(t.pages) for t in self._tables.values())

    @property
    def free_pages(self) -> int:
        return self.num_pages - self.used_pages

    @property
    def capacity(self) -> int:
        return self.num_pages * self.page_elems

    @property
    def used(self) -> int:
        return self.used_pages * self.page_elems

    @property
    def free(self) -> int:
        return self.capacity - self.used

    def utilization(self) -> float:
        return self.used_pages / self.num_pages if self.num_pages else 0.0

    def tables(self) -> List[PageTable]:
        return sorted(self._tables.values(), key=lambda t: t.table_id)

    def fits(self, shapes: List[Tuple[int, int]]) -> bool:
        return sum(self.pages_for(r) for r, _ in shapes) <= self.free_pages

    # -- allocation ------------------------------------------------------
    def _carve(self, n: int, role: str) -> List[View]:
        if n > self.free_pages:
            raise AllocationError(
                f"paged arena full: need {n} pages, free {self.free_pages} "
                f"of {self.num_pages}")
        return [self._substrate.alloc(self.page_rows, self.cols, role)
                for _ in range(n)]

    def alloc(self, rows: int, cols: int, role: str = ROLE_ACT) -> PageTable:
        """Open a page table covering ``rows`` rows.  ``cols`` must match the
        arena's column width (pages are homogeneous)."""
        assert role in ROLES, role
        if cols != self.cols:
            raise AllocationError(
                f"paged arena is {self.cols} cols wide, got {cols}")
        if rows < 1:
            raise AllocationError(f"page table needs rows >= 1, got {rows}")
        pages = self._carve(self.pages_for(rows), role)
        t = PageTable(self._next_id, int(rows), self.cols, role, pages)
        self._tables[self._next_id] = t
        self._next_id += 1
        return t

    def grow(self, table: PageTable, rows: int) -> PageTable:
        """Extend ``table`` to cover ``rows`` rows, allocating pages only
        when the request crosses a page boundary.  Raises
        :class:`AllocationError` (table unchanged) when no page is free —
        the preemption trigger."""
        if table.table_id not in self._tables:
            raise AllocationError(f"grow on a freed table {table.table_id}")
        need = self.pages_for(rows) - len(table.pages)
        if need > 0:
            table.pages.extend(self._carve(need, table.role))
        if rows > table.rows:
            table.rows = int(rows)
        return table

    def free_view(self, table: PageTable) -> None:
        """Release every page back to the substrate (idempotent)."""
        t = self._tables.pop(table.table_id, None)
        if t is None:
            return
        for p in t.pages:
            self._substrate.free_view(p)
        t.pages.clear()

    # -- invariant check (exercised by the property suite) ---------------
    def check(self) -> None:
        """Assert structural invariants: pages never overlap, page counts
        and substrate accounting agree, and the free count never goes
        negative."""
        spans = sorted((p.offset, p.offset + p.size)
                       for t in self._tables.values() for p in t.pages)
        for (_, e0), (s1, _) in zip(spans, spans[1:]):
            if s1 < e0:
                raise AssertionError(f"overlapping pages at {s1} < {e0}")
        n_pages = sum(len(t.pages) for t in self._tables.values())
        if n_pages * self.page_elems != self._substrate.used:
            raise AssertionError(
                f"leak: {n_pages} pages vs substrate used "
                f"{self._substrate.used}")
        if not 0 <= self.free_pages <= self.num_pages:
            raise AssertionError(f"free_pages out of range: {self.free_pages}")
        for t in self._tables.values():
            if len(t.pages) != self.pages_for(max(t.rows, 1)):
                raise AssertionError(
                    f"table {t.table_id}: rows {t.rows} vs "
                    f"{len(t.pages)} pages")


# ---------------------------------------------------------------------------
# device-side functional ops
# ---------------------------------------------------------------------------

def store_view(arena_buf: jnp.ndarray, view: View, matrix: jnp.ndarray):
    """Write a (rows, cols) matrix into the flat arena at the view window."""
    flat = matrix.reshape(-1).astype(arena_buf.dtype)
    return jax.lax.dynamic_update_slice(arena_buf, flat, (view.offset,))


def load_view(arena_buf: jnp.ndarray, view: View) -> jnp.ndarray:
    """Read the view window back as a (rows, cols) matrix."""
    flat = jax.lax.dynamic_slice(arena_buf, (view.offset,), (view.size,))
    return flat.reshape(view.rows, view.cols)


def load_padded(arena_buf: jnp.ndarray, view: View,
                padded_shape: Tuple[int, int]) -> jnp.ndarray:
    """Read a view into a zero-padded (max-shape) buffer — the handoff format
    of the ``filco_mm`` kernel (padded operands + runtime valid dims)."""
    m = load_view(arena_buf, view)
    pr, pc = padded_shape
    return jnp.pad(m, ((0, pr - view.rows), (0, pc - view.cols)))
