"""fabriclint (tools/fabriclint) and the REPRO_SANITIZE runtime sanitizer.

Static side: every rule fires on a seeded-violation fixture and stays quiet
on the matching clean fixture; inline suppressions and the baseline absorb
findings by line-number-free fingerprint; and the real tree lints green
under the committed baseline (the CI gate, pinned here so a tier-1 run
catches a red lint before the workflow does).

Dynamic side: a sanitized engine run is bit-identical to the unsanitized
run (the sanitizer changes zero numerics), an injected implicit
device→host transfer on the hot path raises, and a release path bypassing
``_release_slot`` trips the post-step slot-accounting sweep.
"""
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:     # tools/ is a repo-root package
    sys.path.insert(0, str(REPO))

from tools.fabriclint import run_lint                     # noqa: E402
from tools.fabriclint import baseline as baseline_mod     # noqa: E402
from tools.fabriclint.rules import ALL_RULES              # noqa: E402
from tools.fabriclint.walker import Index                 # noqa: E402


def lint_source(src, rule, *, current_pr=9, path="fixture.py"):
    """Run one rule over a fixture snippet; returns (index, findings) with
    inline suppressions already applied (as run_lint does)."""
    index = Index(repo_root=REPO)
    index.add_source(path, textwrap.dedent(src))
    found = ALL_RULES[rule](index, {"current_pr": current_pr,
                                    "repo_root": REPO})
    return index, [f for f in found if not index.suppressed(f)]


# ---------------------------------------------------------------------------
# hot-sync
# ---------------------------------------------------------------------------

HOT_SYNC_BAD = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    class Eng:
        def step(self):
            return self._advance()

        def _advance(self):
            x = jnp.ones((4,))
            return float(jnp.sum(x)), np.asarray(x), x.item()
"""

HOT_SYNC_CLEAN = """
    import jax
    import jax.numpy as jnp

    class Eng:
        def step(self):
            self._advance()
            return list(self._emitted)

        def _advance(self):
            x = jnp.ones((4,))
            self._buf = x          # stays on device: no sync

        def warm_compile(self, sub):
            # event-time boundary: syncs here are priced by the DSE
            return float(jnp.zeros(()))
"""


def test_hot_sync_flags_implicit_coercions():
    _, found = lint_source(HOT_SYNC_BAD, "hot-sync")
    codes = {f.code for f in found}
    assert any("float" in c for c in codes), codes
    assert any("asarray" in c for c in codes), codes
    assert any("item" in c for c in codes), codes
    assert all(f.symbol == "Eng._advance" for f in found)


def test_hot_sync_clean_and_boundary_quiet():
    _, found = lint_source(HOT_SYNC_CLEAN, "hot-sync")
    assert found == []


def test_hot_sync_reports_explicit_syncs_for_baselining():
    src = """
        import jax

        class Eng:
            def step(self):
                return jax.device_get(self._nxt)
    """
    _, found = lint_source(src, "hot-sync")
    assert len(found) == 1
    assert "explicit" in found[0].message
    assert "device_get" in found[0].code


# ---------------------------------------------------------------------------
# cache-key
# ---------------------------------------------------------------------------

CACHE_KEY_BAD = """
    class Eng:
        def _config_key(self, slots):
            return (self.cfg.max_len, slots)

        def _build_decode(self, mesh):
            return (self.cfg.max_len, self._shape())

        def _shape(self):
            return self.cfg.use_kernels   # read transitively, never keyed
"""

CACHE_KEY_CLEAN = """
    class Eng:
        def _config_key(self, slots):
            return (self.cfg.max_len, self.cfg.use_kernels, slots)

        def _exec_for(self, mesh):
            return self._config_key(self.cfg.max_slots)

        def _build_decode(self, mesh):
            return (self.cfg.max_len, self.cfg.use_kernels,
                    self.cfg.max_slots)
"""


def test_cache_key_flags_unkeyed_transitive_read():
    _, found = lint_source(CACHE_KEY_BAD, "cache-key")
    assert len(found) == 1
    f = found[0]
    assert f.code == "cfg.use_kernels"
    assert f.symbol == "Eng._shape"
    assert "_build_decode" in f.message


def test_cache_key_call_site_args_count_as_keyed():
    _, found = lint_source(CACHE_KEY_CLEAN, "cache-key")
    assert found == []


# ---------------------------------------------------------------------------
# thread-safety
# ---------------------------------------------------------------------------

THREAD_SAFETY_BAD = """
    class Eng:
        def __init__(self, pool):
            self._memo = {}
            pool.submit(self.warm_compile)

        def step(self):
            self._fill(1)

        def warm_compile(self):
            self._fill(2)

        def _fill(self, k):
            self._memo[k] = k      # raced: prewarm thread + serving loop
"""

THREAD_SAFETY_CLEAN = """
    class Eng:
        def __init__(self, pool):
            self._memo = {}
            pool.submit(self.warm_compile)

        def step(self):
            self._fill(1)

        def warm_compile(self):
            self._fill(2)

        def _fill(self, k):
            with self._lock:
                self._memo[k] = k
"""


def test_thread_safety_flags_unlocked_shared_mutation():
    _, found = lint_source(THREAD_SAFETY_BAD, "thread-safety")
    assert len(found) == 1
    f = found[0]
    assert f.symbol == "Eng._fill"
    assert "_memo" in f.message and "lock" in f.message


def test_thread_safety_lock_scope_clears_it():
    _, found = lint_source(THREAD_SAFETY_CLEAN, "thread-safety")
    assert found == []


# ---------------------------------------------------------------------------
# deprecation
# ---------------------------------------------------------------------------

DEPRECATION_SHIM = """
    import warnings

    # fabriclint: deprecated-since=PR6
    def old_api(x):
        warnings.warn("use new_api", DeprecationWarning, stacklevel=2)
        return x
"""

DEPRECATION_UNANNOTATED = """
    import warnings

    def old_api(x):
        warnings.warn("use new_api", DeprecationWarning, stacklevel=2)
        return x
"""


def test_deprecation_in_grace_is_quiet():
    _, found = lint_source(DEPRECATION_SHIM, "deprecation", current_pr=7)
    assert found == []


def test_deprecation_fails_past_grace_window():
    # the red-before-removal state the PR-6 shims were deleted from
    _, found = lint_source(DEPRECATION_SHIM, "deprecation", current_pr=9)
    assert len(found) == 1
    f = found[0]
    assert f.code == "deprecated-since=PR6"
    assert "delete this shim" in f.message


def test_deprecation_unannotated_shim_flagged():
    _, found = lint_source(DEPRECATION_UNANNOTATED, "deprecation",
                           current_pr=7)
    assert len(found) == 1
    assert "deprecated-since" in found[0].message


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------

PROTOCOL_BAD = """
    from typing import Protocol, runtime_checkable

    @runtime_checkable
    class Engine(Protocol):
        def submit(self, tokens, max_new_tokens=16): ...

        @property
        def queue_depth(self): ...

    class DecodeEngine:
        def __init__(self):
            self.queue_depth = 0

        def submit(self, prompt, max_new_tokens=16):   # renamed param
            return 0

    class SSMEngine:
        def submit(self, tokens, max_new_tokens=16):
            return 0
        # queue_depth missing entirely
"""

PROTOCOL_CLEAN = """
    from typing import Protocol, runtime_checkable

    @runtime_checkable
    class Engine(Protocol):
        def submit(self, tokens, max_new_tokens=16): ...

        @property
        def queue_depth(self): ...

    class DecodeEngine:
        def __init__(self):
            self.queue_depth = 0

        def submit(self, tokens, max_new_tokens=16, trace=None):
            return 0

    class SSMEngine(DecodeEngine):
        pass
"""


def test_protocol_flags_drifted_signature_and_missing_property():
    _, found = lint_source(PROTOCOL_BAD, "protocol")
    codes = {f.code for f in found}
    assert "signature:submit" in codes, codes
    assert "property:queue_depth" in codes, codes


def test_protocol_defaulted_extras_and_inherited_members_conform():
    _, found = lint_source(PROTOCOL_CLEAN, "protocol")
    assert found == []


# ---------------------------------------------------------------------------
# suppression + baseline round-trip
# ---------------------------------------------------------------------------

def test_inline_suppression_silences_one_rule():
    src = """
        import jax.numpy as jnp

        class Eng:
            def step(self):
                x = jnp.ones(())
                # fabriclint: disable=hot-sync -- fixture: deliberate sync
                return float(x)
    """
    _, found = lint_source(src, "hot-sync")
    assert found == []
    # a different rule's suppression does NOT silence it
    src_wrong = src.replace("disable=hot-sync", "disable=cache-key")
    _, found = lint_source(src_wrong, "hot-sync")
    assert len(found) == 1


def test_baseline_round_trip(tmp_path):
    src = """
        import jax

        class Eng:
            def step(self):
                return jax.device_get(self._nxt)
    """
    _, found = lint_source(src, "hot-sync")
    assert len(found) == 1
    entries = [baseline_mod.entry_for(found[0], "fixture: designed harvest")]
    path = tmp_path / "baseline.json"
    baseline_mod.save(path, entries)
    loaded = baseline_mod.load(path)
    assert loaded == sorted(entries, key=lambda e: tuple(
        e[k] for k in baseline_mod.KEYS))

    new, baselined, stale = baseline_mod.apply(found, loaded)
    assert new == [] and stale == []
    assert baselined[0][1] == "fixture: designed harvest"

    # fingerprints are line-free: the same finding on a shifted line matches
    shifted = "\n" + src
    _, found2 = lint_source(shifted, "hot-sync")
    new, baselined, _ = baseline_mod.apply(found2, loaded)
    assert new == [] and len(baselined) == 1

    # entries matching nothing surface as stale
    _, _, stale = baseline_mod.apply([], loaded)
    assert stale == loaded


def test_real_tree_lints_green_under_committed_baseline():
    findings, baselined, stale = run_lint(
        [str(REPO / "src")], repo_root=REPO,
        baseline_path=REPO / "tools" / "fabriclint" / "baseline.json")
    assert findings == [], "\n".join(f.render() for f in findings)
    assert stale == [], stale
    # the four deliberate hot-path syncs stay baselined with reasons
    assert len(baselined) == 4
    assert all(reason and "TODO" not in reason for _, reason in baselined)


# ---------------------------------------------------------------------------
# runtime sanitizer (REPRO_SANITIZE=1)
# ---------------------------------------------------------------------------

import jax                                         # noqa: E402
import jax.numpy as jnp                            # noqa: E402

from repro.configs import get_reduced              # noqa: E402
from repro.models.model import Model               # noqa: E402
from repro.workloads.base import (ImplicitTransferError,    # noqa: E402
                                  build_engine, sanitize_enabled)
from repro.workloads.decode import DecodeEngine, ServeConfig  # noqa: E402


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("minitron-4b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _serve_cfg():
    return ServeConfig(max_slots=2, max_len=64)


def _run_fleet(model, params):
    """A short mixed-fleet run: decode + encoder engines to completion."""
    dec = build_engine("decode", model, params, _serve_cfg())
    dec.submit([1, 2, 3], max_new_tokens=4)
    dec.submit([4, 5], max_new_tokens=4)
    streams = dec.run_to_completion()
    enc = build_engine("encoder", model, params, _serve_cfg())
    enc.submit([1, 2, 3, 4])
    enc.step()
    return streams, enc.results()


def test_sanitize_env_gate(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanitize_enabled()


def test_sanitized_run_is_bit_identical(monkeypatch, small_model):
    model, params = small_model
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    plain_streams, plain_emb = _run_fleet(model, params)
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    san_streams, san_emb = _run_fleet(model, params)
    assert san_streams == plain_streams
    assert san_emb == plain_emb
    assert any(len(v) for v in plain_streams.values())


def test_sanitizer_catches_injected_implicit_sync(monkeypatch, small_model):
    model, params = small_model
    monkeypatch.setenv("REPRO_SANITIZE", "1")

    class Bad(DecodeEngine):
        def _step_dispatch(self):
            super()._step_dispatch()
            float(jnp.ones(()))   # implicit transfer on the hot path

    bad = Bad(model, params, _serve_cfg())
    bad.submit([1, 2, 3], max_new_tokens=2)
    with pytest.raises(ImplicitTransferError, match="implicit"):
        bad.step()


def test_sanitizer_allows_explicit_device_get(monkeypatch, small_model):
    model, params = small_model
    monkeypatch.setenv("REPRO_SANITIZE", "1")

    class Probe(DecodeEngine):
        def _step_dispatch(self):
            super()._step_dispatch()
            # the sanctioned read-back: explicit, guard lets it through
            self.probed = float(jax.device_get(jnp.ones(())))

    eng = Probe(model, params, _serve_cfg())
    eng.submit([1, 2, 3], max_new_tokens=2)
    eng.step()
    assert eng.probed == 1.0


def test_sanitizer_catches_release_path_bypass(monkeypatch, small_model):
    model, params = small_model
    monkeypatch.setenv("REPRO_SANITIZE", "1")

    class Leaky(DecodeEngine):
        def _release_slot(self, slot, req):
            # bypass the single release point: drop the slot, leak the
            # arena view, never return the slot to the free list
            if slot in self._active:
                del self._active[slot]
            req.slot = -1

    leak = Leaky(model, params, _serve_cfg())
    leak.submit([1, 2], max_new_tokens=1)
    with pytest.raises(AssertionError, match="slot accounting"):
        for _ in range(6):
            leak.step()


def test_sanitizer_off_is_a_no_op(monkeypatch, small_model):
    model, params = small_model
    monkeypatch.setenv("REPRO_SANITIZE", "0")

    class Bad(DecodeEngine):
        def _step_dispatch(self):
            super()._step_dispatch()
            float(jnp.ones(()))

    bad = Bad(model, params, _serve_cfg())
    bad.submit([1, 2, 3], max_new_tokens=2)
    bad.step()   # no guard armed: nothing raises
