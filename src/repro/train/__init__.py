from repro.train import checkpoint, fault
from repro.train.trainer import (
    TrainConfig,
    Trainer,
    make_train_step,
    setup_sharded_state,
)

__all__ = ["checkpoint", "fault", "TrainConfig", "Trainer", "make_train_step",
           "setup_sharded_state"]
