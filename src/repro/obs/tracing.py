"""Span tracer with Chrome/Perfetto trace-event JSON export.

``SpanTracer.span(...)`` is a context manager that records a complete
("ph": "X") trace event into a bounded ring buffer.  The record path is a
``perf_counter`` pair plus one deque append — cheap enough to leave on
for every decode step.  Spans taken on background threads (e.g. the
speculative-prewarm compile thread) land on their own ``tid`` row, which
is exactly what makes compile/dispatch overlap visible in the Perfetto
UI: open ``chrome://tracing`` or https://ui.perfetto.dev and load the
file written by ``dump`` / ``ComposedServer.dump_trace``.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

__all__ = ["SpanTracer", "NULL_SPAN", "trace_span"]


class _NullSpan:
    """Reusable no-op context manager for disabled telemetry."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class SpanTracer:
    """Bounded ring buffer of completed spans.

    Events are stored as dicts already in trace-event form; ``ts``/``dur``
    are microseconds relative to the tracer's origin.  The ring evicts the
    oldest spans first, so a long-running fabric keeps the most recent
    window of activity without growing.
    """

    def __init__(self, capacity: int = 8192, *, pid: int = 1) -> None:
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._origin = time.perf_counter()
        self._pid = pid
        self._tids: Dict[int, int] = {}
        self._tid_lock = threading.Lock()

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._tid_lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def record(self, name: str, t0: float, t1: float,
               args: Optional[Dict[str, Any]] = None,
               cat: str = "serve") -> None:
        """Record a completed span given perf_counter() endpoints."""
        ev = {
            "name": name,
            "cat": cat,
            "ph": "X",
            "ts": (t0 - self._origin) * 1e6,
            "dur": max(t1 - t0, 0.0) * 1e6,
            "pid": self._pid,
            "tid": self._tid(),
        }
        if args:
            ev["args"] = args
        self._events.append(ev)

    @contextmanager
    def span(self, name: str, cat: str = "serve", **args: Any):
        """Time a block and record it as a complete trace event.

        Yields the args dict so callers can attach results computed
        inside the block (e.g. ``recompose`` fills in ``moved``)."""
        payload: Dict[str, Any] = dict(args) if args else {}
        t0 = time.perf_counter()
        try:
            yield payload
        finally:
            self.record(name, t0, time.perf_counter(), payload or None,
                        cat=cat)

    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        """Events sorted by start time (ring order is completion order;
        Perfetto wants nesting parents to precede children)."""
        return sorted(self._events, key=lambda e: (e["tid"], e["ts"]))

    def to_json(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path

    def clear(self) -> None:
        self._events.clear()


# Module-level convenience used by ad-hoc scripts/tests: a process-wide
# tracer so `with trace_span("phase"):` works without plumbing.
_GLOBAL = SpanTracer()


def trace_span(name: str, cat: str = "serve", **args: Any):
    """Span context manager on the process-global tracer."""
    return _GLOBAL.span(name, cat=cat, **args)


def global_tracer() -> SpanTracer:
    return _GLOBAL
