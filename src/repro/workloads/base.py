"""Workload classes: the contract every tenant engine on the composed fabric
satisfies, and the registry mapping a tenant's architecture to its engine.

FILCO's headline claim is matching *diverse* workloads to composed
accelerators (paper §1): the win from reconfigurability comes from scheduling
heterogeneous DNNs whose bound resource differs.  The serving-side
counterpart is that one :class:`~repro.serve.fabric.ComposedServer` runs a
mixed fleet where each tenant's engine is chosen by workload class:

* ``decode``  — autoregressive transformer decode: bandwidth-bound batched
  GEMV against streamed weights, KV cache grows with sequence length
  (:class:`~repro.workloads.decode.DecodeEngine`);
* ``ssm``     — mamba-style recurrent decode: constant-size state per slot,
  bound by state + parameter bandwidth, O(1) per token
  (:class:`~repro.workloads.ssm.SSMEngine`);
* ``encoder`` — prefill-only / embedding workloads: compute-bound
  full-sequence matmuls, no decode loop
  (:class:`~repro.workloads.encoder.EncoderEngine`);
* ``encdec``  — full encode→decode jobs on encoder-decoder archs: one
  compute-bound bidirectional encode of the source, then bandwidth-bound
  autoregressive decode whose every step additionally reads a per-slot
  cross-attention source cache scaled by the source length
  (:class:`~repro.workloads.encdec.EncDecEngine`).

The :class:`Engine` protocol is what the fabric and the recomposition policy
program against; the concrete engines share no inheritance requirement with
it — any object with these methods can be a tenant.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import os
import threading
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable

from repro.configs.base import ModelConfig
from repro.core.dse import DesignPoint

# canonical workload-class ids
DECODE = "decode"
SSM = "ssm"
ENCODER = "encoder"
ENCDEC = "encdec"
WORKLOAD_CLASSES: Tuple[str, ...] = (DECODE, SSM, ENCODER, ENCDEC)


def workload_class_of(cfg: ModelConfig) -> str:
    """Default workload class for an architecture.

    Attention-free SSM archs decode from recurrent state (``ssm``);
    encoder-decoder archs serve full encode→decode jobs (``encdec``);
    anything else with a decode loop defaults to ``decode``.  ``encoder`` is
    never inferred: any arch can serve embedding traffic, so it is an
    explicit tenant choice (``TenantSpec(workload="encoder")``), not a
    property of the config.
    """
    if cfg.ssm is not None and cfg.attention_free:
        return SSM
    if cfg.is_encdec and cfg.cross_attention:
        return ENCDEC
    return DECODE


def length_buckets(buckets: Sequence[int], cap: int) -> Tuple[int, ...]:
    """Normalized ascending ladder of padded-length program buckets.

    ``buckets`` are the requested sequence-length breakpoints (e.g.
    ``(128, 512)``); ``cap`` is the engine's hard capacity and is always the
    final bucket.  Entries outside ``(0, cap)`` are dropped.  A job of
    length L runs in the smallest bucket >= L, so short jobs skip the padded
    FLOPs of the full-capacity program; an empty ``buckets`` means one
    program at ``cap`` (the pre-bucketing behavior).
    """
    ladder = sorted({int(b) for b in buckets if 0 < int(b) < cap})
    return tuple(ladder) + (cap,)


def pick_bucket(ladder: Sequence[int], length: int) -> int:
    """Smallest bucket in ``ladder`` that fits ``length`` (ladder is
    ascending and its last entry is the capacity, so callers reject
    oversized jobs before picking)."""
    for b in ladder:
        if length <= b:
            return b
    return ladder[-1]


class DecayedLengthEstimator:
    """Exponentially decayed estimate of the submitted-length distribution.

    Replaces the flat last-N window behind ``Engine.recent_lengths()``: a
    flat deque weighs a 200-observation-old prompt the same as the last one,
    so after a traffic shift the serving DSE's Stage-1 bucket-ladder search
    keeps optimizing for the dead distribution until the stale half drains.
    Here every new observation decays all older ones by ``decay``, giving an
    effective window of ~1/(1-decay) observations — a shifted distribution
    dominates the estimate within a bounded number of submissions (pinned by
    tests/test_ragged_decode.py).

    ``lengths()`` keeps the protocol's ``Tuple[int, ...]`` shape by emitting
    a fixed-size weighted resample (largest-remainder allocation of
    ``resolution`` copies), so downstream consumers (``padded_factor``,
    Stage-1 candidate ladders, expected-length means) need no change.
    Deterministic: no RNG, same observations -> same tuple.

    The deque-compatible ``append``/``__iter__``/``__len__`` surface keeps
    existing engine call sites unchanged.
    """

    def __init__(self, decay: float = 0.97, cap: int = 256,
                 resolution: int = 64):
        if not 0.0 < decay < 1.0:
            raise ValueError(f"decay must be in (0, 1), got {decay}")
        self.decay = decay
        self.resolution = resolution
        # (length, weight) at a shared scale; fresh observations enter at
        # self._scale, which grows by 1/decay per observation so older
        # entries decay without a touch-everything pass
        self._samples: "collections.deque" = collections.deque(maxlen=cap)
        self._scale = 1.0

    def observe(self, length: int) -> None:
        self._scale /= self.decay
        if self._scale > 1e9:               # keep float headroom
            factor = self._scale
            self._samples = collections.deque(
                ((ln, w / factor) for ln, w in self._samples),
                maxlen=self._samples.maxlen)
            self._scale = 1.0
        self._samples.append((int(length), self._scale))

    # deque-compatible surface (engines call .append on submit)
    def append(self, length: int) -> None:
        self.observe(length)

    def __len__(self) -> int:
        return len(self._samples)

    def __iter__(self):
        return iter(self.lengths())

    def lengths(self) -> Tuple[int, ...]:
        """Weighted resample of the observed lengths, newest-heavy: each
        retained sample gets ``resolution``-normalized copies proportional
        to its decayed weight (largest remainder; decayed-out samples get
        none)."""
        if not self._samples:
            return ()
        total = sum(w for _, w in self._samples)
        n = min(self.resolution, len(self._samples) or 1)
        quotas = [(ln, n * w / total) for ln, w in self._samples]
        counts = [(ln, int(q)) for ln, q in quotas]
        short = n - sum(c for _, c in counts)
        # hand the remainder to the largest fractional parts (ties: newest)
        order = sorted(range(len(quotas)),
                       key=lambda i: (quotas[i][1] - int(quotas[i][1]), i),
                       reverse=True)
        for i in order[:short]:
            counts[i] = (counts[i][0], counts[i][1] + 1)
        out: List[int] = []
        for ln, c in counts:
            out.extend([ln] * c)
        return tuple(out)

    def mean(self) -> float:
        """Decay-weighted mean length (0.0 when nothing observed)."""
        if not self._samples:
            return 0.0
        total = sum(w for _, w in self._samples)
        return sum(ln * w for ln, w in self._samples) / total


@runtime_checkable
class Engine(Protocol):
    """What the fabric requires of a tenant engine.

    Extracted from the PR-1/2 ``ServeEngine`` (now the transformer
    :class:`DecodeEngine`): submit work, advance one batched step, expose the
    load signals the recomposition policy decides on, migrate onto a new
    composed sub-accelerator, and pre-compile for a candidate one.
    """

    workload_class: str

    # -- work ingestion / progress --------------------------------------
    def submit(self, tokens, max_new_tokens: int = 16) -> int: ...
    def step(self) -> List[Tuple[int, Any]]: ...
    def results(self) -> Dict[int, Any]: ...
    def snapshot(self) -> Dict[int, Any]: ...

    # -- load signals (recomposition policy inputs) ---------------------
    @property
    def queue_depth(self) -> int: ...
    @property
    def active_count(self) -> int: ...
    @property
    def has_work(self) -> bool: ...
    def pending_tokens(self) -> int: ...
    def arena_utilization(self) -> float: ...

    # -- preemption (SLO-aware scheduling; see docs/scheduling.md) ------
    # ``preempt_one`` parks the policy victim's device state host-side and
    # returns its rid (None when nothing is preemptible — e.g. the encoder
    # engine, whose jobs complete within their step).  Preempted requests
    # re-admit via the engine's own _admit with bit-identical continuation.
    def preempt_one(self) -> Optional[int]: ...
    @property
    def preempted_depth(self) -> int: ...
    def queue_head_wait_s(self, now: Optional[float] = None) -> float: ...

    # -- real-time recomposition / design-point reconfiguration ---------
    # ``apply`` moves the engine onto a new composed sub-accelerator and/or
    # retunes its runtime knobs in one call; the knobs ride a
    # :class:`~repro.core.dse.DesignPoint` (``None`` fields = keep).
    def reshard_to(self, sub) -> None: ...
    def apply(self, sub=None,
              point: Optional[DesignPoint] = None) -> Dict[str, Any]: ...
    def warm_compile(self, sub,
                     point: Optional[DesignPoint] = None) -> int: ...
    def sync(self) -> None: ...

    # -- serving-DSE inputs/outputs -------------------------------------
    def design(self) -> Dict[str, Any]: ...
    def recent_lengths(self) -> Tuple[int, ...]: ...

    # -- telemetry (ComposedServer.stats reads these per tenant) --------
    reshard_count: int

    @property
    def compile_builds(self) -> int: ...
    def stats(self) -> Dict[str, Any]: ...


class EngineTelemetry:
    """Shared plumbing for concrete engines: per-engine cold-build counting
    against the (possibly fabric-shared) executable cache, and bounded
    finished-request retention.  Expects ``self._exec``, ``self._own_builds``,
    ``self._finished`` and ``self.finished_cap`` set by the constructor."""

    # build counts bump from both the speculative-prewarm thread
    # (warm_compile) and the serving loop (cold builds at dispatch); one
    # class-level lock covers the counter — a bump is far too cheap to
    # contend, and engines don't route their __init__ through this mixin
    _builds_lock = threading.Lock()

    @property
    def compile_builds(self) -> int:
        """Cold executable compiles this engine performed (warm-path
        telemetry).  With a fabric-shared cache this counts builds done
        *through this engine*, not cache-wide builds — a hit on another
        same-config tenant's program is exactly the savings we measure."""
        return self._own_builds

    def _counted(self, builder):
        """Wrap a cold-build closure so per-engine telemetry sees it.

        Besides the ``_own_builds`` count, the build is timed into the
        engine's obs registry (``compile_build_s`` histogram +
        ``compile_builds`` counter) and recorded as a ``compile_build``
        span — builds running on the speculative-prewarm thread land on
        their own trace row, which is what makes compile/dispatch overlap
        visible in the exported trace."""
        obs = getattr(self, "_obs", None)

        def run():
            with self._builds_lock:
                self._own_builds += 1
            if obs is None or not obs.enabled:
                return builder()
            with obs.timed("compile_build", "compile_build_s"):
                result = builder()
            obs.inc("compile_builds")
            return result
        return run

    def _evict_finished(self) -> None:
        """Bound host memory: a long-running engine must not grow with
        every request ever served (oldest finished records drop first).
        Eviction only ever touches the ``_finished`` record map — a
        request's slot and arena reservation are released together at its
        finish/preempt site (``DecodeEngine._release_slot``), never here,
        so record eviction can't strand or double-free arena bytes."""
        while len(self._finished) > self.finished_cap:
            self._finished.pop(next(iter(self._finished)))

    # -- preemption defaults (engines without preemptible device state) --
    preempt_count = 0

    def preempt_one(self) -> Optional[int]:
        """No preemptible per-request device state (e.g. the encoder
        engine: jobs complete within their step).  Slot-pool engines
        override (DecodeEngine and subclasses)."""
        return None

    @property
    def preempted_depth(self) -> int:
        return len(getattr(self, "_parked", ()))

    def queue_head_wait_s(self, now: Optional[float] = None) -> float:
        """Seconds the oldest queued job has waited (SLO-risk signal);
        engines with a ``_queue`` of submit-stamped records override or
        inherit the DecodeEngine implementation."""
        import time as _time
        stamps = [getattr(r, "submitted_s", 0.0)
                  for r in getattr(self, "_queue", ())]
        stamps = [s for s in stamps if s > 0.0]
        if not stamps:
            return 0.0
        return max((now if now is not None else _time.perf_counter())
                   - min(stamps), 0.0)


def build_engine(wclass: str, model, params, serve_cfg, *, mesh=None,
                 rules=None, exec_cache=None, obs=None):
    """Construct the engine serving ``wclass`` traffic for ``model``.

    ``exec_cache`` is the fabric-level shared AOT executable cache: engines
    key their programs by (config fingerprint, mesh fingerprint, shapes), so
    same-config tenants share warm executables instead of each compiling its
    own copy.

    ``obs`` is a :class:`repro.obs.Telemetry` handle (labels typically
    already scoped to the tenant + workload class; one *fresh* registry per
    dp replica so the group can merge them).  ``None`` gives the engine a
    private enabled handle, so standalone engines are observable too.
    """
    from repro.workloads.decode import DecodeEngine
    from repro.workloads.encdec import EncDecEngine
    from repro.workloads.encoder import EncoderEngine
    from repro.workloads.ssm import SSMEngine

    classes = {DECODE: DecodeEngine, SSM: SSMEngine, ENCODER: EncoderEngine,
               ENCDEC: EncDecEngine}
    if wclass not in classes:
        raise KeyError(f"unknown workload class {wclass!r}; "
                       f"known: {WORKLOAD_CLASSES}")
    return classes[wclass](model, params, serve_cfg, mesh=mesh, rules=rules,
                           exec_cache=exec_cache, obs=obs)


# ----------------------------------------------------------------------
# runtime sanitizer (REPRO_SANITIZE=1)
#
# The static side of fabriclint (tools/fabriclint) proves properties of the
# *source*; these hooks check the same invariants on the *running* fabric.
# They are the dynamic counterpart of two lint rules:
#
# * hot-sync   → sanitize_guard() arms jax's device→host transfer guard
#   around an engine step, so any IMPLICIT read-back (``float(arr)``,
#   ``np.asarray(arr)``, ``.item()``) raises at the offending line.
#   Explicit ``jax.device_get`` / ``jax.block_until_ready`` — the baselined,
#   deliberate sync points — stay allowed.
# * single-release-point → sanitize_check() sweeps the engine's host
#   bookkeeping after every step: slot/arena accounting must agree (every
#   release went through ``_release_slot``), and a paged arena's internal
#   page ledger must balance (``PagedArena.check``).
#
# Both are no-ops unless REPRO_SANITIZE is set, and both change zero
# numerics: CI's slo-smoke runs sanitized and must stay digest-identical
# to the unsanitized run (tests/test_fabriclint.py pins this).
# ----------------------------------------------------------------------

SANITIZE_ENV = "REPRO_SANITIZE"


def sanitize_enabled() -> bool:
    """True when the runtime sanitizer is armed (``REPRO_SANITIZE=1``).

    Read per call, not cached at import — tests flip the env var around
    individual engine runs."""
    return os.environ.get(SANITIZE_ENV, "0").lower() not in ("0", "", "false")


class ImplicitTransferError(RuntimeError):
    """An implicit device→host transfer happened on a sanitized engine step."""


_tl = threading.local()


def _allow_depth() -> int:
    return getattr(_tl, "explicit_depth", 0)


@contextlib.contextmanager
def _explicit_ok():
    _tl.explicit_depth = _allow_depth() + 1
    try:
        yield
    finally:
        _tl.explicit_depth -= 1


def _explicit_wrap(orig):
    @functools.wraps(orig)
    def run(*args, **kwargs):
        with _explicit_ok():
            return orig(*args, **kwargs)
    return run


# implicit-coercion surface of the jax array type: each of these silently
# synchronizes device→host when called on a device array
_COERCION_KINDS = ("__float__", "__int__", "__bool__", "__index__",
                   "__array__", "item", "tolist")
_patch_lock = threading.Lock()
_patch_depth = 0
_saved: Dict[str, Any] = {}
_array_cls = None


def _jax_array_cls():
    global _array_cls
    if _array_cls is None:
        import jax.numpy as jnp
        _array_cls = type(jnp.zeros(()))
    return _array_cls


def _blocked(kind, orig):
    def run(self, *args, **kwargs):
        if _allow_depth():
            return orig(self, *args, **kwargs)
        raise ImplicitTransferError(
            f"implicit device→host transfer ({kind}) on a sanitized engine "
            f"step — read back explicitly via jax.device_get, and baseline "
            f"the fabriclint hot-sync finding with a reason if deliberate")
    return run


@contextlib.contextmanager
def _python_transfer_guard():
    """Backstop for backends where jax's transfer guard is inert (the CPU
    backend's device_get is zero-copy, so no guarded transfer ever fires):
    patch the implicit-coercion dunders on the jax array type to raise,
    while ``jax.device_get`` / ``jax.block_until_ready`` mark their
    read-backs explicit via a thread-local depth.  Re-entrant; the patch is
    installed once at depth 1 and restored at depth 0."""
    global _patch_depth
    import jax
    cls = _jax_array_cls()
    with _patch_lock:
        _patch_depth += 1
        if _patch_depth == 1:
            for kind in _COERCION_KINDS:
                orig = getattr(cls, kind, None)
                if orig is None:
                    continue
                _saved[kind] = orig
                setattr(cls, kind, _blocked(kind, orig))
            _saved["device_get"] = jax.device_get
            _saved["block_until_ready"] = jax.block_until_ready
            jax.device_get = _explicit_wrap(_saved["device_get"])
            jax.block_until_ready = _explicit_wrap(_saved["block_until_ready"])
    try:
        yield
    finally:
        with _patch_lock:
            _patch_depth -= 1
            if _patch_depth == 0:
                for kind in _COERCION_KINDS:
                    if kind in _saved:
                        setattr(cls, kind, _saved.pop(kind))
                jax.device_get = _saved.pop("device_get")
                jax.block_until_ready = _saved.pop("block_until_ready")


@contextlib.contextmanager
def sanitize_guard():
    """Disallow implicit device→host transfers for the enclosed engine step.

    Under the guard a stray ``float(device_array)`` on the hot path raises
    :class:`ImplicitTransferError` at the offending line; the deliberate
    syncs go through ``jax.device_get`` and are unaffected.  Arms both
    jax's own transfer guard (real accelerator backends) and the Python
    coercion backstop (CPU backends, where device_get is zero-copy and the
    jax guard never fires).  No-op when the sanitizer is off."""
    if not sanitize_enabled():
        yield
        return
    import jax
    with jax.transfer_guard_device_to_host("disallow"), \
            _python_transfer_guard():
        yield


def sanitize_check(engine) -> None:
    """Post-step invariant sweep (no-op when the sanitizer is off).

    Duck-typed on the slot-engine attributes so it runs on any protocol
    implementation: engines without an arena or slot pool (EncoderEngine,
    ReplicaGroup members are checked individually) skip the absent parts.
    """
    if not sanitize_enabled():
        return
    arena = getattr(engine, "arena", None)
    check = getattr(arena, "check", None)
    if callable(check):
        check()
    active = getattr(engine, "_active", None)
    free = getattr(engine, "_free_slots", None)
    cfg = getattr(engine, "cfg", None)
    if active is None or free is None or cfg is None:
        return
    name = type(engine).__name__
    dup = set(active) & set(free)
    if dup:
        raise AssertionError(
            f"fabric sanitizer: {name} slots both active and free: "
            f"{sorted(dup)} — a release path bypassed _release_slot")
    slots = getattr(cfg, "max_slots", None)
    if slots is not None and len(active) + len(free) != slots:
        raise AssertionError(
            f"fabric sanitizer: {name} slot accounting diverged — "
            f"{len(active)} active + {len(free)} free != {slots} slots; "
            f"some release path bypassed _release_slot")
    for slot, req in active.items():
        if getattr(req, "slot", slot) != slot:
            raise AssertionError(
                f"fabric sanitizer: {name} active request in slot {slot} "
                f"records slot {req.slot}")
    for parked in getattr(engine, "_parked", ()) or ():
        req = parked[0] if isinstance(parked, tuple) else parked
        if getattr(req, "view", None) is not None or \
                getattr(req, "slot", -1) != -1:
            raise AssertionError(
                f"fabric sanitizer: {name} parked request rid="
                f"{getattr(req, 'rid', '?')} still holds a slot or arena "
                f"view — preemption bypassed _release_slot")
