"""thread-safety: shared state touched by both the prewarm thread and the
serving loop must be mutated under a lock.

The fabric runs exactly one background context: the speculative-prewarm /
design-warm single-worker pool (``ComposedServer._pool()``), whose thunks
call ``warm_compile`` on live engines while the serving loop keeps
stepping them.  Any attribute *mutated* from both contexts outside a
``with <lock>:`` scope is a data race (the PR-6 era had exactly one:
``EngineTelemetry._counted``'s build counter).

Roots are discovered, not configured: every ``pool.submit(fn)`` /
``Thread(target=fn)`` call seeds the background set with the call names in
``fn`` (lambda bodies mined); the main set walks from every ``step``
method.  Mutations are ``self.X = / += ...``, ``self.X[...] = ...`` and
mutating method calls (``self.X.append(...)`` etc.); a nested closure's
mutations belong to its enclosing method.  Reads are not flagged —
engines that *snapshot* main-thread sets before iterating on the prewarm
thread (``sorted(tuple(self._prefill_lens))``) are the sanctioned pattern.
"""
from __future__ import annotations

from typing import Dict, List, Set

from tools.fabriclint import Finding
from tools.fabriclint.walker import Index

RULE = "thread-safety"

MAIN_ROOTS = frozenset({"step"})


def check(index: Index, config: Dict) -> List[Finding]:
    bg = index.reachable(index.submit_seeds, include_lambda=True)
    main = index.reachable(MAIN_ROOTS, include_lambda=True)

    # attr -> context -> list of (FuncInfo, Mutation), unlocked only
    unlocked: Dict[str, Dict[str, List]] = {}
    locked_attrs: Set[str] = set()
    for name, infos in index.functions.items():
        in_bg, in_main = name in bg, name in main
        if not (in_bg or in_main) or name == "__init__":
            continue
        for info in infos:
            for mut in info.mutations:
                if mut.locked:
                    locked_attrs.add(mut.attr)
                    continue
                slot = unlocked.setdefault(mut.attr, {"bg": [], "main": []})
                if in_bg:
                    slot["bg"].append((info, mut))
                if in_main:
                    slot["main"].append((info, mut))

    findings: List[Finding] = []
    for attr in sorted(unlocked):
        slot = unlocked[attr]
        if not (slot["bg"] and slot["main"]):
            continue
        seen = set()
        for ctx in ("bg", "main"):
            for info, mut in slot[ctx]:
                site = (info.path, mut.line)
                if site in seen:
                    continue
                seen.add(site)
                findings.append(Finding(
                    rule=RULE, path=info.path, line=mut.line,
                    symbol=info.qualname, code=mut.code,
                    message=(f"`self.{attr}` is mutated from both the "
                             "prewarm thread and the serving loop; this "
                             "site holds no lock (wrap in `with <lock>:`)")))
    return findings
