"""Serving-side two-stage DSE — Stage 1: the per-tenant design-point
optimizer (paper §3.1's "analytical model with a two-stage DSE", run *live*
in the serving loop).

The offline driver (:mod:`repro.core.dse`) optimizes per-layer runtime
parameters (Stage 1) and then schedules over the resulting mode tables
(Stage 2).  The serving fabric runs the same split at tenant granularity:

* **Stage 1 (here)** — for each candidate CU grant ``c``, pick the tenant's
  best *engine configuration* with the analytical model: data-parallel
  replica count (the grant tiled into ``dp`` independent ``tp``-wide
  slices, Herald-style), tensor-parallel degree over one slice (the
  all-reduce cost can make ``tp < c`` optimal), per-replica decode/SSM
  slot count (batch per step, memory-feasibility bounded, priced via
  ``batch`` in the step cost), and the encoder/enc-dec bucket ladder (fit
  to observed job lengths).  The result is a per-(tenant, c)
  :class:`~repro.core.dse.DesignPoint` memo;
* **Stage 2** — :class:`~repro.serve.fabric.AnalyticalPolicy`'s split
  search minimizes predicted makespan over compositions of those
  Stage-1-optimal points instead of raw CU counts, and
  :class:`~repro.serve.fabric.ComposedServer` applies the winning points
  live (``Engine.apply``).

This is the Herald/COAC point (PAPERS.md): matching each workload to its
own sub-accelerator *configuration* — not just a CU share — and
co-optimizing that configuration with the schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.common.platform import PlatformProfile, TPU_V5E
from repro.configs.base import ModelConfig
from repro.core.analytical import dp_dispatch_overhead, tp_collective_latency
from repro.core.dse import DesignPoint, dp_candidates, tp_candidates
from repro.workloads.base import (DECODE, ENCDEC, ENCODER, SSM,
                                  length_buckets, pick_bucket)

__all__ = ["DesignPoint", "Stage1Optimizer", "TenantDesignSpace",
           "design_key", "padded_factor"]


def design_key(cus: int, design: Mapping[str, object]) -> str:
    """Compact stable identity of an *applied* design point, e.g.
    ``"c4-tp2-dp1-s8"`` (plus ``-b128.512`` when a bucket ladder is set).

    Built from a group's grant width and ``Engine.design()`` output, so two
    tenants (or the same tenant before/after a retune) land on the same key
    iff they run the same configuration.  The fabric's
    :class:`repro.obs.PredictionLedger` files predicted and measured step
    costs under this key — the per-(class, design point) axis of the
    ``predicted_vs_measured`` summary."""
    tp = design.get("tp")
    dp = design.get("dp") or 1
    buckets = design.get("buckets")
    key = (f"c{int(cus)}-tp{int(tp) if tp else 0}-dp{int(dp)}"
           f"-s{int(design.get('slots') or 0)}")
    if buckets:
        key += "-b" + ".".join(str(int(b)) for b in buckets)
    return key


@dataclasses.dataclass(frozen=True)
class TenantDesignSpace:
    """The static bounds of one tenant's Stage-1 search, snapshotted from
    its engine by the fabric each decide tick."""

    wclass: str                          # workload class (repro.workloads)
    max_len: int                         # per-slot decode capacity (tokens)
    max_src: int = 0                     # enc-dec source capacity (frames)
    base_slots: int = 4                  # currently applied slot count
    base_buckets: Tuple[int, ...] = ()   # currently applied bucket ladder
    base_tp: Optional[int] = None        # applied TP degree (None = grant)
    base_dp: int = 1                     # applied replica count
    per_slot_elems: int = 0              # arena elements one slot pins
    tp_allowed: bool = True              # False on replicated fabrics
    slot_cap: int = 64                   # hard slot-count ceiling
    dp_cap: int = 64                     # hard replica-count ceiling
    # decode-side admission prefill pads prompts up to this bucket (0 =
    # exact-length prefill, e.g. SSM/hybrid archs): Stage 1 prices the
    # padded prefill work instead of treating prompt padding as free
    prefill_bucket: int = 0
    # ragged Pallas decode kernels active (ServeConfig.use_kernels): decode
    # steps stream only the live KV/source prefix, so Stage 1 prices the
    # expected observed length instead of the full per-slot capacity
    use_kernels: bool = True
    # paged KV arena (ServeConfig.paged_kv): admission reserves fixed-size
    # (page_rows, cols) pages as a stream grows instead of pinning
    # per_slot_elems up front, so the memory bound on the slot count is the
    # EXPECTED page footprint of a slot — Stage 1 can admit more slots on
    # the same HBM than the worst-case reservation would allow
    paged: bool = False
    page_rows: int = 0
    page_elems: int = 0


def padded_factor(ladder: Sequence[int], lengths: Sequence[int]) -> float:
    """Padded-work multiplier of a bucket ladder over observed job lengths:
    (tokens actually computed at each job's smallest fitting bucket) /
    (valid tokens).  1.0 = no padding waste; the capacity-only ladder on
    short jobs can be 10x+.  Empty observations price at no waste."""
    valid = [L for L in lengths if 0 < L <= ladder[-1]]
    if not valid:
        return 1.0
    return sum(pick_bucket(ladder, L) for L in valid) / sum(valid)


def _quantile(sorted_vals: Sequence[int], frac: float) -> int:
    return sorted_vals[min(int(frac * len(sorted_vals)),
                           len(sorted_vals) - 1)]


class Stage1Optimizer:
    """Per-tenant design-point search on the analytical model.

    ``step_cost`` is the class-aware per-step/per-token price (normally
    ``AnalyticalPolicy.step_cost`` — passing the bound method keeps the
    policy's memo as the shared price table).  Stage 1 layers on top of it
    the terms the split search alone cannot see:

    * the **tensor-parallel trade**: sharding a step over ``p`` CUs divides
      its bandwidth terms by ``p`` but adds ``2(p-1)`` all-reduce phases
      per layer (:func:`tp_collective_latency`) — small models stop
      scaling early, and the optimal ``tp`` can be < the grant;
    * the **batching trade**: ``slots`` decode streams amortize one step's
      weight traffic over ``slots`` tokens, but only ``min(slots, queue)``
      streams exist to fill them, and every slot pins arena memory;
    * the **padding trade**: a bucket ladder fit to observed job lengths
      cuts the encode phase's padded FLOPs (:func:`padded_factor`).
    """

    def __init__(self, step_cost: Callable,
                 platform: PlatformProfile = TPU_V5E, *,
                 slot_choices: Tuple[int, ...] = (1, 2, 4, 8, 16),
                 mem_budget_bytes: Optional[float] = None):
        self.step_cost = step_cost
        self.platform = platform
        self.slot_choices = tuple(sorted(set(slot_choices)))
        # HBM a tenant's slot pool may pin per granted CU (params, single
        # caches and headroom take the rest)
        self.mem_budget_bytes = (mem_budget_bytes if mem_budget_bytes
                                 is not None else platform.hbm_bytes / 2)

    # -- cost terms --------------------------------------------------------
    def collective_s(self, cfg: ModelConfig, batch: int, p: int,
                     space: Optional[TenantDesignSpace] = None) -> float:
        """Per-step tensor-parallel synchronization cost: ~2 all-reduces of
        the (batch, d_model) activations per layer at degree ``p``.  A
        replicated fabric (``tp_allowed=False``) runs no collectives at
        all, so its engines pay nothing regardless of grant.  Encoder-class
        work shards the encoder stack, so it pays over the same layer count
        ``step_cost`` prices its compute on."""
        if space is not None and not space.tp_allowed:
            return 0.0
        layers = (cfg.encoder_layers or cfg.num_layers
                  if space is not None and space.wclass == ENCODER
                  else cfg.num_layers)
        bytes_per = 4.0 * max(batch, 1) * cfg.d_model
        return layers * 2.0 * tp_collective_latency(
            self.platform, p, bytes_per)

    def _expected_src(self, space: TenantDesignSpace,
                      ladder: Tuple[int, ...],
                      lengths: Sequence[int], src_cap: int) -> int:
        """Expected per-slot source length an enc-dec tenant's
        cross-attention reads under ``ladder`` (falls back to the capacity
        when no lengths were observed — the pre-DSE pricing).  With the
        ragged kernels active the cross read is the *true* source length,
        not the padded bucket."""
        valid = [L for L in lengths if 0 < L <= ladder[-1]]
        if not valid:
            return src_cap or space.max_src or space.max_len
        if space.use_kernels:
            return max(1, sum(valid) // len(valid))
        return max(1, sum(pick_bucket(ladder, L) for L in valid)
                   // len(valid))

    def _expected_kv(self, space: TenantDesignSpace,
                     lengths: Sequence[int]) -> int:
        """Decoder-KV length a decode step streams per slot: the full
        per-slot capacity on the padded path (masked rows still read), the
        mean observed prompt length under the ragged kernels (no
        observations -> capacity, so an idle tenant is never under-priced)."""
        if not space.use_kernels:
            return space.max_len
        valid = [L for L in lengths if 0 < L <= space.max_len]
        if not valid:
            return space.max_len
        return max(1, min(sum(valid) // len(valid), space.max_len))

    def _prefill_tax(self, cfg: ModelConfig, space: TenantDesignSpace,
                     p: int, lengths: Sequence[int]) -> float:
        """Amortized per-step price of decode-side admission prefill: each
        admitted prompt runs one padded full-sequence pass (length rounded
        up to ``prefill_bucket``), paid once per request and spread over the
        request's expected decode steps.  Previously prompt padding was
        free to the model, so Stage 1 could never see a bucket mismatched
        to the traffic."""
        if space.prefill_bucket <= 0:
            return 0.0
        valid = [L for L in lengths if 0 < L <= space.max_len]
        if not valid:
            return 0.0
        bucket = max(space.prefill_bucket, 8)
        padded = [min(-(-L // bucket) * bucket, space.max_len)
                  for L in valid]
        mean_len = sum(valid) / len(valid)
        mean_pad = sum(padded) / len(padded)
        per_tok = self.step_cost(cfg, 1, p, ENCODER)
        steps = max(space.max_len - mean_len, 1.0)
        return per_tok * mean_pad / steps

    def cost_of(self, cfg: ModelConfig, space: TenantDesignSpace,
                concurrency: int, point: DesignPoint,
                lengths: Sequence[int] = (), src_cap: int = 0) -> float:
        """Predicted seconds per unit of owed work at a pinned design point
        (the hysteresis baseline: what the *currently applied* point costs
        under the current load).

        ``point.dp`` replicas tile the grant into ``cus // dp``-CU slices,
        each running an independent engine at ``slots`` slots: throughput
        multiplies by the replicas the queue can fill (``min(dp*slots,
        k)``), the TP degree is clamped to one slice's width, and every
        replica past the first pays the host dispatch serialization tax
        (:func:`~repro.core.analytical.dp_dispatch_overhead`)."""
        c = point.cus
        if c <= 0:
            return float("inf")
        d = max(1, min(point.dp or space.base_dp, c))
        w = max(c // d, 1)                     # CUs per replica slice
        p = min(point.tp or w, w)
        slots = point.slots or space.base_slots
        ladder = length_buckets(point.buckets if point.buckets is not None
                                else space.base_buckets,
                                space.max_src or space.max_len)
        k = max(concurrency, 1)
        if space.wclass == ENCODER:
            per_tok = self.step_cost(cfg, slots, p, ENCODER)
            coll = self.collective_s(cfg, 1, p, space)
            return (per_tok * padded_factor(ladder, lengths) + coll) / d
        if space.wclass == ENCDEC:
            src = self._expected_src(space, ladder, lengths, src_cap)
            base = self.step_cost(cfg, slots, p, ENCDEC, src_len=src,
                                  kv_len=space.max_len)
        elif space.wclass == DECODE:
            base = self.step_cost(cfg, slots, p, DECODE,
                                  kv_len=self._expected_kv(space, lengths))
        else:
            base = self.step_cost(cfg, slots, p, space.wclass)
        per_step = (base + self.collective_s(cfg, slots, p, space)
                    + dp_dispatch_overhead(d)) / min(d * slots, k)
        # decode-side prompt padding at admission is work too (satellite of
        # the ragged-kernel hot path: the prefill bucket stops being free)
        if space.wclass == DECODE:
            per_step += self._prefill_tax(cfg, space, p, lengths)
        return per_step

    def _per_slot_bytes(self, space: TenantDesignSpace,
                        lengths: Sequence[int]) -> float:
        """Expected HBM one slot pins: the full worst-case reservation on a
        slot-granular arena; on a paged arena the whole-page footprint of a
        slot's *lifetime-average* live rows — the midpoint between the
        expected admission length and the per-slot capacity (no
        observations -> capacity, so an idle tenant is never
        under-priced)."""
        worst = 4.0 * space.per_slot_elems
        if (not space.paged or space.page_rows <= 0
                or space.page_elems <= 0):
            return worst
        valid = [L for L in lengths if 0 < L <= space.max_len]
        rows = (min((sum(valid) / len(valid) + space.max_len) / 2.0,
                    space.max_len)
                if valid else space.max_len)
        pages = -(-int(max(rows, 1)) // space.page_rows)
        expected = 4.0 * pages * space.page_elems
        return min(expected, worst) if worst > 0 else expected

    # -- the search --------------------------------------------------------
    def _slot_candidates(self, space: TenantDesignSpace, concurrency: int,
                         p: int, lengths: Sequence[int] = ()
                         ) -> Tuple[int, ...]:
        """Arena-feasible slot counts worth trying at TP degree ``p``: the
        preset ladder plus the applied count and the observed concurrency
        (rounded up to even), memory-bounded by the slot pool the ``p``
        compute CUs' HBM can pin (expected page footprint per slot on a
        paged arena, worst-case reservation otherwise)."""
        cap = space.slot_cap
        per_bytes = self._per_slot_bytes(space, lengths)
        if per_bytes > 0:
            by_mem = int(p * self.mem_budget_bytes // per_bytes)
            cap = max(1, min(cap, by_mem))
        want = min(max(concurrency, 1), cap)
        cands = {s for s in self.slot_choices if s <= cap}
        cands.add(min(space.base_slots, cap))
        cands.add(min(want + (want % 2), cap))     # cover the queue
        return tuple(sorted(c for c in cands if c >= 1))

    def _ladder_candidates(self, space: TenantDesignSpace,
                           lengths: Sequence[int]) -> Tuple[Tuple[int, ...], ...]:
        """Candidate bucket ladders: the applied one, capacity-only, and
        quantile ladders fit to the observed length distribution (p50 and
        p50+p90 breakpoints, rounded up to 8)."""
        cap = space.max_src or space.max_len
        cands = {length_buckets(space.base_buckets, cap),
                 length_buckets((), cap)}
        valid = sorted(L for L in lengths if 0 < L <= cap)
        if valid:
            r8 = lambda v: min(-(-v // 8) * 8, cap)          # noqa: E731
            p50, p90 = _quantile(valid, 0.5), _quantile(valid, 0.9)
            cands.add(length_buckets((r8(p50),), cap))
            cands.add(length_buckets((r8(p50), r8(p90)), cap))
        return tuple(sorted(cands))

    def best(self, cfg: ModelConfig, space: TenantDesignSpace,
             concurrency: int, cus: int, lengths: Sequence[int] = (),
             src_cap: int = 0) -> DesignPoint:
        """Stage 1 proper: the tenant's cheapest design point on a
        ``cus``-CU grant, searched jointly over ``(dp, tp, slots,
        buckets)``.  Ties break toward the currently applied knobs
        (stability: a reconfiguration must buy something)."""
        if cus <= 0:
            return DesignPoint(cus=0, cost=float("inf"))
        has_encode = space.wclass in (ENCODER, ENCDEC)
        ladders = (self._ladder_candidates(space, lengths) if has_encode
                   else (None,))
        base_ladder = length_buckets(space.base_buckets,
                                     space.max_src or space.max_len)
        dps = tuple(d for d in dp_candidates(cus, 1)
                    if d <= max(space.dp_cap, 1)) or (1,)
        applied_dp = max(1, min(space.base_dp, cus))
        k = max(concurrency, 1)
        best = None
        for dp in dps:
            w = max(cus // dp, 1)              # CUs per replica slice
            tps = tp_candidates(w) if space.tp_allowed else (w,)
            # what the engine would run at on THIS slice if nothing changed
            applied_tp = min(space.base_tp or w, w)
            per_k = -(-k // dp)                # per-replica queue share
            for tp in tps:
                slot_cands = ((space.base_slots,)
                              if space.wclass == ENCODER
                              else self._slot_candidates(space, per_k, tp,
                                                         lengths))
                for slots in slot_cands:
                    for ladder in ladders:
                        point = DesignPoint(cus=cus, tp=tp, slots=slots,
                                            buckets=ladder, dp=dp)
                        cost = self.cost_of(cfg, space, concurrency, point,
                                            lengths, src_cap)
                        # deviation from the applied knobs: tie-break only
                        # (reconfiguring must buy something, so ties never
                        # trigger a gratuitous reshard/resize/ladder swap)
                        dev = ((0 if dp == applied_dp else 1)
                               + (0 if tp == applied_tp else 1)
                               + (0 if slots == space.base_slots else 1)
                               + (0 if ladder in (None, base_ladder) else 1))
                        cand = (cost, dev,
                                dataclasses.replace(point, cost=cost))
                        if best is None or cand[:2] < best[:2]:
                            best = cand
        assert best is not None
        return best[2]
