"""Instruction-set tests: binary encode/decode roundtrips (hypothesis) and
field semantics (Table 1)."""
from hypothesis import given, settings, strategies as st

from repro.core import instructions as isa


@settings(max_examples=50, deadline=None)
@given(st.booleans(), st.integers(0, 3), st.integers(0, 65535))
def test_instrgen_roundtrip(last, unit, length):
    i = isa.InstrGen(last, unit, length)
    assert isa.InstrGen.decode(i.encode()) == i


@settings(max_examples=50, deadline=None)
@given(st.booleans(), st.integers(0, 2**40), st.integers(0, 1000),
       st.integers(0, 2**20), st.integers(0, 2**20),
       st.integers(0, 2**20), st.integers(0, 2**20),
       st.integers(0, 2**20), st.integers(0, 2**20))
def test_iomload_roundtrip(last, addr, fmu, m, n, r0, r1, c0, c1):
    i = isa.IOMLoad(last, addr, fmu, m, n, r0, r1, c0, c1)
    assert isa.IOMLoad.decode(i.encode()) == i


@settings(max_examples=50, deadline=None)
@given(st.booleans(), st.integers(0, 3), st.integers(0, 3),
       st.integers(0, 255), st.integers(0, 255), st.integers(0, 2**20),
       st.integers(0, 2**16), st.integers(0, 2**16),
       st.integers(0, 2**16), st.integers(0, 2**16), st.integers(0, 2**16))
def test_fmu_roundtrip(last, ping, pong, src, des, count, r0, r1, c0, c1, vc):
    i = isa.FMUInstr(last, ping, pong, src, des, count, r0, r1, c0, c1, vc)
    assert isa.FMUInstr.decode(i.encode()) == i


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 1023), st.integers(0, 1023), st.integers(0, 1023))
def test_pack_unpack_mkn(m, k, n):
    assert isa.unpack_mkn(isa.pack_mkn(m, k, n)) == (m, k, n)


def test_stream_encode_decode():
    instrs = [isa.CUInstr(False, isa.OP_MM, isa.OP_NOP, 1, 2,
                          isa.pack_mkn(4, 2, 3), 5),
              isa.CUInstr(True, isa.OP_MM, isa.OP_NOP, 0, 1,
                          isa.pack_mkn(1, 1, 1), 2)]
    data = isa.encode_stream(instrs)
    back = isa.decode_stream("cu", data)
    assert back == instrs
    # runtime reconfiguration payload is a few bytes (paper §2.5)
    assert len(data) // len(instrs) <= 16
